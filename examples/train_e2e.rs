//! End-to-end driver (DESIGN.md §validation): train the decoder-only
//! transformer LM on the synthetic token corpus across 8 simulated
//! workers with 8-bit APS gradient synchronization for a few hundred
//! steps, logging the loss curve. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example train_e2e               # full run (~300 steps)
//! cargo run --release --example train_e2e -- --steps 40 # quick check
//! ```

use anyhow::Result;
use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::FpFormat;
use aps_cpd::optim::{LrSchedule, OptimizerKind};
use aps_cpd::runtime::Engine;
use aps_cpd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300)?;
    let world = args.get_usize("world", 8)?;
    let epochs = 5usize;

    let engine = Engine::cpu()?;
    let model = engine.load_model("artifacts", "transformer")?;
    println!(
        "e2e: transformer LM — {} params, vocab {}, seq {}, {} workers × batch {}",
        model.spec.total_params(),
        model.spec.num_classes,
        model.spec.x_shape[0],
        world,
        model.spec.batch
    );

    let sync = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
    let mut setup = TrainerSetup::new(world, sync);
    setup.epochs = epochs;
    setup.steps_per_epoch = steps.div_ceil(epochs);
    setup.optimizer = OptimizerKind::Sgd { momentum: 0.9, weight_decay: 1e-5, nesterov: false };
    setup.schedule = LrSchedule::WarmupStep {
        warmup_from: 0.01,
        peak: 0.15,
        warmup_epochs: 1.0,
        decay_at: vec![3.0, 4.0],
        decay_factor: 0.3,
    };
    setup.eval_examples = 64;
    setup.log_every = 10;

    let mut trainer = Trainer::new(&model, setup)?;
    let out = trainer.train("e2e-transformer-aps-e5m2")?;

    println!("\n--- loss curve (step, train loss) ---");
    for p in out.loss.points.iter().step_by(10.max(out.loss.points.len() / 30)) {
        println!("{:>5} {:.4}", p.0, p.1);
    }
    println!("--- eval loss per epoch ---");
    for p in &out.eval.points {
        println!("epoch {:>2}: {:.4}", p.0, p.1);
    }
    let uniform = (model.spec.num_classes as f64).ln();
    println!(
        "\nfinal eval loss {:.4} (uniform-vocab entropy {:.3})",
        out.final_metric, uniform
    );
    println!(
        "steps {} | wall {:.1}s | payload {} MiB/worker | exponent phase {} KiB | diverged: {}",
        out.steps_run,
        out.wall_secs,
        out.comm_payload_bytes >> 20,
        out.comm_exponent_bytes >> 10,
        out.diverged
    );
    anyhow::ensure!(!out.diverged, "e2e run diverged");
    anyhow::ensure!(out.final_metric < uniform, "no learning happened");
    Ok(())
}

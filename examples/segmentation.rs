//! Segmentation workload (paper Table 3 scenario): train the FCN on the
//! synthetic shape dataset under FP32 vs APS-8bit vs naive-8bit and
//! report mIoU / mAcc.

use anyhow::Result;
use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::FpFormat;
use aps_cpd::optim::LrSchedule;
use aps_cpd::runtime::Engine;
use aps_cpd::util::cli::Args;
use aps_cpd::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 30)?;
    let epochs = args.get_usize("epochs", 3)?;

    let engine = Engine::cpu()?;
    let model = engine.load_model("artifacts", "fcn")?;
    println!(
        "fcn: {} params, {} classes, batch {} × 8 workers",
        model.spec.total_params(),
        model.spec.num_classes,
        model.spec.batch
    );

    let mut t = Table::new(&["precision", "APS", "mIoU", "mAcc", "diverged"]);
    for (label, aps, method) in [
        ("(8,23): 32bits", "/", SyncMethod::Fp32),
        ("(4,3): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E4M3 }),
        ("(4,3): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E4M3 }),
        ("(5,2): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E5M2 }),
        ("(5,2): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E5M2 }),
    ] {
        let mut setup = TrainerSetup::new(8, SyncOptions::new(method));
        setup.epochs = epochs;
        setup.steps_per_epoch = steps;
        setup.schedule = LrSchedule::Constant { lr: 0.1 };
        setup.eval_examples = 64;
        let mut trainer = Trainer::new(&model, setup)?;
        let out = trainer.train(format!("fcn {label} aps={aps}"))?;
        t.row(&[
            label.to_string(),
            aps.to_string(),
            format!("{:.2}", 100.0 * out.final_metric),
            format!("{:.2}", 100.0 * out.final_macc.unwrap_or(f64::NAN)),
            format!("{}", out.diverged),
        ]);
    }
    println!();
    t.print();
    Ok(())
}

//! Communication-model tour: Fig 11 reproduction plus sweeps over world
//! size and topology with the α–β model.

use anyhow::Result;
use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::perfmodel::{fig11_layers, fig11_table, sync_time, CommMethod, NetworkModel};
use aps_cpd::util::table::Table;

fn main() -> Result<()> {
    let net = NetworkModel::v100_nccl();

    println!("Fig 11 — all-reduce time on 32 workers (α–β model, V100/NCCL calibration):\n");
    let mut t = Table::new(&[
        "layer",
        "fp16 ms",
        "APS exp ms",
        "APS payload ms",
        "APS total ms",
        "speedup",
    ]);
    for r in fig11_table(&net, 32) {
        t.row(&[
            r.label.clone(),
            format!("{:.3}", r.fp16_ms),
            format!("{:.4}", r.aps_exp_phase_ms),
            format!("{:.3}", r.aps_payload_ms),
            format!("{:.3}", r.aps_total_ms),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t.print();

    println!("\nTopology sweep — ResNet-50 tail layers, APS-8bit total sync time (ms):\n");
    let layers = fig11_layers();
    let mut t = Table::new(&["world", "ring", "hier k=8", "hier k=16", "hier k=32"]);
    for world in [32usize, 64, 128, 256, 512] {
        let mut row = vec![world.to_string()];
        for topo in [
            Some(Topology::Ring),
            (world % 8 == 0).then_some(Topology::Hierarchical { group_size: 8 }),
            (world % 16 == 0).then_some(Topology::Hierarchical { group_size: 16 }),
            (world % 32 == 0).then_some(Topology::Hierarchical { group_size: 32 }),
        ] {
            row.push(match topo {
                Some(tp) => format!(
                    "{:.3}",
                    1e3 * sync_time(
                        &net,
                        tp,
                        world,
                        &layers,
                        CommMethod::Aps { fmt: FpFormat::E5M2 },
                        true
                    )
                ),
                None => "-".to_string(),
            });
        }
        t.row(&row);
    }
    t.print();

    println!("\nWire-width sweep — fused tail-layer sync on 32 workers:\n");
    let mut t = Table::new(&["method", "time ms", "vs fp32"]);
    let fp32 = sync_time(
        &net,
        Topology::Ring,
        32,
        &layers,
        CommMethod::PlainAllReduce { bits: 32 },
        true,
    );
    for (name, m) in [
        ("fp32 all-reduce", CommMethod::PlainAllReduce { bits: 32 }),
        ("fp16 all-reduce", CommMethod::PlainAllReduce { bits: 16 }),
        ("APS 8-bit (e5m2)", CommMethod::Aps { fmt: FpFormat::E5M2 }),
        ("APS 4-bit (e3m0, byte-packed)", CommMethod::Aps { fmt: FpFormat::E3M0 }),
    ] {
        let s = sync_time(&net, Topology::Ring, 32, &layers, m, true);
        t.row(&[name.to_string(), format!("{:.3}", 1e3 * s), format!("{:.2}x", fp32 / s)]);
    }
    t.print();
    Ok(())
}

//! Quickstart: train the MLP across 8 simulated workers with 8-bit APS
//! gradient communication and compare against the FP32 baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::FpFormat;
use aps_cpd::optim::LrSchedule;
use aps_cpd::runtime::Engine;
use aps_cpd::util::table::Table;

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let model = engine.load_model("artifacts", "mlp")?;
    println!(
        "model: {} ({} params), local batch {}, 8 workers → global batch {}\n",
        model.spec.name,
        model.spec.total_params(),
        model.spec.batch,
        model.spec.batch * 8
    );

    let mut results = Vec::new();
    for (label, method) in [
        ("fp32 (baseline)", SyncMethod::Fp32),
        ("aps e5m2 (8-bit)", SyncMethod::Aps { fmt: FpFormat::E5M2 }),
        ("naive e5m2 (8-bit, no APS)", SyncMethod::Naive { fmt: FpFormat::E5M2 }),
        ("aps e3m0 (4-bit)", SyncMethod::Aps { fmt: FpFormat::E3M0 }),
        ("naive e3m0 (4-bit, no APS)", SyncMethod::Naive { fmt: FpFormat::E3M0 }),
    ] {
        let mut setup = TrainerSetup::new(8, SyncOptions::new(method));
        setup.epochs = 3;
        setup.steps_per_epoch = 15;
        setup.schedule = LrSchedule::Constant { lr: 0.05 };
        setup.eval_examples = 512;
        setup.log_every = 15;
        let mut trainer = Trainer::new(&model, setup)?;
        let out = trainer.train(label)?;
        results.push(out);
    }

    let mut t = Table::new(&[
        "method",
        "final acc",
        "final loss",
        "payload KiB/worker",
        "exp-phase B",
        "diverged",
    ]);
    for r in &results {
        t.row(&[
            r.name.clone(),
            format!("{:.3}", r.final_metric),
            format!("{:.3}", r.loss.tail_mean(5)),
            format!("{}", r.comm_payload_bytes / 1024),
            format!("{}", r.comm_exponent_bytes),
            format!("{}", r.diverged),
        ]);
    }
    println!();
    t.print();
    println!(
        "\nAPS sends {:.1}× fewer payload bytes than FP32 at matched accuracy.",
        results[0].comm_payload_bytes as f64 / results[1].comm_payload_bytes as f64
    );
    Ok(())
}

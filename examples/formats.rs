//! CPD tour: Table 1 format ranges, the Fig 4 power-of-two round-trip,
//! the Fig 12 accumulator-precision effect, and Kahan summation.

use anyhow::Result;
use aps_cpd::cpd::gemm::{dot, AccumStrategy};
use aps_cpd::cpd::{accum, quantize, quantize_shifted, FpFormat, Rounding};
use aps_cpd::util::table::Table;

const RNE: Rounding = Rounding::NearestEven;

fn main() -> Result<()> {
    // ---- Table 1: representable ranges. ---------------------------------
    println!("Table 1 — representable ranges:\n");
    let mut t = Table::new(&["format", "exp", "man", "range"]);
    for f in [
        FpFormat::FP32,
        FpFormat::FP16,
        FpFormat::BF16,
        FpFormat::E6M9,
        FpFormat::E5M2,
        FpFormat::E4M3,
        FpFormat::E3M0,
    ] {
        let (lo, hi) = f.exponent_range();
        t.row(&[
            f.to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            format!("[2^{lo}, 2^{hi}]"),
        ]);
    }
    t.print();

    // ---- Fig 4: scaling by 8 is lossless on the wire, by 10 is not. -----
    println!("\nFig 4 — wire value after scaling in (5,2):\n");
    let x = 1.25f32;
    let wire8 = quantize(x * 8.0, FpFormat::E5M2, RNE);
    let wire10 = quantize(x * 10.0, FpFormat::E5M2, RNE);
    println!("  x = {x}");
    println!("  Q(x*8)  = {wire8}   (= x·8 exactly: exponent-only change)");
    println!("  Q(x*10) = {wire10}   (x·10 = 12.5 not representable → round-off)");
    assert_eq!(wire8, 10.0);
    assert_ne!(wire10 as f64, 12.5);
    // The exponent-space shift primitive is exact by construction:
    assert_eq!(quantize_shifted(x, 3, FpFormat::E5M2, RNE), 10.0);

    // ---- Fig 12: accumulator precision in a dot product. ----------------
    println!("\nFig 12 — dot-product accumulator strategies in (4,2), exact = 128:\n");
    let a = vec![1.0f32; 256];
    let b = vec![0.5f32; 256];
    let fmt = FpFormat::new(4, 2);
    let mut t = Table::new(&["strategy", "result"]);
    for (name, s) in [
        ("FP32 accumulate, cast once (QPyTorch-style)", AccumStrategy::WideThenCast),
        ("low-precision accumulator (CPD faithful)", AccumStrategy::LowPrecision),
        ("low-precision + Kahan (CPD §5.1.1)", AccumStrategy::Kahan),
    ] {
        let r = dot(&a, &b, fmt, RNE, s);
        t.row(&[name.to_string(), format!("{r}")]);
    }
    t.print();

    // ---- Kahan accumulation demo. ---------------------------------------
    println!("\nKahan summation — 64 + 1.0×64 in (4,3), exact = 128:\n");
    let xs: Vec<f32> = std::iter::once(64.0).chain(std::iter::repeat(1.0).take(64)).collect();
    let naive = accum::sum_low_precision(&xs, FpFormat::E4M3, RNE);
    let kahan = accum::sum_kahan(&xs, FpFormat::E4M3, RNE);
    println!("  naive low-precision sum: {naive}");
    println!("  Kahan low-precision sum: {kahan}");
    Ok(())
}

//! Vendored, offline stand-in for the `anyhow` crate.
//!
//! This repository must build with no network access and no registry
//! cache, so the small slice of `anyhow` it actually uses is implemented
//! here and wired in as a path dependency (the import paths in the main
//! crate are unchanged, so swapping back to the real crate is a one-line
//! Cargo.toml edit). Provided surface:
//!
//! * [`Error`] — an opaque error value with a context chain; `Display`
//!   shows the outermost message, `Debug` shows the full chain.
//! * [`Result`] — `Result<T, E = Error>` alias.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on any
//!   `Result` whose error converts into [`Error`].
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what makes the blanket
//! `impl From<E: std::error::Error>` coherent alongside `From<Error>`.

use std::fmt;

/// An error value: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain from the outermost message inward.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in self.chain().skip(1) {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Capture the std source chain as a context chain.
        let mut messages: Vec<String> = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            messages.push(s.to_string());
            cur = s.source();
        }
        let mut err = Error::msg(messages.pop().unwrap());
        while let Some(m) = messages.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (mirrors `anyhow::Context`).
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context() {
        let e = io_fail().with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "step 7");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn chain_walks_outside_in() {
        let e = Error::msg("root").context("mid").context("top");
        let msgs: Vec<&str> = e.chain().collect();
        assert_eq!(msgs, vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}

//! Evaluation metrics and distribution tooling.
//!
//! * classification accuracy (Tables 4–8)
//! * mIoU / mAcc for segmentation (Table 3)
//! * exponent histograms of gradient values (Figs 1, 2, 5)
//! * under/overflow fractions for a format + scale (Fig 5)
//! * a small loss-curve recorder used by every training run.

use crate::cpd::FpFormat;

/// Top-1 accuracy given per-example logits (`n × classes`) and labels.
pub fn top1_accuracy(logits: &[f32], labels: &[u32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    if labels.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for (i, &lab) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best as u32 == lab {
            correct += 1;
        }
    }
    correct as f64 / labels.len() as f64
}

/// Segmentation confusion-matrix metrics (paper Table 3's mIoU / mAcc).
#[derive(Clone, Debug)]
pub struct SegmentationMetrics {
    classes: usize,
    /// `confusion[t * classes + p]` = pixels with true `t` predicted `p`.
    confusion: Vec<u64>,
}

impl SegmentationMetrics {
    pub fn new(classes: usize) -> Self {
        SegmentationMetrics { classes, confusion: vec![0; classes * classes] }
    }

    /// Accumulate per-pixel logits (`pixels × classes`) against a mask.
    pub fn update_from_logits(&mut self, logits: &[f32], mask: &[u32]) {
        assert_eq!(logits.len(), mask.len() * self.classes);
        for (i, &t) in mask.iter().enumerate() {
            let row = &logits[i * self.classes..(i + 1) * self.classes];
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            self.confusion[t as usize * self.classes + best] += 1;
        }
    }

    /// Accumulate hard predictions against a mask.
    pub fn update(&mut self, pred: &[u32], mask: &[u32]) {
        assert_eq!(pred.len(), mask.len());
        for (&p, &t) in pred.iter().zip(mask) {
            self.confusion[t as usize * self.classes + p as usize] += 1;
        }
    }

    /// Mean intersection-over-union over classes present in the reference.
    pub fn miou(&self) -> f64 {
        let c = self.classes;
        let mut sum = 0.0;
        let mut n = 0usize;
        for k in 0..c {
            let tp = self.confusion[k * c + k];
            let fp: u64 = (0..c).filter(|&t| t != k).map(|t| self.confusion[t * c + k]).sum();
            let fn_: u64 = (0..c).filter(|&p| p != k).map(|p| self.confusion[k * c + p]).sum();
            let denom = tp + fp + fn_;
            if tp + fn_ == 0 {
                continue; // class absent from reference
            }
            sum += tp as f64 / denom.max(1) as f64;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Mean per-class pixel accuracy (paper's mAcc).
    pub fn macc(&self) -> f64 {
        let c = self.classes;
        let mut sum = 0.0;
        let mut n = 0usize;
        for k in 0..c {
            let tp = self.confusion[k * c + k];
            let total: u64 = (0..c).map(|p| self.confusion[k * c + p]).sum();
            if total == 0 {
                continue;
            }
            // apslint: allow(lossy_cast) -- example counts stay far below 2^53, so the f64 division is exact in its inputs
            sum += tp as f64 / total as f64;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

/// Histogram of binary exponents (`floor(log2 |x|)`) — the x-axis of the
/// paper's Figs 1, 2 and 5 gradient-distribution plots.
#[derive(Clone, Debug)]
pub struct ExpHistogram {
    /// Exponent of the first bucket (inclusive).
    pub min_exp: i32,
    /// Bucket `i` counts values with exponent `min_exp + i`.
    pub counts: Vec<u64>,
    /// Exact zeros (no exponent).
    pub zeros: u64,
    /// Values below `min_exp` / at-or-above `min_exp + counts.len()`.
    pub below: u64,
    pub above: u64,
}

impl ExpHistogram {
    pub fn new(min_exp: i32, max_exp: i32) -> Self {
        assert!(max_exp > min_exp);
        ExpHistogram {
            min_exp,
            counts: vec![0; (max_exp - min_exp) as usize],
            zeros: 0,
            below: 0,
            above: 0,
        }
    }

    /// Standard gradient window used by the figure reproductions.
    pub fn gradient_window() -> Self {
        Self::new(-40, 10)
    }

    pub fn add(&mut self, x: f32) {
        if x == 0.0 {
            self.zeros += 1;
            return;
        }
        if !x.is_finite() {
            self.above += 1;
            return;
        }
        let e = x.abs().log2().floor() as i32;
        let idx = e - self.min_exp;
        if idx < 0 {
            self.below += 1;
        } else if idx as usize >= self.counts.len() {
            self.above += 1;
        } else {
            self.counts[idx as usize] += 1;
        }
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.zeros + self.below + self.above
    }

    /// Fraction of (non-zero) mass whose exponent is below `e`.
    pub fn frac_below(&self, e: i32) -> f64 {
        let nz: u64 = self.counts.iter().sum::<u64>() + self.below + self.above;
        if nz == 0 {
            return 0.0;
        }
        let mut c = self.below;
        for (i, &v) in self.counts.iter().enumerate() {
            if self.min_exp + (i as i32) < e {
                c += v;
            }
        }
        // apslint: allow(lossy_cast) -- histogram element counts stay far below 2^53, so the f64 division is exact in its inputs
        c as f64 / nz as f64
    }

    /// Percentile exponent (0..=100) of the non-zero mass.
    pub fn percentile_exp(&self, pct: f64) -> i32 {
        let nz: u64 = self.counts.iter().sum::<u64>() + self.below + self.above;
        // apslint: allow(lossy_cast) -- histogram element counts stay far below 2^53, so nz is exact in f64
        let target = (nz as f64 * pct / 100.0) as u64;
        let mut acc = self.below;
        if acc >= target {
            return self.min_exp - 1;
        }
        for (i, &v) in self.counts.iter().enumerate() {
            acc += v;
            if acc >= target {
                return self.min_exp + i as i32;
            }
        }
        // apslint: allow(lossy_cast) -- the histogram has a fixed, small number of exponent bins (< 300), exact in i32
        self.min_exp + self.counts.len() as i32
    }

    /// Render an ASCII bar chart (benches print these as the "figures").
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let e = self.min_exp + i as i32;
            let bar = "#".repeat(((c as f64 / max as f64) * width as f64).ceil() as usize);
            out.push_str(&format!("2^{e:>4} | {bar} {c}\n"));
        }
        out
    }
}

/// Fractions of a sample that would underflow / overflow in `fmt` after
/// scaling by `2^factor_exp` (paper Fig 5's curves).
pub fn under_overflow_fracs(xs: &[f32], fmt: FpFormat, factor_exp: i32) -> (f64, f64) {
    let lo = fmt.min_subnormal() / 2.0; // RNE cutoff to zero
    let hi = fmt.max_value();
    let scale = (factor_exp as f64).exp2();
    let mut under = 0usize;
    let mut over = 0usize;
    let mut nonzero = 0usize;
    for &x in xs {
        if x == 0.0 {
            continue;
        }
        nonzero += 1;
        let v = (x as f64).abs() * scale;
        if v < lo {
            under += 1;
        } else if v > hi {
            over += 1;
        }
    }
    if nonzero == 0 {
        (0.0, 0.0)
    } else {
        (under as f64 / nonzero as f64, over as f64 / nonzero as f64)
    }
}

/// Rolling record of scalar series (loss curves etc.) for EXPERIMENTS.md.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }
    /// Mean of the final `k` values (smoothed endpoint for tables).
    pub fn tail_mean(&self, k: usize) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let n = self.points.len();
        let s = &self.points[n.saturating_sub(k)..];
        s.iter().map(|p| p.1).sum::<f64>() / s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        // 2 classes; logits rows: [0.9, 0.1] → 0, [0.2, 0.8] → 1
        let logits = vec![0.9, 0.1, 0.2, 0.8];
        assert_eq!(top1_accuracy(&logits, &[0, 1], 2), 1.0);
        assert_eq!(top1_accuracy(&logits, &[1, 1], 2), 0.5);
    }

    #[test]
    fn miou_perfect_and_degenerate() {
        let mut m = SegmentationMetrics::new(3);
        m.update(&[0, 1, 2, 1], &[0, 1, 2, 1]);
        assert!((m.miou() - 1.0).abs() < 1e-12);
        assert!((m.macc() - 1.0).abs() < 1e-12);

        let mut w = SegmentationMetrics::new(3);
        w.update(&[1, 1, 1, 1], &[0, 0, 0, 0]);
        assert_eq!(w.miou(), 0.0);
    }

    #[test]
    fn miou_half_overlap() {
        let mut m = SegmentationMetrics::new(2);
        // class 1: true {a,b}, predicted correctly on a only; class 0 ok.
        m.update(&[1, 0, 0], &[1, 1, 0]);
        // IoU(1) = 1/2, IoU(0) = 1/2 → mIoU = 0.5
        assert!((m.miou() - 0.5).abs() < 1e-9, "{}", m.miou());
    }

    #[test]
    fn exp_histogram() {
        let mut h = ExpHistogram::new(-4, 4);
        h.add_all(&[1.0, 1.5, 0.25, 0.0, 1e-9, 1e9]);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.below, 1);
        assert_eq!(h.above, 1);
        assert_eq!(h.counts[(0 - h.min_exp) as usize], 2); // 1.0 and 1.5
        assert_eq!(h.counts[(-2 - h.min_exp) as usize], 1); // 0.25
        assert_eq!(h.total(), 6);
        assert!(!h.ascii(20).is_empty());
    }

    #[test]
    fn percentiles() {
        let mut h = ExpHistogram::new(-8, 8);
        for i in 0..100 {
            h.add(2f32.powi(-(i % 8)));
        }
        let p50 = h.percentile_exp(50.0);
        assert!((-8..=0).contains(&p50));
        assert!(h.percentile_exp(100.0) >= p50);
    }

    #[test]
    fn fig5_fracs_move_with_scale() {
        let fmt = FpFormat::E5M2;
        let xs: Vec<f32> = (1..1000).map(|i| i as f32 * 1e-7).collect();
        let (u0, o0) = under_overflow_fracs(&xs, fmt, 0);
        let (u1, o1) = under_overflow_fracs(&xs, fmt, 20);
        assert!(u1 < u0, "scaling up reduces underflow");
        assert!(o1 >= o0);
        let (u2, _) = under_overflow_fracs(&xs, fmt, 60);
        assert_eq!(u2, 0.0);
    }

    #[test]
    fn series_tail_mean() {
        let mut s = Series::new("loss");
        for i in 0..10 {
            s.push(i as f64, i as f64);
        }
        assert_eq!(s.tail_mean(2), 8.5);
        assert_eq!(s.last(), Some(9.0));
    }
}

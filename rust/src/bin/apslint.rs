//! `apslint` — the repo's static-analysis pass. See `aps_cpd::lint` for
//! the rule table, rationale and waiver syntax.
//!
//! ```text
//! cargo run --bin apslint                      # lint the repo, write apslint_report.json
//! cargo run --bin apslint -- --json out.json   # report elsewhere
//! cargo run --bin apslint -- --quiet           # summary line only
//! cargo run --bin apslint -- path/to/repo      # lint another checkout
//! ```
//!
//! Exit code 0 when every error-severity diagnostic carries a reasoned
//! waiver, 1 when any does not (this is what fails CI), 2 on I/O or
//! usage errors.

use aps_cpd::lint;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json_path = PathBuf::from("apslint_report.json");
    let mut quiet = false;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_path = PathBuf::from(p),
                None => {
                    eprintln!("apslint: --json needs a path");
                    return ExitCode::from(2);
                }
            },
            "--quiet" | "-q" => quiet = true,
            "--help" | "-h" => {
                eprintln!("usage: apslint [ROOT] [--json PATH] [--quiet]");
                return ExitCode::SUCCESS;
            }
            other if !other.starts_with('-') => root = PathBuf::from(other),
            other => {
                eprintln!("apslint: unknown flag {other:?}");
                return ExitCode::from(2);
            }
        }
    }

    let cfg = lint::Config::repo_default();
    let report = match lint::run(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("apslint: {e}");
            return ExitCode::from(2);
        }
    };

    if !quiet {
        for d in &report.diagnostics {
            println!("{}", d.render());
        }
    }
    println!(
        "apslint: {} error(s), {} warning(s), {} waived across {} files",
        report.errors(),
        report.warnings(),
        report.waived(),
        report.files_scanned
    );

    if let Err(e) = std::fs::write(&json_path, report.to_json().to_string() + "\n") {
        eprintln!("apslint: cannot write {}: {e}", json_path.display());
        return ExitCode::from(2);
    }

    if report.ok() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

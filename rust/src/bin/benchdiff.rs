//! `benchdiff` — the bench-trajectory regression gate.
//!
//! ```text
//! benchdiff <baseline.json> <current.json> [--refresh] [--write-missing]
//! ```
//!
//! Compares a freshly measured `BENCH_packed.json` against the committed
//! baseline (see `util::benchdiff` for the rules: bytes-moved exact,
//! throughput gated at 0.8x of the dense-normalized baseline ratio).
//! Exit 0 on pass, 1 on regression, 2 on usage/IO/parse errors.
//!
//! `--refresh` rewrites the baseline with the current record after a
//! passing comparison (how an intentional perf/traffic change lands).
//! `--write-missing` seeds the baseline from the current record when the
//! baseline file does not exist yet (bootstrap).

use aps_cpd::util::benchdiff::compare;
use aps_cpd::util::json::Json;
use std::process::ExitCode;

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut refresh = false;
    let mut write_missing = false;
    for a in &args {
        match a.as_str() {
            "--refresh" => refresh = true,
            "--write-missing" => write_missing = true,
            _ => paths.push(a.clone()),
        }
    }
    let [baseline_path, current_path] = paths.as_slice() else {
        eprintln!("usage: benchdiff <baseline.json> <current.json> [--refresh] [--write-missing]");
        return ExitCode::from(2);
    };

    let current = match load(current_path) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };

    if !std::path::Path::new(baseline_path).exists() {
        if write_missing {
            if let Err(e) = std::fs::write(baseline_path, current.to_string()) {
                eprintln!("benchdiff: seed {baseline_path}: {e}");
                return ExitCode::from(2);
            }
            println!("benchdiff: baseline {baseline_path} seeded from {current_path}");
            return ExitCode::SUCCESS;
        }
        eprintln!("benchdiff: baseline {baseline_path} missing (pass --write-missing to seed)");
        return ExitCode::from(2);
    }

    let baseline = match load(baseline_path) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };

    let report = match compare(&baseline, &current) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };
    print!("{}", report.render());
    if !report.ok() {
        return ExitCode::FAILURE;
    }
    if refresh {
        if let Err(e) = std::fs::write(baseline_path, current.to_string()) {
            eprintln!("benchdiff: refresh {baseline_path}: {e}");
            return ExitCode::from(2);
        }
        println!("benchdiff: baseline {baseline_path} refreshed");
    }
    ExitCode::SUCCESS
}

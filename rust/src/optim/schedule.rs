//! Learning-rate schedules from the paper's recipes (§4.1–§4.2).


/// A learning-rate schedule evaluated per epoch (fractional epochs allowed
/// so warmup can be per-step).
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// Constant LR.
    Constant { lr: f32 },
    /// Linear warmup from `warmup_from` to `peak` over `warmup_epochs`,
    /// then ×`decay_factor` at each epoch in `decay_at` (ResNet18 recipe:
    /// warmup 0.1→1.6 over 5 epochs, ×0.1 at 40 and 80).
    WarmupStep {
        warmup_from: f32,
        peak: f32,
        warmup_epochs: f32,
        decay_at: Vec<f32>,
        decay_factor: f32,
    },
    /// Linear ramp 0→`peak` over `up_epochs`, hold, then linear down to 0
    /// over the final `down_epochs` of `total_epochs` (DavidNet recipe:
    /// up 5 epochs to 0.4, down over the last 20).
    Triangular {
        peak: f32,
        up_epochs: f32,
        down_epochs: f32,
        total_epochs: f32,
    },
}

impl LrSchedule {
    /// Paper's ResNet18/CIFAR recipe.
    pub fn resnet18_recipe() -> Self {
        LrSchedule::WarmupStep {
            warmup_from: 0.1,
            peak: 1.6,
            warmup_epochs: 5.0,
            decay_at: vec![40.0, 80.0],
            decay_factor: 0.1,
        }
    }

    /// Paper's DavidNet/CIFAR recipe (§4.1): 0→0.4 over 5 epochs, then
    /// linearly to zero over the last 20 of 30 epochs.
    pub fn davidnet_recipe(total_epochs: f32) -> Self {
        LrSchedule::Triangular {
            peak: 0.4,
            up_epochs: 5.0,
            down_epochs: 20.0_f32.min(total_epochs - 5.0),
            total_epochs,
        }
    }

    /// LR at a (fractional) epoch.
    pub fn at(&self, epoch: f32) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::WarmupStep { warmup_from, peak, warmup_epochs, decay_at, decay_factor } => {
                if epoch < *warmup_epochs && *warmup_epochs > 0.0 {
                    warmup_from + (peak - warmup_from) * (epoch / warmup_epochs)
                } else {
                    let decays = decay_at.iter().filter(|&&e| epoch >= e).count() as i32;
                    peak * decay_factor.powi(decays)
                }
            }
            LrSchedule::Triangular { peak, up_epochs, down_epochs, total_epochs } => {
                if epoch < *up_epochs && *up_epochs > 0.0 {
                    peak * (epoch / up_epochs)
                } else {
                    let down_start = total_epochs - down_epochs;
                    if epoch >= down_start && *down_epochs > 0.0 {
                        (peak * (1.0 - (epoch - down_start) / down_epochs)).max(0.0)
                    } else {
                        *peak
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_step_shape() {
        let s = LrSchedule::resnet18_recipe();
        assert!((s.at(0.0) - 0.1).abs() < 1e-6);
        assert!((s.at(2.5) - 0.85).abs() < 1e-6); // halfway up
        assert!((s.at(5.0) - 1.6).abs() < 1e-6);
        assert!((s.at(39.9) - 1.6).abs() < 1e-6);
        assert!((s.at(40.0) - 0.16).abs() < 1e-6);
        assert!((s.at(80.0) - 0.016).abs() < 1e-6);
    }

    #[test]
    fn triangular_shape() {
        let s = LrSchedule::davidnet_recipe(30.0);
        assert_eq!(s.at(0.0), 0.0);
        assert!((s.at(5.0) - 0.4).abs() < 1e-6);
        assert!((s.at(10.0) - 0.4).abs() < 1e-6); // plateau
        assert!((s.at(20.0) - 0.2).abs() < 1e-6); // halfway down
        assert!(s.at(30.0).abs() < 1e-6);
        assert!(s.at(31.0) >= 0.0); // never negative
    }

    #[test]
    fn constant() {
        let s = LrSchedule::Constant { lr: 0.01 };
        assert_eq!(s.at(0.0), 0.01);
        assert_eq!(s.at(100.0), 0.01);
    }
}

//! Optimizers and LR schedules used by the paper's experiments (§4.1).
//!
//! * [`Sgd`] — momentum SGD (ResNet18 recipe: m=0.9, wd=1e-4) and
//!   Nesterov momentum (DavidNet recipe: m=0.9, wd=2.56e-1).
//! * [`Lars`] — layer-wise adaptive rate scaling (You et al. [30]),
//!   the §4.1 LARS study (Table 5, Fig 9).
//! * [`schedule`] — warmup + step decay (ResNet18), linear up/down
//!   (DavidNet), and the ImageNet 90-epoch recipe (ResNet50).

pub mod schedule;

pub use schedule::LrSchedule;


/// One model parameter tensor with its optimizer state.
#[derive(Clone, Debug)]
pub struct ParamState {
    /// Momentum buffer, same length as the parameter.
    pub momentum: Vec<f32>,
}

/// Optimizer configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptimizerKind {
    /// Momentum SGD: `v = m·v + g + wd·w ; w -= lr·v`.
    Sgd { momentum: f32, weight_decay: f32, nesterov: bool },
    /// LARS: layer-wise trust ratio `η·‖w‖/(‖g‖ + wd·‖w‖)` scales the
    /// local LR before the momentum update (You et al. [30]).
    Lars { momentum: f32, weight_decay: f32, eta: f32, epsilon: f32 },
}

impl OptimizerKind {
    /// Paper's ResNet18/CIFAR recipe (§4.1).
    pub fn resnet18_recipe() -> Self {
        OptimizerKind::Sgd { momentum: 0.9, weight_decay: 1e-4, nesterov: false }
    }
    /// Paper's DavidNet/CIFAR recipe (§4.1): Nesterov, wd γ=0.256.
    pub fn davidnet_recipe() -> Self {
        OptimizerKind::Sgd { momentum: 0.9, weight_decay: 0.256, nesterov: true }
    }
    /// LARS recipe for the Table 5 study.
    pub fn lars_recipe() -> Self {
        OptimizerKind::Lars { momentum: 0.9, weight_decay: 1e-4, eta: 0.001, epsilon: 1e-9 }
    }
}

/// A full optimizer over a list of parameter tensors.
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    states: Vec<ParamState>,
}

impl Optimizer {
    /// Create state for parameters with the given lengths.
    pub fn new(kind: OptimizerKind, param_lens: &[usize]) -> Self {
        let states = param_lens
            .iter()
            .map(|&n| ParamState { momentum: vec![0.0; n] })
            .collect();
        Optimizer { kind, states }
    }

    /// Apply one update step in place. `params[l]` and `grads[l]` are the
    /// layer-`l` tensors; `lr` comes from the schedule.
    pub fn step(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.states.len());
        match self.kind {
            OptimizerKind::Sgd { momentum, weight_decay, nesterov } => {
                for ((w, g), st) in params.iter_mut().zip(grads).zip(&mut self.states) {
                    sgd_update(w, g, &mut st.momentum, lr, momentum, weight_decay, nesterov);
                }
            }
            OptimizerKind::Lars { momentum, weight_decay, eta, epsilon } => {
                for ((w, g), st) in params.iter_mut().zip(grads).zip(&mut self.states) {
                    lars_update(w, g, &mut st.momentum, lr, momentum, weight_decay, eta, epsilon);
                }
            }
        }
    }

    /// The LARS trust ratio for one layer (exposed for the Table 5 study:
    /// LARS's sensitivity to low-precision gradients acts through this).
    pub fn lars_trust_ratio(w: &[f32], g: &[f32], weight_decay: f32, eta: f32, eps: f32) -> f32 {
        let wn = l2_norm(w);
        let gn = l2_norm(g);
        if wn == 0.0 || gn == 0.0 {
            1.0
        } else {
            eta * wn / (gn + weight_decay * wn + eps)
        }
    }
}

fn sgd_update(
    w: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    lr: f32,
    m: f32,
    wd: f32,
    nesterov: bool,
) {
    for i in 0..w.len() {
        let grad = g[i] + wd * w[i];
        v[i] = m * v[i] + grad;
        let upd = if nesterov { grad + m * v[i] } else { v[i] };
        w[i] -= lr * upd;
    }
}

#[allow(clippy::too_many_arguments)]
fn lars_update(
    w: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    lr: f32,
    m: f32,
    wd: f32,
    eta: f32,
    eps: f32,
) {
    let trust = Optimizer::lars_trust_ratio(w, g, wd, eta, eps);
    let local_lr = lr * trust;
    for i in 0..w.len() {
        let grad = g[i] + wd * w[i];
        v[i] = m * v[i] + local_lr * grad;
        w[i] -= v[i];
    }
}

/// Euclidean norm with f64 accumulation.
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_plain_descends_quadratic() {
        // f(w) = 0.5 w², grad = w; GD with momentum must converge to 0.
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.9, weight_decay: 0.0, nesterov: false },
            &[1],
        );
        let mut w = vec![vec![10.0f32]];
        for _ in 0..200 {
            let g = vec![vec![w[0][0]]];
            opt.step(&mut w, &g, 0.05);
        }
        assert!(w[0][0].abs() < 1e-3, "w={}", w[0][0]);
    }

    #[test]
    fn nesterov_differs_from_heavy_ball() {
        let mk = |nesterov| {
            let mut opt = Optimizer::new(
                OptimizerKind::Sgd { momentum: 0.9, weight_decay: 0.0, nesterov },
                &[1],
            );
            let mut w = vec![vec![1.0f32]];
            for _ in 0..3 {
                let g = vec![vec![w[0][0]]];
                opt.step(&mut w, &g, 0.1);
            }
            w[0][0]
        };
        assert_ne!(mk(true), mk(false));
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut opt = Optimizer::new(
            OptimizerKind::Sgd { momentum: 0.0, weight_decay: 0.1, nesterov: false },
            &[2],
        );
        let mut w = vec![vec![1.0f32, -1.0]];
        let g = vec![vec![0.0f32, 0.0]];
        opt.step(&mut w, &g, 1.0);
        assert!(w[0][0] < 1.0 && w[0][1] > -1.0);
    }

    #[test]
    fn lars_trust_ratio_scaling() {
        // Gradient 10× larger norm → trust ratio 10× smaller (approx).
        let w = vec![1.0f32; 100];
        let g1 = vec![0.1f32; 100];
        let g2 = vec![1.0f32; 100];
        let t1 = Optimizer::lars_trust_ratio(&w, &g1, 0.0, 0.001, 0.0);
        let t2 = Optimizer::lars_trust_ratio(&w, &g2, 0.0, 0.001, 0.0);
        assert!((t1 / t2 - 10.0).abs() < 1e-3);
    }

    #[test]
    fn lars_converges_quadratic() {
        let mut opt = Optimizer::new(OptimizerKind::lars_recipe(), &[4]);
        let mut w = vec![vec![5.0f32, -3.0, 2.0, 1.0]];
        for _ in 0..3000 {
            let g = vec![w[0].clone()];
            opt.step(&mut w, &g, 10.0);
        }
        assert!(l2_norm(&w[0]) < 0.5, "‖w‖={}", l2_norm(&w[0]));
    }

    #[test]
    fn zero_grad_zero_norm_guard() {
        let t = Optimizer::lars_trust_ratio(&[0.0], &[0.0], 0.1, 0.001, 1e-9);
        assert_eq!(t, 1.0);
    }
}

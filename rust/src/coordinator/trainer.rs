//! The distributed training loop (paper §4 experiments' engine).

use super::Workload;
use crate::aps::{self, HybridSchedule, SyncOptions};
use crate::collectives::Topology;
use crate::cpd::avg_roundoff_error;
use crate::data::shard_range;
use crate::metrics::{top1_accuracy, SegmentationMetrics, Series};
use crate::optim::{LrSchedule, Optimizer, OptimizerKind};
use crate::runtime::Model;
use crate::sync::{StrategySpec, SyncSession, SyncSessionBuilder, TransportSpec, WireMode};
use crate::Result;
use anyhow::{anyhow, ensure};
use std::time::Instant;

/// Everything needed to construct a [`Trainer`] besides the model.
#[derive(Clone, Debug)]
pub struct TrainerSetup {
    pub world_size: usize,
    pub sync: SyncOptions,
    /// Strategy override: when set, it supersedes `sync.method` (this is
    /// how codecs outside the closed `SyncMethod` enum — ternary, top-k,
    /// or anything user-built — reach the trainer).
    pub strategy: Option<StrategySpec>,
    /// Optional hybrid-precision schedule (FP32 for the first
    /// `fp32_epochs`, the configured strategy afterwards).
    pub hybrid: Option<HybridSchedule>,
    /// How the session materializes wire traffic (packed bit-buffers by
    /// default; results are bit-identical either way).
    pub wire: WireMode,
    /// Transport for the overlapped sync path. Anything other than the
    /// default `InProcess` (or a non-zero `bucket_bytes`) routes every
    /// step through `SyncSession::step_overlapped` in backprop order —
    /// results stay bit-identical to the synchronous path.
    pub transport: TransportSpec,
    /// Bucket fusion threshold (honest wire bytes) for the overlapped
    /// path; 0 picks an automatic size.
    pub bucket_bytes: usize,
    /// Consumer-side (packed fold) thread budget; 0 auto-sizes per layer.
    pub fold_threads: usize,
    /// Producer-side (encode fan-out) thread budget; 0 auto-sizes per
    /// layer, 1 keeps the serial encode loop.
    pub encode_threads: usize,
    pub optimizer: OptimizerKind,
    pub schedule: LrSchedule,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    /// Examples per epoch-end eval pass.
    pub eval_examples: usize,
    /// Track Eq.-5 round-off against an exact (f64) reduction each step.
    pub track_roundoff: bool,
    pub seed: u64,
    /// Print a progress line every n steps (0 = silent).
    pub log_every: usize,
}

impl TrainerSetup {
    pub fn new(world_size: usize, sync: SyncOptions) -> Self {
        TrainerSetup {
            world_size,
            sync,
            strategy: None,
            hybrid: None,
            wire: WireMode::default(),
            transport: TransportSpec::default(),
            bucket_bytes: 0,
            fold_threads: 0,
            encode_threads: 0,
            optimizer: OptimizerKind::Sgd { momentum: 0.9, weight_decay: 1e-4, nesterov: false },
            schedule: LrSchedule::Constant { lr: 0.05 },
            epochs: 2,
            steps_per_epoch: 20,
            eval_examples: 256,
            track_roundoff: false,
            seed: 42,
            log_every: 0,
        }
    }
}

/// Everything a training run reports (feeds the tables in EXPERIMENTS.md).
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    pub name: String,
    /// Per-step mean worker loss.
    pub loss: Series,
    /// Per-epoch eval metric (accuracy / mIoU / eval loss).
    pub eval: Series,
    /// Final eval metric.
    pub final_metric: f64,
    /// Segmentation only: final mean per-class accuracy.
    pub final_macc: Option<f64>,
    /// Gradient payload bytes per worker, whole run (dense simulation
    /// accounting).
    pub comm_payload_bytes: u64,
    /// APS exponent-phase bytes per worker, whole run.
    pub comm_exponent_bytes: u64,
    /// The codec's honest packed wire bytes per worker, whole run
    /// (value + index bits and metadata via `sync::WireCost`) — for
    /// sparse/quantized codecs this is the number to quote.
    pub comm_honest_bytes: u64,
    /// Per-step Eq.-5 round-off of the synchronized gradient (if tracked).
    pub roundoff: Series,
    /// Per-step weighted underflow fraction on the wire.
    pub underflow: Series,
    /// Training hit a non-finite loss at some step.
    pub diverged: bool,
    pub steps_run: usize,
    pub wall_secs: f64,
}

impl TrainOutcome {
    /// Mean Eq.-5 round-off over the run.
    pub fn mean_roundoff(&self) -> f64 {
        if self.roundoff.points.is_empty() {
            f64::NAN
        } else {
            self.roundoff.points.iter().map(|p| p.1).sum::<f64>()
                / self.roundoff.points.len() as f64
        }
    }
}

/// The data-parallel trainer.
pub struct Trainer<'m> {
    model: &'m Model,
    setup: TrainerSetup,
    workload: Workload,
    /// The long-lived synchronization pipeline (strategy + collective +
    /// reusable wire buffers).
    session: SyncSession,
    /// The strategy in effect outside the hybrid schedule's FP32 phase.
    low_spec: StrategySpec,
    /// What the session currently runs (tracks hybrid epoch switches).
    current_spec: StrategySpec,
    pub params: Vec<Vec<f32>>,
    optimizer: Optimizer,
}

impl<'m> Trainer<'m> {
    pub fn new(model: &'m Model, setup: TrainerSetup) -> Result<Self> {
        let workload = Workload::for_spec(&model.spec, setup.seed)?;
        ensure!(
            model.spec.eval_output == workload.expected_eval_output(),
            "artifact eval output does not match workload"
        );
        let params = model.initial_params()?;
        let optimizer = Optimizer::new(setup.optimizer, &model.spec.param_lens());
        // The strategy override wins; otherwise the hybrid schedule's low
        // method, otherwise the plain sync method (legacy semantics).
        let low_spec = setup.strategy.clone().unwrap_or_else(|| match &setup.hybrid {
            Some(h) => StrategySpec::from(h.low),
            None => StrategySpec::from(setup.sync.method),
        });
        // The hybrid warm-epoch rule lives in step() alone; it swaps the
        // strategy before the first sync if epoch 0 is an FP32 epoch.
        let current_spec = low_spec.clone();
        let session = SyncSessionBuilder::from_sync_options(setup.world_size, &setup.sync)
            .spec(current_spec.clone())
            .with_wire(setup.wire)
            .with_transport(setup.transport)
            .with_bucket_bytes(setup.bucket_bytes)
            .with_fold_threads(setup.fold_threads)
            .with_encode_threads(setup.encode_threads)
            .build();
        Ok(Trainer { model, setup, workload, session, low_spec, current_spec, params, optimizer })
    }

    pub fn spec(&self) -> &crate::runtime::ModelSpec {
        &self.model.spec
    }

    /// Global batch = per-artifact batch × world size.
    pub fn global_batch(&self) -> usize {
        self.model.spec.batch * self.setup.world_size
    }

    /// Compute every worker's `(loss, grads)` for global step `step`.
    /// Worker `w` reads examples
    /// `step·global_batch + shard(w)` from the infinite dataset.
    pub fn worker_grads(&self, step: usize) -> Result<(f32, Vec<Vec<Vec<f32>>>)> {
        let world = self.setup.world_size;
        let local = self.model.spec.batch;
        let global = self.global_batch();
        // Convert the (shared) parameters to PJRT literals once per step,
        // not once per worker — see EXPERIMENTS.md §Perf.
        let prepared = self.model.prepare_params(&self.params)?;

        // Fast path: one vmapped dispatch for every worker's fwd+bwd.
        if self.model.has_multi_train(world) {
            let (mut xs_f32, mut xs_i32, mut ys) = (Vec::new(), Vec::new(), Vec::new());
            for w in 0..world {
                let start = (step * global + shard_range(global, world, w).start) as u64;
                match &self.workload {
                    Workload::Classification(g) => {
                        let b = g.batch(start, local);
                        xs_f32.extend_from_slice(&b.images);
                        ys.extend(b.labels.iter().map(|&l| l as i32));
                    }
                    Workload::Segmentation(g) => {
                        let b = g.batch(start, local);
                        xs_f32.extend_from_slice(&b.images);
                        ys.extend(b.masks.iter().map(|&l| l as i32));
                    }
                    Workload::Lm(g) => {
                        let b = g.batch(start, local);
                        xs_i32.extend(b.tokens.iter().map(|&t| t as i32));
                        ys.extend(b.targets.iter().map(|&t| t as i32));
                    }
                }
            }
            let xf = (!xs_f32.is_empty()).then_some(xs_f32.as_slice());
            let xi = (!xs_i32.is_empty()).then_some(xs_i32.as_slice());
            return self.model.train_step_multi(&prepared, world, xf, xi, &ys);
        }

        let mut all = Vec::with_capacity(world);
        let mut loss_sum = 0.0f64;
        for w in 0..world {
            let start = (step * global + shard_range(global, world, w).start) as u64;
            let (loss, grads) = match &self.workload {
                Workload::Classification(g) => {
                    let b = g.batch(start, local);
                    let y: Vec<i32> = b.labels.iter().map(|&l| l as i32).collect();
                    self.model.train_step_prepared(&prepared, Some(&b.images), None, &y)?
                }
                Workload::Segmentation(g) => {
                    let b = g.batch(start, local);
                    let y: Vec<i32> = b.masks.iter().map(|&l| l as i32).collect();
                    self.model.train_step_prepared(&prepared, Some(&b.images), None, &y)?
                }
                Workload::Lm(g) => {
                    let b = g.batch(start, local);
                    let x: Vec<i32> = b.tokens.iter().map(|&t| t as i32).collect();
                    let y: Vec<i32> = b.targets.iter().map(|&t| t as i32).collect();
                    self.model.train_step_prepared(&prepared, None, Some(&x), &y)?
                }
            };
            loss_sum += loss as f64;
            all.push(grads);
        }
        // apslint: allow(lossy_cast) -- mean loss is a diagnostic; f32 matches the per-step loss the model already reports
        Ok(((loss_sum / world as f64) as f32, all))
    }

    /// One full training step: grads → sync → optimizer. Returns the mean
    /// worker loss. `epoch` selects the hybrid-precision strategy.
    pub fn step(&mut self, epoch: usize, step: usize, out: &mut TrainOutcome) -> Result<f32> {
        let (loss, worker_grads) = self.worker_grads(step)?;

        // Hybrid schedule: FP32 strategy for the warm epochs, the
        // configured strategy afterwards; swapping keeps all buffers.
        // Compare by reference — cloning the spec (a Box for ef:* codecs)
        // belongs only in the rare epoch-switch branch, not every step.
        let fp32 = StrategySpec::Fp32;
        let desired = match &self.setup.hybrid {
            Some(h) if epoch < h.fp32_epochs => &fp32,
            _ => &self.low_spec,
        };
        if desired != &self.current_spec {
            let desired = desired.clone();
            self.session.set_strategy(desired.build());
            self.current_spec = desired;
        }
        let overlapped = self.setup.transport != TransportSpec::InProcess
            || self.setup.bucket_bytes != 0;
        let (reduced, report) = if matches!(self.setup.sync.topo, Topology::Ps { .. }) {
            // The parameter server owns its transport and can fault
            // mid-step (straggler past patience, dead peer); the checked
            // path rolls the step back cleanly and surfaces the
            // TransportError instead of applying a partial fold.
            self.session
                .step_checked(&worker_grads)
                .map_err(|e| anyhow!("gradient sync failed: {e}"))?
        } else if overlapped {
            // Backprop completion order: the last layer's gradient is
            // ready first, so its bucket ships while earlier layers are
            // still "computing". (After a hybrid strategy swap the
            // session falls back to the synchronous path internally;
            // results are bit-identical either way.)
            let layers = worker_grads.first().map_or(0, |g| g.len());
            let order: Vec<usize> = (0..layers).rev().collect();
            self.session
                .step_overlapped(&worker_grads, &order)
                .map_err(|e| anyhow!("gradient sync failed: {e}"))?
        } else {
            self.session.step(&worker_grads)
        };

        if self.setup.track_roundoff {
            let exact = aps::reduce_exact(&worker_grads, self.setup.sync.average);
            let mut err_sum = 0.0;
            let mut elems = 0usize;
            for (e, r) in exact.iter().zip(reduced) {
                err_sum += avg_roundoff_error(e, r) * e.len() as f64;
                elems += e.len();
            }
            out.roundoff.push(step as f64, err_sum / elems.max(1) as f64);
        }
        out.underflow.push(step as f64, report.underflow_frac());
        out.comm_payload_bytes += report.payload_bytes;
        out.comm_exponent_bytes += report.exponent_bytes;
        out.comm_honest_bytes += report.wire.total_bytes();

        // Global step → fractional epoch for the LR schedule.
        let epoch_f = step as f32 / self.setup.steps_per_epoch.max(1) as f32;
        let lr = self.setup.schedule.at(epoch_f);
        self.optimizer.step(&mut self.params, reduced, lr);

        if !loss.is_finite() {
            out.diverged = true;
        }
        Ok(loss)
    }

    /// Epoch-end evaluation on the held-out deterministic eval set.
    pub fn evaluate(&self) -> Result<(f64, Option<f64>)> {
        let local = self.model.spec.batch;
        let chunks = (self.setup.eval_examples / local).max(1);
        match &self.workload {
            Workload::Classification(g) => {
                let mut correct_weighted = 0.0;
                for c in 0..chunks {
                    let b = g.batch((1 << 40) + (c * local) as u64, local);
                    let logits =
                        self.model.eval_step(&self.params, Some(&b.images), None, None)?;
                    correct_weighted +=
                        top1_accuracy(&logits, &b.labels, self.model.spec.num_classes);
                }
                Ok((correct_weighted / chunks as f64, None))
            }
            Workload::Segmentation(g) => {
                let mut m = SegmentationMetrics::new(self.model.spec.num_classes);
                for c in 0..chunks {
                    let b = g.batch((1 << 40) + (c * local) as u64, local);
                    let logits =
                        self.model.eval_step(&self.params, Some(&b.images), None, None)?;
                    m.update_from_logits(&logits, &b.masks);
                }
                Ok((m.miou(), Some(m.macc())))
            }
            Workload::Lm(g) => {
                let mut loss_sum = 0.0;
                for c in 0..chunks {
                    let b = g.batch((1 << 40) + (c * local) as u64, local);
                    let x: Vec<i32> = b.tokens.iter().map(|&t| t as i32).collect();
                    let y: Vec<i32> = b.targets.iter().map(|&t| t as i32).collect();
                    let out = self.model.eval_step(&self.params, None, Some(&x), Some(&y))?;
                    loss_sum += out[0] as f64;
                }
                Ok((loss_sum / chunks as f64, None))
            }
        }
    }

    /// Run the full schedule and return the outcome.
    pub fn train(&mut self, name: impl Into<String>) -> Result<TrainOutcome> {
        let mut out = TrainOutcome { name: name.into(), ..Default::default() };
        let t0 = Instant::now();
        let mut global_step = 0usize;
        for epoch in 0..self.setup.epochs {
            for _ in 0..self.setup.steps_per_epoch {
                let loss = self.step(epoch, global_step, &mut out)?;
                out.loss.push(global_step as f64, loss as f64);
                if self.setup.log_every > 0 && global_step % self.setup.log_every == 0 {
                    eprintln!(
                        "[{}] epoch {epoch} step {global_step} loss {loss:.4}",
                        out.name
                    );
                }
                global_step += 1;
            }
            let (metric, macc) = self.evaluate()?;
            out.eval.push(epoch as f64, metric);
            out.final_macc = macc;
            if self.setup.log_every > 0 {
                eprintln!(
                    "[{}] epoch {epoch} {} = {metric:.4}",
                    out.name,
                    self.workload.metric_name()
                );
            }
        }
        out.final_metric = out.eval.last().unwrap_or(f64::NAN);
        out.steps_run = global_step;
        out.wall_secs = t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Collect per-layer gradients at the current parameters (worker 0) —
    /// the raw material of the Fig 1/2 distribution plots.
    pub fn snapshot_gradients(&self, step: usize) -> Result<Vec<Vec<f32>>> {
        let (_, mut all) = self.worker_grads(step)?;
        Ok(all.swap_remove(0))
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }
}

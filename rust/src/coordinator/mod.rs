//! The L3 coordinator: data-parallel training over the simulated cluster.
//!
//! [`Trainer`] owns the whole loop the paper's system runs:
//!
//! 1. shard the global batch across the simulated workers ([`crate::data`]);
//! 2. run each worker's forward+backward through the AOT-compiled HLO
//!    ([`crate::runtime`] — real gradients, no Python);
//! 3. synchronize gradients with APS / loss scaling / naive / FP32 over
//!    ring or hierarchical all-reduce ([`crate::aps`], [`crate::collectives`]);
//! 4. apply the optimizer ([`crate::optim`]) and record metrics.
//!
//! [`Workload`] adapts the loop to the three task families (classification,
//! segmentation, language modeling); [`TrainOutcome`] is what every bench
//! and example reports into EXPERIMENTS.md.

pub mod trainer;

pub use trainer::{TrainOutcome, Trainer, TrainerSetup};

use crate::data::{corpus::SyntheticCorpus, segmentation::SyntheticSegmentation, synthetic::SyntheticImages};
use crate::runtime::{EvalOutput, ModelSpec, XDtype};
use crate::Result;
use anyhow::anyhow;

/// Task family + its data generator, derived from the model spec.
#[derive(Clone, Debug)]
pub enum Workload {
    Classification(SyntheticImages),
    Segmentation(SyntheticSegmentation),
    Lm(SyntheticCorpus),
}

impl Workload {
    /// Choose the generator matching the artifact's input/output shapes.
    pub fn for_spec(spec: &ModelSpec, seed: u64) -> Result<Workload> {
        match (spec.x_dtype, spec.y_shape.len()) {
            (XDtype::I32, _) => {
                let s = *spec
                    .x_shape
                    .first()
                    .ok_or_else(|| anyhow!("LM spec needs [seq_len] x_shape"))?;
                Ok(Workload::Lm(SyntheticCorpus::new(spec.num_classes, s, seed)))
            }
            (XDtype::F32, 0) => {
                let [h, w, c] = spec.x_shape[..] else {
                    return Err(anyhow!("classifier x_shape must be [h, w, c]"));
                };
                let mut g = SyntheticImages::cifar_like(seed);
                g.height = h;
                g.width = w;
                g.channels = c;
                g.num_classes = spec.num_classes;
                Ok(Workload::Classification(g))
            }
            (XDtype::F32, 2) => {
                let [h, w, c] = spec.x_shape[..] else {
                    return Err(anyhow!("segmenter x_shape must be [h, w, c]"));
                };
                let mut g = SyntheticSegmentation::new(seed);
                g.height = h;
                g.width = w;
                g.channels = c;
                g.num_classes = spec.num_classes;
                Ok(Workload::Segmentation(g))
            }
            other => Err(anyhow!("cannot infer workload from spec: {other:?}")),
        }
    }

    /// Human name of the epoch-end eval metric.
    pub fn metric_name(&self) -> &'static str {
        match self {
            Workload::Classification(_) => "top1_accuracy",
            Workload::Segmentation(_) => "mIoU",
            Workload::Lm(_) => "eval_loss",
        }
    }

    /// Whether larger metric values are better (false for LM loss).
    pub fn higher_is_better(&self) -> bool {
        !matches!(self, Workload::Lm(_))
    }

    pub fn expected_eval_output(&self) -> EvalOutput {
        match self {
            Workload::Lm(_) => EvalOutput::Loss,
            _ => EvalOutput::Logits,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn spec(x_dtype: XDtype, x_shape: Vec<usize>, y_shape: Vec<usize>) -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            params: vec![ParamSpec { name: "w".into(), shape: vec![4] }],
            batch: 2,
            x_shape,
            x_dtype,
            y_shape,
            num_classes: 10,
            eval_output: EvalOutput::Logits,
            train_artifact: "x".into(),
            eval_artifact: "y".into(),
            init_seed: 0,
            multi_train: Default::default(),
        }
    }

    #[test]
    fn workload_inference() {
        let c = Workload::for_spec(&spec(XDtype::F32, vec![8, 8, 3], vec![]), 0).unwrap();
        assert!(matches!(c, Workload::Classification(_)));
        let s = Workload::for_spec(&spec(XDtype::F32, vec![16, 16, 3], vec![16, 16]), 0).unwrap();
        assert!(matches!(s, Workload::Segmentation(_)));
        let l = Workload::for_spec(&spec(XDtype::I32, vec![32], vec![32]), 0).unwrap();
        assert!(matches!(l, Workload::Lm(_)));
        assert_eq!(l.metric_name(), "eval_loss");
        assert!(!l.higher_is_better());
    }

    #[test]
    fn bad_shapes_error() {
        assert!(Workload::for_spec(&spec(XDtype::F32, vec![8, 8], vec![]), 0).is_err());
        assert!(Workload::for_spec(&spec(XDtype::F32, vec![8, 8, 3], vec![1, 2, 3]), 0).is_err());
    }
}

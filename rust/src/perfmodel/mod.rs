//! α–β communication cost model (paper §4.3, Fig 11; §4.2 step counts).
//!
//! Time for one message of `n` bytes over one step: `α + n·β` where `α`
//! is per-message latency and `β` inverse bandwidth. For a ring all-reduce
//! of an `S`-byte tensor across `p` workers:
//!
//! `T_ring = 2(p-1)·α + 2·(p-1)/p·S·β`
//!
//! Hierarchical with group size `k` (gather + ring-across-masters +
//! broadcast; paper §4.2 counts `4(k-1) + 2(p/k-1)` steps):
//!
//! `T_hier = (4(k-1) + 2(p/k-1))·α + (2(k-1) + 2(m-1)/m)·S·β`,  m = p/k
//!
//! APS costs two phases (Fig 11's gray + orange bars): the 1-byte-per-layer
//! exponent max all-reduce, then the low-precision payload all-reduce.
//! Defaults are calibrated to the paper's testbed (32×V100 + NCCL): the
//! measured ~0.26 ms to all-reduce res5c_branch2b (2.3 MB at FP16) gives
//! β ≈ 5 ns/byte effective; α ≈ 12 µs per ring step.

use crate::collectives::Topology;
use crate::cpd::FpFormat;

/// Network parameters of the modeled cluster.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-step latency, seconds.
    pub alpha: f64,
    /// Inverse bandwidth, seconds per byte.
    pub beta: f64,
    /// Cast/scale compute overhead per element, seconds (APS pays this
    /// twice: scale+cast down, cast+unscale up).
    pub cast_per_elem: f64,
    /// Producer-side encode/pack overhead per element, seconds — the
    /// quantize→pack pass that runs before any byte reaches the wire.
    /// Every method pays it once per element (it models the session's
    /// encode phase, `SyncReport::encode_ns`), so it shifts absolute
    /// times without flattering either side of a speedup ratio.
    pub encode_per_elem: f64,
}

impl NetworkModel {
    /// Calibrated to the paper's 32×V100 NCCL measurements (Fig 11): the
    /// fused (lazy) APS row lands at ≈1.33× over FP16 when the cast/scale
    /// kernel costs ~2.3 ns/element — the overhead visible as the gray +
    /// orange split in the paper's bars.
    pub fn v100_nccl() -> Self {
        NetworkModel { alpha: 12e-6, beta: 5e-9, cast_per_elem: 2.3e-9, encode_per_elem: 0.3e-9 }
    }

    /// A slower commodity-ethernet profile (25 GbE-ish) for sweeps.
    pub fn ethernet_25g() -> Self {
        NetworkModel { alpha: 30e-6, beta: 3.2e-10 * 8.0, cast_per_elem: 2e-11, encode_per_elem: 5e-11 }
    }

    /// Producer-side encode/pack time for one worker's `elems` gradient
    /// elements (the α–β model's mirror of the session's measured
    /// `SyncReport::encode_ns`).
    pub fn encode_time(&self, elems: u64) -> f64 {
        // apslint: allow(lossy_cast) -- element counts stay far below 2^53 for any realistic model
        elems as f64 * self.encode_per_elem
    }

    /// Time for one all-reduce of `bytes` across `p` workers.
    pub fn allreduce_time(&self, topo: Topology, p: usize, bytes: u64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        // apslint: allow(lossy_cast) -- wire byte counts stay far below 2^53 for any realistic model
        let s = bytes as f64;
        match topo {
            Topology::Ring => {
                let steps = 2.0 * (p as f64 - 1.0);
                steps * self.alpha + 2.0 * (p as f64 - 1.0) / p as f64 * s * self.beta
            }
            Topology::Hierarchical { group_size: k } => {
                assert!(p % k == 0);
                let m = (p / k) as f64;
                let steps = (4 * (k - 1)) as f64 + 2.0 * (m - 1.0);
                let bw = (2 * (k - 1)) as f64 * s + 2.0 * (m - 1.0) / m * s;
                steps * self.alpha + bw * self.beta
            }
            Topology::Ps { shards, .. } => {
                // Push + pull (two α latencies regardless of p); each of
                // the S server shards ingests p contributions of its s/S
                // slice and fans the result back out, so the serialized
                // bandwidth term scales with p/S — the classic PS
                // incast bottleneck that sharding divides.
                let sh = shards.max(1) as f64;
                2.0 * self.alpha + s * self.beta * (2.0 * p as f64 / sh)
            }
        }
    }
}

/// One layer to synchronize: element count only (shape is irrelevant).
#[derive(Clone, Copy, Debug)]
pub struct LayerSpec {
    pub name: &'static str,
    pub elements: u64,
}

/// The ResNet-50 layers Fig 11 measures.
pub fn fig11_layers() -> Vec<LayerSpec> {
    vec![
        LayerSpec { name: "res5c_branch2a", elements: 2048 * 512 },
        LayerSpec { name: "res5c_branch2b", elements: 512 * 512 * 3 * 3 },
        LayerSpec { name: "res5c_branch2c", elements: 512 * 2048 },
    ]
}

/// Gradient-synchronization methods the model can price (Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CommMethod {
    /// Plain all-reduce at the given wire width (bits per element).
    PlainAllReduce { bits: u32 },
    /// APS: exponent phase (8 bits/layer) + payload at `fmt` width.
    Aps { fmt: FpFormat },
}

/// Predicted time to synchronize a set of layers.
///
/// `fused` concatenates all layers into one message (lazy all-reduce,
/// §4.3): latency is paid once instead of per layer. APS's exponent phase
/// is one tiny message either way (the vector `E` is per-step, not
/// per-layer).
pub fn sync_time(
    net: &NetworkModel,
    topo: Topology,
    p: usize,
    layers: &[LayerSpec],
    method: CommMethod,
    fused: bool,
) -> f64 {
    let total_elems: u64 = layers.iter().map(|l| l.elements).sum();
    // Producer-side encode/pack pass — every method quantizes/lays out
    // its wire image once per element before communicating, so the term
    // is common to both arms (it moves absolute times, never the APS-vs-
    // plain ratio's direction).
    let encode = net.encode_time(total_elems);
    match method {
        CommMethod::PlainAllReduce { bits } => {
            let per_elem = bits as u64 / 8;
            let payload = if fused {
                net.allreduce_time(topo, p, total_elems * per_elem)
            } else {
                layers
                    .iter()
                    .map(|l| net.allreduce_time(topo, p, l.elements * per_elem))
                    .sum()
            };
            encode + payload
        }
        CommMethod::Aps { fmt } => {
            let per_elem = (fmt.total_bits() as u64).div_ceil(8);
            // Phase 1: find-max + all-reduce of one byte per layer.
            let exp_bytes = layers.len() as u64;
            let exp_phase = net.allreduce_time(topo, p, exp_bytes);
            // Cast/scale overhead on every element, down and up.
            // apslint: allow(lossy_cast) -- element counts stay far below 2^53 for any realistic model
            let cast = 2.0 * total_elems as f64 * net.cast_per_elem;
            // Phase 2: payload.
            let payload = if fused {
                net.allreduce_time(topo, p, total_elems * per_elem)
            } else {
                layers
                    .iter()
                    .map(|l| net.allreduce_time(topo, p, l.elements * per_elem))
                    .sum()
            };
            encode + exp_phase + cast + payload
        }
    }
}

/// Fig 11 row: timing breakdown for one configuration.
#[derive(Clone, Debug)]
pub struct Fig11Row {
    pub label: String,
    pub fp16_ms: f64,
    pub aps_exp_phase_ms: f64,
    pub aps_payload_ms: f64,
    pub aps_total_ms: f64,
    pub speedup: f64,
}

/// Reproduce Fig 11: per-layer FP16 vs APS-8bit, plus the fused row.
pub fn fig11_table(net: &NetworkModel, p: usize) -> Vec<Fig11Row> {
    let layers = fig11_layers();
    let topo = Topology::Ring;
    let mut rows = Vec::new();
    for l in &layers {
        let one = vec![*l];
        let fp16 = sync_time(net, topo, p, &one, CommMethod::PlainAllReduce { bits: 16 }, false);
        let exp = net.allreduce_time(topo, p, 1);
        let aps =
            sync_time(net, topo, p, &one, CommMethod::Aps { fmt: FpFormat::E5M2 }, false);
        rows.push(Fig11Row {
            label: l.name.to_string(),
            fp16_ms: fp16 * 1e3,
            aps_exp_phase_ms: exp * 1e3,
            aps_payload_ms: (aps - exp) * 1e3,
            aps_total_ms: aps * 1e3,
            speedup: fp16 / aps,
        });
    }
    // Rightmost bar: three consecutive layers fused (lazy all-reduce).
    let fp16 =
        sync_time(net, topo, p, &layers, CommMethod::PlainAllReduce { bits: 16 }, false);
    let aps_fused = sync_time(net, topo, p, &layers, CommMethod::Aps { fmt: FpFormat::E5M2 }, true);
    let exp = net.allreduce_time(topo, p, layers.len() as u64);
    rows.push(Fig11Row {
        label: "res5c_2a+2b+2c (lazy)".to_string(),
        fp16_ms: fp16 * 1e3,
        aps_exp_phase_ms: exp * 1e3,
        aps_payload_ms: (aps_fused - exp) * 1e3,
        aps_total_ms: aps_fused * 1e3,
        speedup: fp16 / aps_fused,
    });
    rows
}

/// Table 2's communication-cost column for a gradient of `l_elems`
/// elements: returns (bits on the wire per element-sync, description).
pub fn table2_cost(method: &str, l_elems: u64) -> (u64, String) {
    match method {
        "APS" => (
            8 * l_elems + 8, // allreduce(8L bits) + allreduce(8 bits)
            format!("allreduce(8 bits) + allreduce({}L bits = {} bits)", 8, 8 * l_elems),
        ),
        "loss-scaling" => (16 * l_elems, format!("allreduce(L*16 bits = {} bits)", 16 * l_elems)),
        "FP32" => (32 * l_elems, format!("allreduce(L*32 bits = {} bits)", 32 * l_elems)),
        _ => (0, "n/a".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_time_monotone_in_size_and_workers() {
        let net = NetworkModel::v100_nccl();
        let t1 = net.allreduce_time(Topology::Ring, 8, 1 << 20);
        let t2 = net.allreduce_time(Topology::Ring, 8, 1 << 22);
        let t3 = net.allreduce_time(Topology::Ring, 32, 1 << 20);
        assert!(t2 > t1);
        assert!(t3 > t1); // more latency steps
        assert_eq!(net.allreduce_time(Topology::Ring, 1, 1 << 20), 0.0);
    }

    #[test]
    fn hierarchical_beats_ring_on_latency_at_scale() {
        // 256 nodes: 74 steps vs 510 steps (paper §4.2) → for small
        // messages hierarchical wins.
        let net = NetworkModel::v100_nccl();
        let small = 4096u64;
        let r = net.allreduce_time(Topology::Ring, 256, small);
        let h = net.allreduce_time(Topology::Hierarchical { group_size: 16 }, 256, small);
        assert!(h < r, "hier {h} ring {r}");
    }

    #[test]
    fn fig11_aps_beats_fp16() {
        let rows = fig11_table(&NetworkModel::v100_nccl(), 32);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.speedup > 1.0, "{}: {}", r.label, r.speedup);
            assert!(r.aps_total_ms > r.aps_payload_ms);
        }
        // Paper: fused (lazy) achieves ~1.33× over half precision.
        let fused = &rows[3];
        assert!(fused.speedup > 1.2 && fused.speedup < 2.5, "{}", fused.speedup);
        // Fused APS total is cheaper than the sum of unfused APS totals.
        let unfused_sum: f64 = rows[..3].iter().map(|r| r.aps_total_ms).sum();
        assert!(fused.aps_total_ms < unfused_sum);
    }

    #[test]
    fn aps_cost_includes_exponent_phase() {
        let net = NetworkModel::v100_nccl();
        let layers = fig11_layers();
        let aps = sync_time(
            &net,
            Topology::Ring,
            32,
            &layers,
            CommMethod::Aps { fmt: FpFormat::E5M2 },
            false,
        );
        let plain8 = sync_time(
            &net,
            Topology::Ring,
            32,
            &layers,
            CommMethod::PlainAllReduce { bits: 8 },
            false,
        );
        assert!(aps > plain8, "APS pays the exponent phase on top");
        assert!(aps < plain8 * 1.5, "…but it must stay trivial (paper's claim)");
    }

    #[test]
    fn encode_term_is_common_to_both_methods() {
        // The producer-side term is paid once per element by plain and
        // APS alike: subtracting it from both recovers the pure
        // communication times, and its presence cannot flip a speedup.
        let net = NetworkModel::v100_nccl();
        let layers = fig11_layers();
        let total: u64 = layers.iter().map(|l| l.elements).sum();
        let enc = net.encode_time(total);
        assert!(enc > 0.0);
        for fused in [false, true] {
            let plain = sync_time(
                &net,
                Topology::Ring,
                32,
                &layers,
                CommMethod::PlainAllReduce { bits: 16 },
                fused,
            );
            let aps = sync_time(
                &net,
                Topology::Ring,
                32,
                &layers,
                CommMethod::Aps { fmt: FpFormat::E5M2 },
                fused,
            );
            assert!(plain > enc && aps > enc, "fused={fused}");
            // With the common term removed, APS still beats FP16 on the
            // wire — the encode pass shrinks but never reverses Fig 11.
            assert!(aps - enc < plain - enc, "fused={fused}");
        }
        // World 1 communicates nothing but still encodes.
        let solo =
            sync_time(&net, Topology::Ring, 1, &layers, CommMethod::PlainAllReduce { bits: 16 }, true);
        assert_eq!(solo, enc, "world 1 communicates nothing but still encodes");
    }

    #[test]
    fn table2_costs() {
        let (aps_bits, _) = table2_cost("APS", 1000);
        let (ls_bits, _) = table2_cost("loss-scaling", 1000);
        assert_eq!(aps_bits, 8008);
        assert_eq!(ls_bits, 16000);
        assert!(aps_bits < ls_bits);
    }
}

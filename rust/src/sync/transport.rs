//! The byte seam under the collectives: a [`Transport`] ships each
//! worker's [`PackedWire`] contribution as real octets and hands back
//! what arrived, so the packed reduction can run over genuinely moved
//! bytes instead of in-process slices.
//!
//! Three implementations, one contract:
//!
//! * [`InProcess`] — the historical behavior: the caller's slices are
//!   "delivered" zero-copy. No serialization, no octets on any wire
//!   ([`Transport::octets_moved`] stays 0).
//! * [`SharedMem`] — per-worker ring of preallocated byte slabs. Each
//!   exchange serializes every worker's frame into its slab and
//!   deserializes it back out, modeling the memcpy cost (and honest
//!   octet count) of a shared-memory transport.
//! * [`Tcp`] — loopback sockets, one pair per worker, with
//!   connect-with-retry at construction and a pump thread owning the
//!   write ends so large frames cannot deadlock a same-thread
//!   write/read cycle. The octets counted are exactly the serialized
//!   payload+metadata bytes written to the sockets.
//!
//! **Wire honesty across the seam.** The frame format ships the packed
//! payload verbatim: for every built-in codec the payload length equals
//! `WireCost::total_bytes()` of the same buffer (payload bytes are the
//! byte-rounded value+index bits, metadata rides as-is), so the octets a
//! serializing transport measures equal the octets the codec claims.
//! `rust/tests/transport_overlap.rs` pins measured == claimed for every
//! codec on both serializing transports.
//!
//! [`BucketPlan`] lives here too: the Horovod-style fusion of layers
//! (walked in backprop-ready order) into ~N-byte buckets that
//! [`super::SyncSession::step_overlapped`] launches onto its worker
//! pool. The plan is pure bookkeeping — every layer lands in exactly one
//! bucket, bucket order is the caller's ready order, and rebuilding with
//! the same inputs yields the same plan (pinned by the property test in
//! `rust/tests/transport_overlap.rs`).

use super::wire::PackedWire;
use super::{GradView, WireCost};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

/// Serialized frame header: `[tag u8][elems u64][value_bits u64]`
/// `[index_bits u64][payload_len u64][meta_len u64]`, all little-endian.
/// All-u64 lengths so no field can silently truncate on any target.
pub const FRAME_HEADER_LEN: usize = 41;

/// Coarse peer-failure classification. A *slow* peer stalled past the
/// transport's patience budget (`WouldBlock`/`TimedOut` on a read) —
/// the bytes may still arrive, so a parameter server can treat the
/// worker as a straggler rather than lost. A *dead* peer's channel is
/// gone (EOF, reset, closed pump): only escalation is correct.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultKind {
    /// Read timed out; the peer may merely be delayed.
    Slow,
    /// The channel itself failed; the peer will never deliver.
    #[default]
    Dead,
}

impl FaultKind {
    /// Label used in [`TransportError`]'s display form.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::Slow => "slow",
            FaultKind::Dead => "dead",
        }
    }
}

/// Map an I/O error to the peer classification: timeouts are *slow*
/// (retryable by a staleness-tolerant caller), everything else — EOF,
/// reset, refused — is *dead*.
pub fn classify_io(e: &std::io::Error) -> FaultKind {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => FaultKind::Slow,
        _ => FaultKind::Dead,
    }
}

/// A transport-level failure: which transport, which worker's channel,
/// whether the peer looks slow or dead, and what went wrong. Cloneable
/// so the session can both surface it to the caller and keep a copy in
/// its drain bookkeeping.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportError {
    /// [`Transport::name`] of the failing transport.
    pub transport: &'static str,
    /// Worker index whose channel failed (`usize::MAX` when the failure
    /// is not attributable to a single worker, e.g. a dead worker pool).
    pub worker: usize,
    /// Slow (timeout — straggler) vs dead (channel gone) peer.
    pub kind: FaultKind,
    /// Human-readable detail (the underlying I/O error, usually).
    pub detail: String,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} transport: worker {} channel failed ({} peer): {}",
            self.transport,
            self.worker,
            self.kind.as_str(),
            self.detail
        )
    }
}

impl std::error::Error for TransportError {}

/// Byte-oriented exchange of one layer's per-worker packed
/// contributions. `Send` because each overlap pool thread owns its own
/// transport instance outright.
///
/// The contract: `exchange` takes all `world` contributions, moves them
/// (however the implementation defines "move"), and returns the
/// delivered slice — same length, same decoded meaning, and for every
/// built-in codec the same bytes. Accounting accumulates across
/// exchanges until [`Transport::reset_moved`].
pub trait Transport: Send {
    /// Short label for benches, reports and errors.
    fn name(&self) -> &'static str;

    /// Ship every worker's packed contribution and return what arrived.
    /// The delivered slice borrows from `self` (or from `packed` for a
    /// zero-copy transport) and is valid until the next call.
    fn exchange<'a>(
        &'a mut self,
        packed: &'a [PackedWire],
    ) -> Result<&'a [PackedWire], TransportError>;

    /// Accumulated [`WireCost`] of everything delivered since the last
    /// [`Transport::reset_moved`] — the transport-side counterpart of
    /// the encode-side `PackedWire::moved_cost` sum.
    fn moved(&self) -> WireCost;

    /// Real serialized octets (payload + metadata, headers excluded)
    /// put on this transport's wire since the last reset. Zero for
    /// [`InProcess`], which serializes nothing.
    fn octets_moved(&self) -> u64;

    /// Zero the [`Transport::moved`]/[`Transport::octets_moved`] counters.
    fn reset_moved(&mut self);

    /// Simulate a peer failure for `worker` (fault-injection hook; the
    /// next `exchange` touching that worker's channel must fail cleanly).
    /// Default: no-op — only transports with real channels can drop one.
    fn kill_peer(&mut self, _worker: usize) {}

    /// Configure the straggler patience budget: per-poll read timeout
    /// and how many consecutive timed-out polls a read tolerates before
    /// surfacing a [`FaultKind::Slow`] error. Returns `true` when the
    /// transport honors the setting (only transports with real blocking
    /// reads can stall). Default: unsupported no-op.
    fn set_patience(&mut self, _read_timeout: Duration, _max_timeouts: usize) -> bool {
        false
    }

    /// Straggler injection: delay every future send on `worker`'s
    /// channel by `delay` (fault-injection hook for slow-peer tests).
    /// Returns `true` when the transport honors the delay. Default:
    /// unsupported no-op.
    fn inject_send_delay(&mut self, _worker: usize, _delay: Duration) -> bool {
        false
    }
}

/// Which [`Transport`] a session (or config) asks for. The closed-enum
/// companion of the open trait, mirroring `StrategySpec` / `Topology`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// Zero-copy in-process delivery (the historical path).
    #[default]
    InProcess,
    /// Serialize through per-worker shared-memory slabs.
    SharedMem,
    /// Serialize through loopback TCP sockets.
    Tcp,
}

impl TransportSpec {
    /// Parse a config name (`sync.transport`).
    pub fn parse(s: &str) -> Option<TransportSpec> {
        match s {
            "in_process" | "inprocess" => Some(TransportSpec::InProcess),
            "shared_mem" | "shm" => Some(TransportSpec::SharedMem),
            "tcp" => Some(TransportSpec::Tcp),
            _ => None,
        }
    }

    /// The config/bench label (inverse of [`TransportSpec::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            TransportSpec::InProcess => "in_process",
            TransportSpec::SharedMem => "shared_mem",
            TransportSpec::Tcp => "tcp",
        }
    }

    /// Construct the transport for `world` workers. Cold: called once
    /// per overlap pool thread; `Tcp` binds its loopback sockets here.
    pub fn build(self, world: usize) -> Box<dyn Transport> {
        match self {
            TransportSpec::InProcess => Box::new(InProcess::new(world)),
            TransportSpec::SharedMem => Box::new(SharedMem::new(world)),
            TransportSpec::Tcp => {
                Box::new(Tcp::new(world).expect("bind loopback sockets for the Tcp transport"))
            }
        }
    }
}

// ---------------------------------------------------------------------
// Frame serialization
// ---------------------------------------------------------------------

/// Serialize one packed contribution into `out` (cleared first):
/// 41-byte header, then the payload bytes, then the metadata bytes.
/// The payload ships verbatim, so for every built-in codec the body
/// length equals `packed.moved_cost().total_bytes()`.
pub fn serialize_frame_into(packed: &PackedWire, out: &mut Vec<u8>) {
    out.clear();
    out.push(packed.tag());
    out.extend_from_slice(&(packed.elems() as u64).to_le_bytes());
    out.extend_from_slice(&packed.value_bits().to_le_bytes());
    out.extend_from_slice(&packed.index_bits().to_le_bytes());
    out.extend_from_slice(&(packed.bytes().len() as u64).to_le_bytes());
    out.extend_from_slice(&(packed.meta_bytes().len() as u64).to_le_bytes());
    out.extend_from_slice(packed.bytes());
    out.extend_from_slice(packed.meta_bytes());
}

/// Parse one frame from `buf` into `out` (buffer capacity reused).
/// Returns the total frame length consumed, or a static description of
/// the truncation.
pub fn deserialize_frame(buf: &[u8], out: &mut PackedWire) -> Result<usize, &'static str> {
    if buf.len() < FRAME_HEADER_LEN {
        return Err("frame header truncated");
    }
    let (tag, elems, value_bits, index_bits, payload_len, meta_len) = parse_header(buf);
    let total = FRAME_HEADER_LEN + payload_len + meta_len;
    if buf.len() < total {
        return Err("frame body truncated");
    }
    let payload = &buf[FRAME_HEADER_LEN..FRAME_HEADER_LEN + payload_len];
    let meta = &buf[FRAME_HEADER_LEN + payload_len..total];
    out.assign_parts(tag, elems, value_bits, index_bits, payload, meta);
    Ok(total)
}

/// Decode the fixed header fields (caller guarantees
/// `h.len() >= FRAME_HEADER_LEN`).
fn parse_header(h: &[u8]) -> (u8, usize, u64, u64, usize, usize) {
    let tag = h[0];
    let elems = frame_len(read_u64(h, 1));
    let value_bits = read_u64(h, 9);
    let index_bits = read_u64(h, 17);
    let payload_len = frame_len(read_u64(h, 25));
    let meta_len = frame_len(read_u64(h, 33));
    (tag, elems, value_bits, index_bits, payload_len, meta_len)
}

/// Narrow a wire-side u64 length to usize, failing loudly rather than
/// truncating on 32-bit targets.
fn frame_len(v: u64) -> usize {
    usize::try_from(v).expect("frame length exceeds the address space")
}

fn read_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().expect("8-byte header field"))
}

// ---------------------------------------------------------------------
// InProcess
// ---------------------------------------------------------------------

/// Zero-copy delivery: the caller's slices *are* the delivered slices.
/// The accounting still runs (`moved` sums the delivered costs) but no
/// octet ever exists, so [`Transport::octets_moved`] stays 0.
pub struct InProcess {
    world: usize,
    moved: WireCost,
}

impl InProcess {
    pub fn new(world: usize) -> InProcess {
        InProcess { world, moved: WireCost::default() }
    }
}

impl Transport for InProcess {
    fn name(&self) -> &'static str {
        "in_process"
    }
    fn exchange<'a>(
        &'a mut self,
        packed: &'a [PackedWire],
    ) -> Result<&'a [PackedWire], TransportError> {
        assert_eq!(packed.len(), self.world, "one contribution per worker");
        for pw in packed {
            self.moved += pw.moved_cost();
        }
        Ok(packed)
    }
    fn moved(&self) -> WireCost {
        self.moved
    }
    fn octets_moved(&self) -> u64 {
        0
    }
    fn reset_moved(&mut self) {
        self.moved = WireCost::default();
    }
}

// ---------------------------------------------------------------------
// SharedMem
// ---------------------------------------------------------------------

/// Per-worker ring of preallocated byte slabs: every exchange
/// serializes each worker's frame into that worker's current slab,
/// deserializes it back into an owned delivery buffer, and advances the
/// ring cursor — two explicit copies per frame, exactly what a
/// shared-memory transport pays.
pub struct SharedMem {
    world: usize,
    /// Two slabs per worker; `cursor` alternates between them so a
    /// frame is never serialized over the bytes it was just read from.
    slabs: Vec<[Vec<u8>; 2]>,
    cursor: usize,
    delivered: Vec<PackedWire>,
    moved: WireCost,
    octets: u64,
}

impl SharedMem {
    pub fn new(world: usize) -> SharedMem {
        let slabs =
            (0..world).map(|_| [Vec::with_capacity(4096), Vec::with_capacity(4096)]).collect();
        SharedMem {
            world,
            slabs,
            cursor: 0,
            delivered: Vec::new(),
            moved: WireCost::default(),
            octets: 0,
        }
    }
}

impl Transport for SharedMem {
    fn name(&self) -> &'static str {
        "shared_mem"
    }
    fn exchange<'a>(
        &'a mut self,
        packed: &'a [PackedWire],
    ) -> Result<&'a [PackedWire], TransportError> {
        assert_eq!(packed.len(), self.world, "one contribution per worker");
        while self.delivered.len() < self.world {
            self.delivered.push(PackedWire::default());
        }
        for (w, pw) in packed.iter().enumerate() {
            let slab = &mut self.slabs[w][self.cursor];
            serialize_frame_into(pw, slab);
            self.octets += (slab.len() - FRAME_HEADER_LEN) as u64;
        }
        for w in 0..self.world {
            deserialize_frame(&self.slabs[w][self.cursor], &mut self.delivered[w]).map_err(
                |detail| TransportError {
                    transport: "shared_mem",
                    worker: w,
                    kind: FaultKind::Dead,
                    detail: detail.into(),
                },
            )?;
            self.moved += self.delivered[w].moved_cost();
        }
        self.cursor ^= 1;
        Ok(&self.delivered)
    }
    fn moved(&self) -> WireCost {
        self.moved
    }
    fn octets_moved(&self) -> u64 {
        self.octets
    }
    fn reset_moved(&mut self) {
        self.moved = WireCost::default();
        self.octets = 0;
    }
}

// ---------------------------------------------------------------------
// Tcp
// ---------------------------------------------------------------------

/// Loopback TCP: one socket pair per worker. Frames are written by a
/// pump thread that owns the client ends (so a large frame can never
/// deadlock a same-thread write/read cycle) and read back here with
/// `read_exact`. [`Transport::kill_peer`] shuts down a retained clone
/// of the worker's client socket: the server side sees EOF and the next
/// exchange fails cleanly with that worker's index.
pub struct Tcp {
    world: usize,
    servers: Vec<TcpStream>,
    /// `try_clone`d client write ends, kept only for fault injection.
    kill_handles: Vec<TcpStream>,
    pump_tx: mpsc::Sender<(usize, Duration, Vec<u8>)>,
    recycle_rx: mpsc::Receiver<Vec<u8>>,
    delivered: Vec<PackedWire>,
    recv_buf: Vec<u8>,
    moved: WireCost,
    octets: u64,
    /// Consecutive timed-out polls a read tolerates before a
    /// [`FaultKind::Slow`] error (0 = the first timeout aborts).
    patience: usize,
    /// Per-worker injected send delays (straggler fault injection).
    delays: Vec<Duration>,
}

impl Tcp {
    /// Bind a loopback listener and establish `world` socket pairs,
    /// retrying connects briefly (cold: once per pool thread).
    pub fn new(world: usize) -> std::io::Result<Tcp> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let mut clients = Vec::with_capacity(world);
        let mut servers = Vec::with_capacity(world);
        for _ in 0..world {
            let client = connect_with_retry(addr)?;
            client.set_nodelay(true)?;
            let (server, _) = listener.accept()?;
            server.set_nodelay(true)?;
            // Hang guard: a dropped peer must surface as an error, not
            // a stuck CI job.
            server.set_read_timeout(Some(Duration::from_secs(5)))?;
            clients.push(client);
            servers.push(server);
        }
        let kill_handles =
            clients.iter().map(|c| c.try_clone()).collect::<std::io::Result<Vec<_>>>()?;
        let (pump_tx, pump_rx) = mpsc::channel::<(usize, Duration, Vec<u8>)>();
        let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<u8>>();
        // Seed the frame-buffer pool so steady-state exchanges recycle
        // instead of allocating.
        for _ in 0..world + 2 {
            let _ = recycle_tx.send(Vec::with_capacity(4096));
        }
        std::thread::spawn(move || {
            let mut clients = clients;
            while let Ok((w, delay, buf)) = pump_rx.recv() {
                // Straggler injection: hold the frame before writing.
                if delay > Duration::ZERO {
                    std::thread::sleep(delay);
                }
                // A failed write (killed peer) is detected by the read
                // side as EOF; the pump stays alive for other workers.
                let _ = clients[w].write_all(&buf);
                let _ = recycle_tx.send(buf);
            }
        });
        Ok(Tcp {
            world,
            servers,
            kill_handles,
            pump_tx,
            recycle_rx,
            delivered: Vec::new(),
            recv_buf: Vec::new(),
            moved: WireCost::default(),
            octets: 0,
            patience: 0,
            delays: vec![Duration::ZERO; world],
        })
    }
}

/// `read_exact` with a stall budget: each `WouldBlock`/`TimedOut` poll
/// counts one stall (partial progress resets the count); once
/// `patience` consecutive stalls are exceeded the timeout error
/// surfaces to the caller, which classifies it [`FaultKind::Slow`].
/// Tracks the fill offset across polls, so a read that resumes after a
/// sub-budget stall is byte-exact — no frame bytes are lost or reread.
fn read_exact_patient(
    stream: &mut TcpStream,
    buf: &mut [u8],
    patience: usize,
) -> std::io::Result<()> {
    let mut filled = 0usize;
    let mut stalls = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "peer closed the connection mid-frame",
                ));
            }
            Ok(n) => {
                filled += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if classify_io(&e) == FaultKind::Slow => {
                stalls += 1;
                if stalls > patience {
                    return Err(e);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read one frame off a socket into `out` (scratch reused across calls).
fn read_frame(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
    out: &mut PackedWire,
    patience: usize,
) -> std::io::Result<()> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    read_exact_patient(stream, &mut header, patience)?;
    let (tag, elems, value_bits, index_bits, payload_len, meta_len) = parse_header(&header);
    scratch.clear();
    scratch.resize(payload_len + meta_len, 0);
    read_exact_patient(stream, scratch, patience)?;
    out.assign_parts(
        tag,
        elems,
        value_bits,
        index_bits,
        &scratch[..payload_len],
        &scratch[payload_len..],
    );
    Ok(())
}

/// Default total budget for establishing one loopback connection.
pub const CONNECT_DEADLINE: Duration = Duration::from_secs(5);

/// Loopback connect with a short retry loop (the listener is already
/// bound, but a loaded machine can still transiently refuse).
fn connect_with_retry(addr: SocketAddr) -> std::io::Result<TcpStream> {
    connect_with_deadline(addr, CONNECT_DEADLINE)
}

/// Connect with exponential backoff (1 ms doubling, capped at 250 ms)
/// until `deadline` of wall time has elapsed. The exhaustion error
/// names the address and attempt count so a refused bind is debuggable
/// from the message alone.
fn connect_with_deadline(addr: SocketAddr, deadline: Duration) -> std::io::Result<TcpStream> {
    let start = std::time::Instant::now();
    let mut backoff = Duration::from_millis(1);
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let elapsed = start.elapsed();
                if elapsed >= deadline {
                    return Err(std::io::Error::new(
                        e.kind(),
                        format!(
                            "connect to {addr} failed after {attempts} attempts \
                             over {elapsed:?}: {e}"
                        ),
                    ));
                }
                std::thread::sleep(backoff.min(deadline.saturating_sub(elapsed)));
                backoff = (backoff * 2).min(Duration::from_millis(250));
            }
        }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }
    fn exchange<'a>(
        &'a mut self,
        packed: &'a [PackedWire],
    ) -> Result<&'a [PackedWire], TransportError> {
        assert_eq!(packed.len(), self.world, "one contribution per worker");
        while self.delivered.len() < self.world {
            self.delivered.push(PackedWire::default());
        }
        for (w, pw) in packed.iter().enumerate() {
            let mut buf = match self.recycle_rx.try_recv() {
                Ok(b) => b,
                // apslint: allow(alloc_in_hot_path) -- buffer-pool miss refill only; the pool is seeded at construction and every buffer returns via the pump's recycle channel, so the steady state recycles
                Err(_) => Vec::new(),
            };
            serialize_frame_into(pw, &mut buf);
            self.octets += (buf.len() - FRAME_HEADER_LEN) as u64;
            let delay = self.delays.get(w).copied().unwrap_or_default();
            if self.pump_tx.send((w, delay, buf)).is_err() {
                return Err(TransportError {
                    transport: "tcp",
                    worker: w,
                    kind: FaultKind::Dead,
                    detail: "socket pump thread exited".into(),
                });
            }
        }
        for w in 0..self.world {
            read_frame(
                &mut self.servers[w],
                &mut self.recv_buf,
                &mut self.delivered[w],
                self.patience,
            )
            .map_err(|e| TransportError {
                transport: "tcp",
                worker: w,
                kind: classify_io(&e),
                detail: e.to_string(),
            })?;
            self.moved += self.delivered[w].moved_cost();
        }
        Ok(&self.delivered)
    }
    fn moved(&self) -> WireCost {
        self.moved
    }
    fn octets_moved(&self) -> u64 {
        self.octets
    }
    fn reset_moved(&mut self) {
        self.moved = WireCost::default();
        self.octets = 0;
    }
    fn kill_peer(&mut self, worker: usize) {
        if let Some(h) = self.kill_handles.get(worker) {
            let _ = h.shutdown(std::net::Shutdown::Both);
        }
    }
    fn set_patience(&mut self, read_timeout: Duration, max_timeouts: usize) -> bool {
        for s in &self.servers {
            if s.set_read_timeout(Some(read_timeout)).is_err() {
                return false;
            }
        }
        self.patience = max_timeouts;
        true
    }
    fn inject_send_delay(&mut self, worker: usize, delay: Duration) -> bool {
        match self.delays.get_mut(worker) {
            Some(d) => {
                *d = delay;
                true
            }
            None => false,
        }
    }
}

// ---------------------------------------------------------------------
// BucketPlan
// ---------------------------------------------------------------------

/// Fusion of layers (in the caller's backprop-ready order) into ~N-byte
/// buckets. Flat storage: bucket `b` is
/// `layers[starts[b]..starts[b + 1]]`. Rebuilt in place every step with
/// no steady-state reallocation.
#[derive(Clone, Debug, Default)]
pub struct BucketPlan {
    layers: Vec<usize>,
    starts: Vec<usize>,
    /// Permutation-check scratch, reused across rebuilds.
    seen: Vec<bool>,
}

impl BucketPlan {
    /// Rebuild the plan: walk `ready_order`, accumulate each layer's
    /// dense f32 footprint (`4 * elems` — a codec-independent yardstick,
    /// so the plan does not depend on data-dependent sparse sizes), and
    /// close a bucket once it reaches `bucket_bytes`. Every bucket holds
    /// at least one layer. Panics unless `ready_order` is a permutation
    /// of `0..num_layers`.
    pub fn rebuild(&mut self, view: &GradView, ready_order: &[usize], bucket_bytes: u64) {
        let num_layers = view.num_layers();
        assert_eq!(
            ready_order.len(),
            num_layers,
            "ready_order must list every layer exactly once"
        );
        self.seen.clear();
        self.seen.resize(num_layers, false);
        for &l in ready_order {
            assert!(l < num_layers, "ready_order layer {l} out of range");
            assert!(!self.seen[l], "ready_order lists layer {l} twice");
            self.seen[l] = true;
        }
        self.layers.clear();
        self.starts.clear();
        self.starts.push(0);
        let mut acc = 0u64;
        for &l in ready_order {
            self.layers.push(l);
            acc += view.layer_len(l) as u64 * 4;
            if acc >= bucket_bytes {
                self.starts.push(self.layers.len());
                acc = 0;
            }
        }
        if *self.starts.last().unwrap_or(&0) != self.layers.len() {
            self.starts.push(self.layers.len());
        }
    }

    pub fn num_buckets(&self) -> usize {
        self.starts.len().saturating_sub(1)
    }

    /// The layer indices of bucket `b`, in ready order.
    pub fn bucket(&self, b: usize) -> &[usize] {
        &self.layers[self.starts[b]..self.starts[b + 1]]
    }
}

/// The auto bucket size (`bucket_bytes == 0`): half the model spread
/// over the pool, floored at 16 KiB so tiny models still fuse.
pub fn auto_bucket_bytes(total_dense_bytes: u64, threads: usize) -> u64 {
    (total_dense_bytes / (2 * threads.max(1)) as u64).max(16 * 1024)
}

/// Octets a session's overlapped steps actually pushed through a
/// serializing transport vs. what the codecs' `WireCost` accounting
/// claimed for the same frames. Equal for every built-in codec; both
/// zero for [`InProcess`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportTraffic {
    /// Measured serialized payload+metadata octets.
    pub octets: u64,
    /// The encode-side claim (`moved_cost().total_bytes()` summed over
    /// the same frames).
    pub claimed_octets: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_packed(seed: u32) -> PackedWire {
        let vals: Vec<f32> =
            (0..17).map(|i| ((seed + i) as f32 * 0.37).sin()).collect();
        let mut pw = PackedWire::default();
        pw.pack_raw_f32(&vals);
        pw.push_meta_f32(1.5 + seed as f32);
        pw
    }

    fn assert_same(a: &PackedWire, b: &PackedWire) {
        assert_eq!(a.tag(), b.tag());
        assert_eq!(a.elems(), b.elems());
        assert_eq!(a.value_bits(), b.value_bits());
        assert_eq!(a.index_bits(), b.index_bits());
        assert_eq!(a.bytes(), b.bytes());
        assert_eq!(a.meta_bytes(), b.meta_bytes());
    }

    #[test]
    fn frame_roundtrip_preserves_every_field() {
        let pw = sample_packed(3);
        let mut buf = Vec::new();
        serialize_frame_into(&pw, &mut buf);
        assert_eq!(
            buf.len() - FRAME_HEADER_LEN,
            pw.moved_cost().total_bytes() as usize,
            "frame body must be exactly the claimed octets"
        );
        let mut out = PackedWire::default();
        let consumed = deserialize_frame(&buf, &mut out).unwrap();
        assert_eq!(consumed, buf.len());
        assert_same(&pw, &out);
    }

    #[test]
    fn truncated_frames_error_cleanly() {
        let pw = sample_packed(1);
        let mut buf = Vec::new();
        serialize_frame_into(&pw, &mut buf);
        let mut out = PackedWire::default();
        assert!(deserialize_frame(&buf[..10], &mut out).is_err());
        assert!(deserialize_frame(&buf[..buf.len() - 1], &mut out).is_err());
    }

    fn exercise(t: &mut dyn Transport, world: usize) {
        let packed: Vec<PackedWire> = (0..world as u32).map(sample_packed).collect();
        let mut claimed = WireCost::default();
        let mut claimed_octets = 0u64;
        for pw in &packed {
            claimed += pw.moved_cost();
            claimed_octets += pw.moved_cost().total_bytes();
        }
        let delivered = t.exchange(&packed).unwrap();
        assert_eq!(delivered.len(), world);
        for (a, b) in packed.iter().zip(delivered.iter()) {
            assert_same(a, b);
        }
        assert_eq!(t.moved(), claimed, "delivered accounting == encode-side claim");
        if t.octets_moved() > 0 {
            assert_eq!(t.octets_moved(), claimed_octets, "measured octets == claimed");
        }
        t.reset_moved();
        assert_eq!(t.moved(), WireCost::default());
        assert_eq!(t.octets_moved(), 0);
    }

    #[test]
    fn in_process_delivers_zero_copy() {
        let mut t = InProcess::new(3);
        exercise(&mut t, 3);
        assert_eq!(t.octets_moved(), 0);
    }

    #[test]
    fn shared_mem_roundtrips_and_counts_octets() {
        let mut t = SharedMem::new(3);
        exercise(&mut t, 3);
        // Second exchange uses the other slab of the ring.
        exercise(&mut t, 3);
    }

    #[test]
    fn tcp_roundtrips_and_counts_octets() {
        let mut t = Tcp::new(3).unwrap();
        exercise(&mut t, 3);
        exercise(&mut t, 3);
    }

    #[test]
    fn tcp_kill_peer_fails_cleanly_with_worker_index() {
        let mut t = Tcp::new(3).unwrap();
        exercise(&mut t, 3);
        t.kill_peer(1);
        let packed: Vec<PackedWire> = (0..3).map(sample_packed).collect();
        let err = t.exchange(&packed).unwrap_err();
        assert_eq!(err.transport, "tcp");
        assert_eq!(err.worker, 1, "failure must name the dropped peer");
        assert_eq!(err.kind, FaultKind::Dead, "a shut-down channel is a dead peer");
    }

    #[test]
    fn tcp_straggler_past_patience_classifies_slow() {
        let mut t = Tcp::new(2).unwrap();
        assert!(t.set_patience(Duration::from_millis(10), 2));
        assert!(t.inject_send_delay(1, Duration::from_millis(400)));
        let packed: Vec<PackedWire> = (0..2).map(sample_packed).collect();
        let err = t.exchange(&packed).unwrap_err();
        assert_eq!(err.transport, "tcp");
        assert_eq!(err.worker, 1, "failure must name the delayed peer");
        assert_eq!(err.kind, FaultKind::Slow, "a timed-out read is a slow peer, not a dead one");
        // The delayed frame may still be in flight; the transport is
        // dropped here rather than reused (frames carry no sequence id,
        // so a retry on the same sockets could desync framing).
    }

    #[test]
    fn tcp_straggler_within_patience_recovers_exactly() {
        let mut t = Tcp::new(2).unwrap();
        // ~10 ms polls with a 100-stall budget (~1 s) comfortably cover
        // the injected 50 ms delay: the read stalls, then resumes and
        // delivers the exact frame.
        assert!(t.set_patience(Duration::from_millis(10), 100));
        assert!(t.inject_send_delay(1, Duration::from_millis(50)));
        exercise(&mut t, 2);
        // Clearing the delay returns the channel to fast-path behavior.
        assert!(t.inject_send_delay(1, Duration::ZERO));
        exercise(&mut t, 2);
    }

    #[test]
    fn connect_deadline_exhaustion_names_the_address() {
        // Bind then drop a listener so the port is (almost certainly)
        // refusing connections.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = connect_with_deadline(addr, Duration::from_millis(50))
            .expect_err("connect to a dropped listener must fail");
        let msg = err.to_string();
        assert!(
            msg.contains(&addr.to_string()),
            "exhaustion error must name the address: {msg}"
        );
        assert!(msg.contains("attempts"), "error should report the attempt count: {msg}");
    }

    #[test]
    fn bucket_plan_covers_every_layer_once_in_ready_order() {
        let grads: Vec<Vec<Vec<f32>>> =
            vec![vec![vec![0.0; 33], vec![0.0; 64], vec![0.0; 128], vec![0.0; 7]]];
        let view = GradView::new(&grads);
        let order = [3usize, 2, 1, 0];
        for bytes in [1u64, 300, 1 << 30] {
            let mut plan = BucketPlan::default();
            plan.rebuild(&view, &order, bytes);
            let flat: Vec<usize> =
                (0..plan.num_buckets()).flat_map(|b| plan.bucket(b).to_vec()).collect();
            assert_eq!(flat, order, "buckets must cover ready_order exactly (bytes={bytes})");
            // Order-stable: same inputs, same plan.
            let mut again = BucketPlan::default();
            again.rebuild(&view, &order, bytes);
            let flat2: Vec<usize> =
                (0..again.num_buckets()).flat_map(|b| again.bucket(b).to_vec()).collect();
            assert_eq!(flat, flat2);
            assert_eq!(plan.num_buckets(), again.num_buckets());
        }
        // bytes=1: every layer in its own bucket; huge: one bucket.
        let mut plan = BucketPlan::default();
        plan.rebuild(&view, &order, 1);
        assert_eq!(plan.num_buckets(), 4);
        plan.rebuild(&view, &order, 1 << 30);
        assert_eq!(plan.num_buckets(), 1);
    }

    #[test]
    #[should_panic(expected = "twice")]
    fn bucket_plan_rejects_duplicate_layers() {
        let grads: Vec<Vec<Vec<f32>>> = vec![vec![vec![0.0; 4], vec![0.0; 4]]];
        let view = GradView::new(&grads);
        BucketPlan::default().rebuild(&view, &[0, 0], 1);
    }

    #[test]
    fn auto_bucket_bytes_floors_and_splits() {
        assert_eq!(auto_bucket_bytes(1 << 20, 4), (1 << 20) / 8);
        assert_eq!(auto_bucket_bytes(1024, 4), 16 * 1024, "floored for tiny models");
    }
}

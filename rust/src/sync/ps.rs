//! Parameter-server collective (ROADMAP open item 1): workers push
//! packed gradient shards to `shards` server shards over a real
//! [`Transport`] and pull the reduced result back — Downpour-style
//! non-blocking pushes (Dean et al., *Large Scale Distributed Deep
//! Networks*) with DGC-style tolerance of late contributions (Lin et
//! al., *Deep Gradient Compression*), on top of the fault semantics the
//! transport seam provides.
//!
//! **Rounds and staleness.** Every gradient reduce through the
//! collective is one logical *round*. A worker with arrival delay `d`
//! (set via [`Collective::set_arrival_delay`], clamped to the
//! collective's staleness budget `K`) contributes its round-`t` gradient
//! at round `t + d`; a round's output is the fold of exactly the
//! contributions that arrive that round (zero when none do). Delays are
//! counted in reduce calls, so for an `L`-layer model a delay of one
//! *step* is `L` rounds — the fold asserts the shapes line up rather
//! than silently folding one layer's stale gradient into another.
//!
//! **Determinism.** Arrivals are folded sorted by `(origin round,
//! worker)` in [`FOLD_BLOCK`]-element cache blocks with the shared
//! [`fold_step`] kernel (stack-resident Kahan lane), so a fixed arrival
//! schedule replays bit-exactly — the contract
//! `rust/tests/ps_topology.rs` pins across all shipped codecs. Server
//! shards (`[s·n/S, (s+1)·n/S)` ranges, re-split whenever membership
//! changes) only partition the iteration space: each element's fold
//! chain is the sorted arrival order regardless of `S`, so re-sharding
//! never changes bits.
//!
//! **Faults.** The reduce methods have no error channel (the
//! [`Collective`] trait predates real transports), so a transport
//! failure zeroes the output — a partial fold never escapes — and parks
//! the [`TransportError`] for [`Collective::take_fault`];
//! `SyncSession::step_checked` harvests it into a clean `Err`. Slow
//! peers ([`super::transport::FaultKind::Slow`], a read past the
//! patience budget) stay distinguishable from dead ones (EOF/reset) so
//! callers can treat stragglers and losses differently.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::time::Duration;

use super::transport::{Transport, TransportError, TransportSpec, TransportTraffic};
use super::wire::{PackScratch, PackedWire};
use super::{LayerCtx, SyncStrategy};
use crate::collectives::{ring, Collective, ReduceOptions, ReduceStats, FOLD_BLOCK};

/// One buffered contribution: which round it was pushed in, which round
/// it becomes foldable, and the decoded dense values.
struct Pending {
    origin: u64,
    due: u64,
    data: Vec<f32>,
}

/// Mutable server state behind the `&self` trait surface. Calls do not
/// re-enter (the same pattern as `HierarchicalCollective`'s scratch), so
/// the `RefCell` borrow is never contended.
struct PsState {
    transport: Box<dyn Transport>,
    /// Monotone reduce-call counter (the logical round clock).
    round: u64,
    /// Per-worker FIFO of not-yet-folded contributions (≤ K+1 entries).
    pending: Vec<VecDeque<Pending>>,
    /// Recycled dense buffers, so steady-state rounds allocate nothing.
    pool: Vec<Vec<f32>>,
    /// Elastic membership: inactive workers' pushes are discarded.
    active: Vec<bool>,
    /// Per-worker arrival delay in rounds (clamped to the staleness cap).
    delays: Vec<usize>,
    /// Shard boundary scratch (`S+1` entries), rebuilt every fold.
    bounds: Vec<usize>,
    /// Due-arrival sort scratch: `(origin, worker, queue index)`.
    order: Vec<(u64, usize, usize)>,
    /// Reused pull-leg frames (the reduced result as raw f32 per worker).
    pull_frames: Vec<PackedWire>,
    /// The parked failure of the most recent faulted round, if any.
    fault: Option<TransportError>,
}

impl PsState {
    fn new(world: usize, transport: Box<dyn Transport>) -> PsState {
        PsState {
            transport,
            round: 0,
            pending: (0..world).map(|_| VecDeque::new()).collect(),
            pool: Vec::new(),
            active: vec![true; world],
            delays: vec![0; world],
            bounds: Vec::new(),
            order: Vec::new(),
            pull_frames: Vec::new(),
            fault: None,
        }
    }
}

/// Server shard count actually in use: the configured count capped by
/// the live worker population (a two-worker world gains nothing from
/// eight shards), never zero.
fn effective_shards(cfg: usize, active: &[bool]) -> usize {
    let alive = active.iter().filter(|a| **a).count();
    cfg.max(1).min(alive.max(1))
}

/// Fold every due arrival into `out` (zeroed first), sorted by
/// `(origin, worker)`, shard range by shard range in cache blocks, then
/// retire the folded entries to the buffer pool. The deterministic heart
/// of the collective: a fixed arrival schedule yields a fixed fold chain
/// per element, hence bit-exact replay.
fn fold_due(
    st: &mut PsState,
    shards_cfg: usize,
    now: u64,
    out: &mut [f32],
    opts: &ReduceOptions,
) {
    let n = out.len();
    st.order.clear();
    for (w, q) in st.pending.iter().enumerate() {
        for (qi, e) in q.iter().enumerate() {
            if e.due <= now {
                assert_eq!(
                    e.data.len(),
                    n,
                    "stale contribution shape mismatch (worker {w}): arrival delays \
                     must be whole multiples of the model's reduce-call cycle"
                );
                st.order.push((e.origin, w, qi));
            }
        }
    }
    // (origin, worker) pairs are unique — one push per worker per
    // round — so the unstable sort is fully deterministic.
    st.order.sort_unstable();
    out.fill(0.0);
    if st.order.is_empty() {
        return;
    }

    // Re-split the element space over the live shard count — the PS
    // analogue of rebuilding the bucket plan on membership change.
    let shards = effective_shards(shards_cfg, &st.active);
    st.bounds.clear();
    for s in 0..=shards {
        st.bounds.push(s * n / shards);
    }

    let mut comp = [0.0f32; FOLD_BLOCK];
    for s in 0..shards {
        let lo = st.bounds[s];
        let hi = st.bounds[s + 1];
        if lo == hi {
            continue;
        }
        let mut b0 = lo;
        while b0 < hi {
            let b1 = (b0 + FOLD_BLOCK).min(hi);
            let blk = &mut out[b0..b1];
            let mut first = true;
            if opts.kahan {
                let comp = &mut comp[..blk.len()];
                comp.fill(0.0);
                for &(_, w, qi) in st.order.iter() {
                    let src = &st.pending[w][qi].data[b0..b1];
                    if first {
                        blk.copy_from_slice(src);
                        first = false;
                        continue;
                    }
                    for i in 0..blk.len() {
                        crate::collectives::fold_step(
                            &mut blk[i],
                            &mut comp[i],
                            src[i],
                            opts.fmt,
                            opts.mode,
                            true,
                        );
                    }
                }
            } else {
                let mut dummy = 0.0f32;
                for &(_, w, qi) in st.order.iter() {
                    let src = &st.pending[w][qi].data[b0..b1];
                    if first {
                        blk.copy_from_slice(src);
                        first = false;
                        continue;
                    }
                    for i in 0..blk.len() {
                        crate::collectives::fold_step(
                            &mut blk[i],
                            &mut dummy,
                            src[i],
                            opts.fmt,
                            opts.mode,
                            false,
                        );
                    }
                }
            }
            b0 = b1;
        }
    }

    // Retire folded entries whole (recycling their buffers). Queue
    // order is not due order when a delay shrinks mid-run, so scan.
    for w in 0..st.pending.len() {
        let mut i = 0;
        while i < st.pending[w].len() {
            if st.pending[w][i].due <= now {
                if let Some(e) = st.pending[w].remove(i) {
                    st.pool.push(e.data);
                }
            } else {
                i += 1;
            }
        }
    }
}

/// The parameter-server [`Collective`]. See the module docs for the
/// round/staleness/fault model.
pub struct PsCollective {
    world: usize,
    shards: usize,
    staleness: usize,
    /// Whether the transport serializes — claimed octets are only
    /// counted then, so `octets == claimed` holds for [`InProcess`]
    /// too (0 == 0), mirroring the overlap pool's accounting.
    count_claimed: bool,
    state: RefCell<PsState>,
}

impl PsCollective {
    /// A parameter server over the in-process transport (no octets on
    /// any wire). `shards` is capped by the live worker count per fold;
    /// `staleness` is the bound `K` on per-worker arrival delay.
    pub fn new(world: usize, shards: usize, staleness: usize) -> PsCollective {
        assert!(world >= 1, "a parameter server needs at least one worker");
        assert!(shards >= 1, "a parameter server needs at least one shard");
        PsCollective {
            world,
            shards,
            staleness,
            count_claimed: false,
            state: RefCell::new(PsState::new(world, TransportSpec::InProcess.build(world))),
        }
    }

    /// Rebuild over `spec`'s transport (the session builder's hook for
    /// `sync.transport`): push/pull legs then move real serialized
    /// octets, measured against the codecs' claimed `WireCost`.
    pub fn with_transport(mut self, spec: TransportSpec) -> PsCollective {
        self.count_claimed = spec != TransportSpec::InProcess;
        {
            let mut st = self.state.borrow_mut();
            st.transport = spec.build(self.world);
        }
        self
    }

    /// Per-round traffic: each worker pushes `n` elements in the wire
    /// format and pulls `n` reduced elements as raw f32. Identical for
    /// the dense and packed paths, so reports stay bit-identical across
    /// wire modes.
    fn round_stats(&self, n: usize, opts: &ReduceOptions) -> ReduceStats {
        let push = n as u64 * ring::wire_bytes(*opts) as u64;
        let pull = n as u64 * 4;
        ReduceStats { bytes_per_worker: push + pull, steps: 2 }
    }
}

impl Collective for PsCollective {
    fn name(&self) -> &'static str {
        "ps"
    }
    fn world_size(&self) -> usize {
        self.world
    }
    fn steps_per_message(&self) -> usize {
        2 // one push + one pull, independent of world size
    }

    fn all_reduce_sum_into(
        &self,
        contribs: &[Vec<f32>],
        out: &mut [f32],
        opts: &ReduceOptions,
    ) -> ReduceStats {
        assert_eq!(contribs.len(), self.world, "one contribution per worker");
        let n = out.len();
        let stats = self.round_stats(n, opts);
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        if st.fault.is_some() {
            // A faulted server stays down until the fault is harvested;
            // zero output, never a partial fold.
            out.fill(0.0);
            return stats;
        }
        let now = st.round;
        st.round += 1;
        for (w, c) in contribs.iter().enumerate() {
            if !st.active[w] {
                continue;
            }
            assert_eq!(c.len(), n, "ragged contributions");
            let mut buf = st.pool.pop().unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(c);
            let due = now + st.delays[w].min(self.staleness) as u64;
            st.pending[w].push_back(Pending { origin: now, due, data: buf });
        }
        fold_due(st, self.shards, now, out, opts);
        stats
    }

    fn all_reduce_max_i8_into(&self, contribs: &[Vec<i8>], out: &mut [i8]) -> ReduceStats {
        assert_eq!(contribs.len(), self.world, "one contribution per worker");
        let st = self.state.borrow();
        let n = out.len();
        out.fill(i8::MIN);
        for (w, c) in contribs.iter().enumerate() {
            if !st.active[w] {
                continue;
            }
            assert_eq!(c.len(), n);
            for (o, &v) in out.iter_mut().zip(c) {
                *o = (*o).max(v);
            }
        }
        // The exponent agreement is synchronous (a stale factor would
        // desynchronize the workers' encode scales): 1 byte per entry
        // up to the server, 1 byte back.
        ReduceStats { bytes_per_worker: 2 * n as u64, steps: 2 }
    }

    fn all_reduce_packed_sum_into(
        &self,
        packed: &[PackedWire],
        strategy: &dyn SyncStrategy,
        ctx: &LayerCtx,
        out: &mut [f32],
        opts: &ReduceOptions,
        _scratch: &mut PackScratch,
    ) -> ReduceStats {
        assert_eq!(packed.len(), self.world, "one packed contribution per worker");
        let n = out.len();
        let stats = self.round_stats(n, opts);
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        if st.fault.is_some() {
            out.fill(0.0);
            return stats;
        }
        let now = st.round;
        st.round += 1;

        // Push leg: every worker's frame ships (a departed worker's
        // channel still carries bytes — the server discards them on
        // arrival), so measured octets cover exactly the frames
        // exchanged. Contributions decode at push time, while this
        // round's `ctx` (factor exponent, step seed) is in force.
        match st.transport.exchange(packed) {
            Ok(delivered) => {
                for w in 0..self.world {
                    if !st.active[w] {
                        continue;
                    }
                    let mut buf = st.pool.pop().unwrap_or_default();
                    buf.clear();
                    // Pool-miss growth only: buffers recycle through
                    // PsState::pool after every fold, so steady-state
                    // rounds reuse their capacity.
                    buf.resize(n, 0.0);
                    strategy.decode_packed(&delivered[w], ctx, 0..n, &mut buf);
                    let due = now + st.delays[w].min(self.staleness) as u64;
                    st.pending[w].push_back(Pending { origin: now, due, data: buf });
                }
            }
            Err(e) => {
                st.fault = Some(e);
                out.fill(0.0);
                return stats;
            }
        }

        fold_due(st, self.shards, now, out, opts);

        // Pull leg: the reduced result returns to every worker as raw
        // f32 — bit-exact and WireCost-honest (4n octets per worker).
        if st.pull_frames.len() < self.world {
            // One frame per worker, grown on the first round only;
            // pack_raw_f32 reuses their capacity afterwards.
            st.pull_frames.resize_with(self.world, PackedWire::default);
        }
        for f in st.pull_frames.iter_mut() {
            f.pack_raw_f32(out);
        }
        if let Err(e) = st.transport.exchange(&st.pull_frames) {
            st.fault = Some(e);
            out.fill(0.0);
        }
        stats
    }

    fn take_fault(&self) -> Option<TransportError> {
        self.state.borrow_mut().fault.take()
    }

    fn transport_traffic(&self) -> Option<TransportTraffic> {
        let st = self.state.borrow();
        Some(TransportTraffic {
            octets: st.transport.octets_moved(),
            claimed_octets: if self.count_claimed {
                st.transport.moved().total_bytes()
            } else {
                0
            },
        })
    }

    fn set_member_active(&self, worker: usize, active: bool) -> bool {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        match st.active.get_mut(worker) {
            Some(a) => {
                *a = active;
                if !active {
                    // A departing worker's queued contributions drop
                    // whole — never partially folded.
                    while let Some(e) = st.pending[worker].pop_front() {
                        st.pool.push(e.data);
                    }
                }
                true
            }
            None => false,
        }
    }

    fn set_arrival_delay(&self, worker: usize, rounds: usize) -> bool {
        match self.state.borrow_mut().delays.get_mut(worker) {
            Some(d) => {
                // Clamped to the staleness budget: the bound `K` holds
                // by construction, not by trust in the schedule.
                *d = rounds.min(self.staleness);
                true
            }
            None => false,
        }
    }

    fn kill_transport_peer(&self, worker: usize) -> bool {
        self.state.borrow_mut().transport.kill_peer(worker);
        true
    }

    fn set_transport_patience(&self, read_timeout_ms: u64, max_timeouts: usize) -> bool {
        self.state
            .borrow_mut()
            .transport
            .set_patience(Duration::from_millis(read_timeout_ms), max_timeouts)
    }

    fn inject_transport_delay(&self, worker: usize, delay_ms: u64) -> bool {
        self.state
            .borrow_mut()
            .transport
            .inject_send_delay(worker, Duration::from_millis(delay_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::FpFormat;

    fn contribs(world: usize, n: usize, round: usize) -> Vec<Vec<f32>> {
        (0..world)
            .map(|w| {
                (0..n)
                    .map(|i| ((w * 131 + round * 31 + i * 7) % 23) as f32 * 0.125 - 1.0)
                    .collect()
            })
            .collect()
    }

    /// Reference: fold all on-time contributions in worker order with
    /// the shared kernel — what a zero-delay PS round must produce.
    fn reference_fold(cs: &[Vec<f32>], opts: &ReduceOptions) -> Vec<f32> {
        let mut out = cs[0].clone();
        let mut dummy = 0.0f32;
        for c in &cs[1..] {
            for (o, &v) in out.iter_mut().zip(c) {
                crate::collectives::fold_step(o, &mut dummy, v, opts.fmt, opts.mode, false);
            }
        }
        out
    }

    #[test]
    fn synchronous_round_folds_in_worker_order() {
        let world = 4;
        let n = 100;
        let opts = ReduceOptions::low_precision(FpFormat::E5M2);
        let ps = PsCollective::new(world, 2, 0);
        let cs = contribs(world, n, 0);
        let mut out = vec![0.0f32; n];
        let stats = ps.all_reduce_sum_into(&cs, &mut out, &opts);
        let want = reference_fold(&cs, &opts);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}");
        }
        assert_eq!(stats.steps, 2);
        assert_eq!(stats.bytes_per_worker, n as u64 * (1 + 4));
    }

    #[test]
    fn shard_count_never_changes_bits() {
        let world = 4;
        let n = 1000 + 7; // uneven splits across every shard count
        let opts = ReduceOptions::low_precision(FpFormat::E4M3);
        let cs = contribs(world, n, 1);
        let mut reference = Vec::new();
        for shards in [1usize, 2, 3, 4, 16] {
            let ps = PsCollective::new(world, shards, 0);
            let mut out = vec![0.0f32; n];
            ps.all_reduce_sum_into(&cs, &mut out, &opts);
            let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
            if reference.is_empty() {
                reference = bits;
            } else {
                assert_eq!(bits, reference, "shards={shards} diverged");
            }
        }
    }

    #[test]
    fn staleness_delays_and_reorders_deterministically() {
        let world = 2;
        let n = 8;
        let opts = ReduceOptions::fp32();
        let ps = PsCollective::new(world, 1, 2);
        assert!(ps.set_arrival_delay(1, 1));
        let r0 = contribs(world, n, 0);
        let r1 = contribs(world, n, 1);

        // Round 0: only worker 0 arrives.
        let mut out0 = vec![0.0f32; n];
        ps.all_reduce_sum_into(&r0, &mut out0, &opts);
        assert_eq!(out0, r0[0]);

        // Round 1: worker 1's round-0 push (older origin, folds first)
        // plus worker 0's round-1 push.
        let mut out1 = vec![0.0f32; n];
        ps.all_reduce_sum_into(&r1, &mut out1, &opts);
        let want = reference_fold(&[r0[1].clone(), r1[0].clone()], &opts);
        for (a, b) in out1.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn arrival_delay_is_clamped_to_the_staleness_budget() {
        let world = 2;
        let n = 4;
        let opts = ReduceOptions::fp32();
        let ps = PsCollective::new(world, 1, 1); // K = 1
        assert!(ps.set_arrival_delay(1, 100)); // clamped to 1
        let r0 = contribs(world, n, 0);
        let r1 = contribs(world, n, 1);
        let mut out = vec![0.0f32; n];
        ps.all_reduce_sum_into(&r0, &mut out, &opts);
        assert_eq!(out, r0[0], "delayed worker must miss its own round");
        ps.all_reduce_sum_into(&r1, &mut out, &opts);
        let want = reference_fold(&[r0[1].clone(), r1[0].clone()], &opts);
        assert_eq!(out, want, "clamp means the push lands exactly one round late");
    }

    #[test]
    fn no_arrivals_round_yields_zeros() {
        let world = 2;
        let n = 6;
        let opts = ReduceOptions::fp32();
        let ps = PsCollective::new(world, 1, 3);
        for w in 0..world {
            assert!(ps.set_arrival_delay(w, 2));
        }
        let mut out = vec![1.0f32; n];
        ps.all_reduce_sum_into(&contribs(world, n, 0), &mut out, &opts);
        assert_eq!(out, vec![0.0; n], "nothing due yet: the server hands back zeros");
    }

    #[test]
    fn departed_member_is_excluded_and_rejoins() {
        let world = 3;
        let n = 16;
        let opts = ReduceOptions::fp32();
        let ps = PsCollective::new(world, 2, 0);
        let cs = contribs(world, n, 2);
        assert!(ps.set_member_active(2, false));
        let mut out = vec![0.0f32; n];
        ps.all_reduce_sum_into(&cs, &mut out, &opts);
        let want = reference_fold(&cs[..2], &opts);
        assert_eq!(out, want, "departed worker must not contribute");
        assert!(ps.set_member_active(2, true));
        ps.all_reduce_sum_into(&cs, &mut out, &opts);
        let want = reference_fold(&cs, &opts);
        assert_eq!(out, want, "rejoined worker contributes again");
    }

    #[test]
    fn max_i8_skips_inactive_workers() {
        let ps = PsCollective::new(3, 1, 0);
        assert!(ps.set_member_active(1, false));
        let contribs = vec![vec![1i8, -5], vec![99, 99], vec![-2, 7]];
        let mut out = vec![0i8; 2];
        let stats = ps.all_reduce_max_i8_into(&contribs, &mut out);
        assert_eq!(out, vec![1, 7], "inactive worker's maxima must be ignored");
        assert_eq!(stats.steps, 2);
    }

    #[test]
    fn out_of_range_worker_hooks_return_false() {
        let ps = PsCollective::new(2, 1, 0);
        assert!(!ps.set_member_active(5, false));
        assert!(!ps.set_arrival_delay(5, 1));
    }
}

//! Built-in [`SyncStrategy`] implementations.
//!
//! The four paper methods ([`Fp32Strategy`], [`NaiveStrategy`],
//! [`LossScalingStrategy`], [`ApsStrategy`]) are bit-identical
//! re-implementations of the pre-trait `SyncMethod` paths — the
//! equivalence suite in `rust/tests/strategy_layer.rs` pins them against
//! `aps::legacy::synchronize`. [`TernaryStrategy`] and [`TopKStrategy`]
//! are net-new codecs proving the trait layer is an open extension point
//! (TernGrad [28] and Deep-Gradient-Compression-style sparsification from
//! the related work).

use super::{unscale_in_place, Factors, GradView, LayerCtx, SyncStrategy};
use crate::aps::local_max_exp;
use crate::collectives::{Collective, ReduceStats};
use crate::cpd::{quantize_shifted_slice_into, FpFormat};

/// Shared phase-2 encode of the four paper methods: shift by the agreed
/// power-of-two factor and cast into the layer's wire format with a
/// single rounding (the exact legacy wire path).
#[inline]
fn cast_encode(src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
    quantize_shifted_slice_into(src, ctx.factor_exp, ctx.fmt, ctx.rounding, out);
}

/// Full-precision baseline: FP32 on the wire, no factors.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fp32Strategy;

impl SyncStrategy for Fp32Strategy {
    fn name(&self) -> &'static str {
        "fp32"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::FP32
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
}

/// Cast to the low-precision wire format with no scaling (the paper's
/// "no APS" rows: underflow/overflow-prone).
#[derive(Clone, Copy, Debug)]
pub struct NaiveStrategy {
    fmt: FpFormat,
}

impl NaiveStrategy {
    pub fn new(fmt: FpFormat) -> Self {
        NaiveStrategy { fmt }
    }
}

impl SyncStrategy for NaiveStrategy {
    fn name(&self) -> &'static str {
        "naive"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
}

/// One global, hand-chosen power-of-two factor for every layer
/// (Micikevicius et al. [21]).
#[derive(Clone, Copy, Debug)]
pub struct LossScalingStrategy {
    fmt: FpFormat,
    factor_exp: i32,
}

impl LossScalingStrategy {
    pub fn new(fmt: FpFormat, factor_exp: i32) -> Self {
        LossScalingStrategy { fmt, factor_exp }
    }
}

impl SyncStrategy for LossScalingStrategy {
    fn name(&self) -> &'static str {
        "loss_scaling"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn prepare(
        &mut self,
        _grads: &GradView,
        _collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        factors.exps.fill(self.factor_exp);
        ReduceStats::default()
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
}

/// Auto-Precision Scaling (paper Algorithm 1): each layer is shifted by
/// the largest power-of-two factor that provably cannot overflow the
/// wire format even after summation across all workers (Eq. 1–4), agreed
/// via a 1-byte-per-layer exponent max-reduce.
#[derive(Clone, Copy, Debug)]
pub struct ApsStrategy {
    fmt: FpFormat,
}

impl ApsStrategy {
    pub fn new(fmt: FpFormat) -> Self {
        ApsStrategy { fmt }
    }
}

impl SyncStrategy for ApsStrategy {
    fn name(&self) -> &'static str {
        "aps"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        let world = grads.world();
        let layers = grads.num_layers();
        factors.ensure_i8(world, layers);
        // Algorithm 1 lines 3–4: each worker contributes one i8 exponent
        // per layer, already inflated by the world size.
        for w in 0..world {
            for l in 0..layers {
                factors.i8_contribs[w][l] = local_max_exp(grads.layer_of(w, l), world)
                    .map(|e| e.clamp(-128, 127) as i8)
                    .unwrap_or(i8::MIN);
            }
        }
        let stats = collective.all_reduce_max_i8_into(&factors.i8_contribs, &mut factors.i8_max);
        for (l, &me) in factors.i8_max.iter().enumerate() {
            factors.exps[l] = if me == i8::MIN {
                0 // all-zero layer: no scaling needed
            } else {
                self.fmt.max_exponent() - me as i32
            };
        }
        stats
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
}

/// TernGrad-style stochastic ternarization (net-new codec).
///
/// Per layer, workers agree (via the same 1-byte exponent max-reduce APS
/// uses) on a power-of-two scale `s = 2^e ≥ max_w max_i |g_i|`; each
/// element is then sent as one of `{-s, 0, +s}`, taking `±s` with
/// probability `|g|/s` (unbiased: `E[symbol] = g`). Symbols are
/// deterministic in `(seed, step, worker, layer, element)` so runs are
/// reproducible. The reduction runs in BF16 words — integer multiples of
/// `s` up to 256 workers are exact, and the simulation accounts 2 bytes
/// per element (a packed deployment would ship 2-bit symbols; see the
/// strategy-matrix bench notes). Under the fp32-last-layer policy the
/// final layer bypasses ternarization and is sent dense.
#[derive(Clone, Copy, Debug)]
pub struct TernaryStrategy {
    seed: u64,
}

impl TernaryStrategy {
    pub fn new(seed: u64) -> Self {
        TernaryStrategy { seed }
    }

    /// One uniform draw in `[0, 1)` from the stream position.
    fn unit(&self, step: u64, worker: u64, layer: u64, elem: u64) -> f32 {
        let mut h = crate::cpd::cast::splitmix64(self.seed ^ step);
        h = crate::cpd::cast::splitmix64(h ^ (worker << 32) ^ layer);
        h = crate::cpd::cast::splitmix64(h ^ elem);
        (h >> 40) as f32 / (1u64 << 24) as f32
    }
}

impl SyncStrategy for TernaryStrategy {
    fn name(&self) -> &'static str {
        "ternary"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::BF16
    }
    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        let world = grads.world();
        // BF16's 7-bit mantissa keeps k·s exact only for |k| ≤ 256;
        // beyond that partial sums round and the codec's unbiasedness
        // silently breaks — fail fast instead.
        assert!(world <= 256, "TernaryStrategy's BF16 wire is exact only up to 256 workers");
        let layers = grads.num_layers();
        factors.ensure_i8(world, layers);
        // Agree on e = ceil(log2 max|g|) per layer (no world inflation —
        // symbols are summed at gradient scale, not shifted).
        for w in 0..world {
            for l in 0..layers {
                factors.i8_contribs[w][l] = local_max_exp(grads.layer_of(w, l), 1)
                    .map(|e| e.clamp(-128, 127) as i8)
                    .unwrap_or(i8::MIN);
            }
        }
        let stats = collective.all_reduce_max_i8_into(&factors.i8_contribs, &mut factors.i8_max);
        for (l, &me) in factors.i8_max.iter().enumerate() {
            factors.exps[l] = if me == i8::MIN { 0 } else { me as i32 };
        }
        stats
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        if ctx.fp32_passthrough {
            // fp32-last-layer policy: dense full-precision passthrough.
            out.copy_from_slice(src);
            return;
        }
        let s = crate::aps::ldexp_f32(1.0, ctx.factor_exp);
        // factor_exp came through an i8 clamp, so s ∈ [2^-128, 2^127].
        debug_assert!(s > 0.0 && s.is_finite(), "ternary scale 2^{}", ctx.factor_exp);
        for (i, (&x, o)) in src.iter().zip(out.iter_mut()).enumerate() {
            if x == 0.0 {
                *o = 0.0;
                continue;
            }
            if !x.is_finite() {
                // Propagate divergence onto the wire like every other
                // strategy (f32::min would otherwise turn NaN into +s).
                *o = x;
                continue;
            }
            let p = (x.abs() / s).min(1.0);
            let u = self.unit(ctx.step, ctx.worker as u64, ctx.layer as u64, i as u64);
            *o = if u < p { if x < 0.0 { -s } else { s } } else { 0.0 };
        }
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        // Symbols are already at gradient scale: only averaging remains.
        unscale_in_place(reduced, 0, ctx.world, ctx.average);
    }
}

/// Top-k magnitude sparsification (Deep Gradient Compression-style).
///
/// Each worker keeps its `frac` largest-magnitude elements per layer
/// (at least one) at full FP32 precision and zeroes the rest; the dense
/// sum then averages as usual. Dropped elements show up in the
/// [`crate::aps::SyncReport`] as wire underflow — exactly what they are
/// from the optimizer's point of view. Deterministic (threshold
/// selection, no RNG), so sessions replay bit-identically. The
/// simulation accounts dense FP32 words; a real deployment ships `k`
/// (index, value) pairs.
#[derive(Clone, Debug)]
pub struct TopKStrategy {
    frac: f32,
    /// |src| scratch for threshold selection (reused across steps).
    scratch: Vec<f32>,
}

impl TopKStrategy {
    pub fn new(frac: f32) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "top-k fraction must be in (0, 1]");
        TopKStrategy { frac, scratch: Vec::new() }
    }
}

impl SyncStrategy for TopKStrategy {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::FP32
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        out.copy_from_slice(src);
        if ctx.fp32_passthrough {
            // fp32-last-layer policy: the protected layer stays dense
            // (top-k's wire is FP32 everywhere, so the explicit flag is
            // the only way to see the policy).
            return;
        }
        let n = src.len();
        if n == 0 {
            return;
        }
        let k = ((self.frac as f64 * n as f64).ceil() as usize).clamp(1, n);
        if k == n {
            return;
        }
        self.scratch.clear();
        self.scratch.extend(src.iter().map(|x| x.abs()));
        // k-th largest magnitude as the keep threshold (ties all kept).
        self.scratch.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
        let thresh = self.scratch[k - 1];
        for o in out.iter_mut() {
            if o.abs() < thresh {
                *o = 0.0;
            }
        }
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, 0, ctx.world, ctx.average);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::RingCollective;
    use crate::cpd::Rounding;

    fn ctx(fmt: FpFormat, factor_exp: i32, world: usize) -> LayerCtx {
        LayerCtx {
            layer: 0,
            num_layers: 1,
            worker: 0,
            world,
            factor_exp,
            fmt,
            fp32_passthrough: false,
            rounding: Rounding::NearestEven,
            average: true,
            step: 0,
        }
    }

    #[test]
    fn fp32_passthrough_keeps_codec_layers_dense() {
        let src = vec![0.25f32, -0.125, 0.5, -1.0];
        let c = LayerCtx { fp32_passthrough: true, ..ctx(FpFormat::FP32, 0, 4) };
        let mut out = vec![0.0f32; 4];
        TernaryStrategy::new(3).encode(&src, &c, &mut out);
        assert_eq!(out, src);
        let mut out = vec![0.0f32; 4];
        TopKStrategy::new(0.25).encode(&src, &c, &mut out);
        assert_eq!(out, src);
    }

    #[test]
    fn ternary_propagates_non_finite_gradients() {
        let mut t = TernaryStrategy::new(1);
        let src = vec![f32::NAN, f32::INFINITY, 0.5, -0.5];
        let mut out = vec![0.0f32; 4];
        t.encode(&src, &ctx(FpFormat::BF16, 0, 4), &mut out);
        assert!(out[0].is_nan(), "NaN must stay visible on the wire");
        assert!(out[1].is_infinite());
        assert!(out[2] == 0.0 || out[2] == 1.0);
    }

    #[test]
    fn ternary_symbols_are_ternary_and_unbiased_ish() {
        let mut t = TernaryStrategy::new(7);
        let grads = vec![vec![vec![0.3f32; 2000]]];
        let view = GradView::new(&grads);
        let coll = RingCollective::new(1);
        let mut factors = Factors::default();
        factors.reset(1);
        t.prepare(&view, &coll, &mut factors);
        let e = factors.exp(0);
        // ceil(log2 0.3) = -1 → s = 0.5
        assert_eq!(e, -1);
        let s = 0.5f32;
        let mut out = vec![0.0f32; 2000];
        let c = ctx(t.wire_format(), e, 1);
        t.encode(&grads[0][0], &c, &mut out);
        let mut mean = 0.0f64;
        for &o in &out {
            assert!(o == 0.0 || o == s || o == -s, "symbol {o}");
            mean += o as f64;
        }
        mean /= out.len() as f64;
        // E[symbol] = 0.3; loose 3-sigma-ish bound for 2000 draws.
        assert!((mean - 0.3).abs() < 0.04, "mean {mean}");
    }

    #[test]
    fn ternary_is_deterministic_per_stream() {
        let mut t = TernaryStrategy::new(9);
        let src = vec![0.1f32, -0.2, 0.05, 0.7];
        let c = ctx(FpFormat::BF16, 0, 4);
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        t.encode(&src, &c, &mut a);
        t.encode(&src, &c, &mut b);
        assert_eq!(a, b);
        // a different worker gets a different stream
        let c2 = LayerCtx { worker: 1, ..c };
        let mut w1 = vec![0.0f32; 4];
        t.encode(&src, &c2, &mut w1);
        let _ = w1; // may or may not differ element-wise; just must run
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let mut t = TopKStrategy::new(0.5);
        let src = vec![0.1f32, -4.0, 0.01, 2.0, -0.5, 0.0];
        let mut out = vec![0.0f32; 6];
        t.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out, vec![0.0, -4.0, 0.0, 2.0, -0.5, 0.0]);
        // survivors are bitwise the source values
        assert_eq!(out[1].to_bits(), src[1].to_bits());
    }

    #[test]
    fn topk_always_keeps_at_least_one() {
        let mut t = TopKStrategy::new(0.01);
        let src = vec![1.0f32, 2.0, 3.0];
        let mut out = vec![0.0f32; 3];
        t.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 1);
        assert_eq!(out[2], 3.0);
    }
}

//! Built-in [`SyncStrategy`] implementations.
//!
//! The four paper methods ([`Fp32Strategy`], [`NaiveStrategy`],
//! [`LossScalingStrategy`], [`ApsStrategy`]) are bit-identical
//! re-implementations of the pre-trait `SyncMethod` paths — the
//! equivalence suite in `rust/tests/strategy_layer.rs` pins them against
//! `aps::legacy::synchronize`. [`TernaryStrategy`], [`TopKStrategy`] and
//! [`QsgdStrategy`] are net-new codecs proving the trait layer is an open
//! extension point (TernGrad [28], Deep-Gradient-Compression-style
//! sparsification, and QSGD bucketed quantization from the related work).
//! All of them are pinned by the shared contract in
//! `rust/tests/codec_conformance.rs`.

use super::wire::{self, index_width, BitReader, BitWriter, PackedWire};
use super::{unscale_in_place, Factors, GradView, LayerCtx, SyncStrategy, WireCost};
use crate::aps::local_max_exp;
use crate::collectives::{Collective, ReduceStats};
use crate::cpd::{quantize_shifted_slice_into, FpFormat};
use crate::util::par;
use core::ops::Range;

/// Shared phase-2 encode of the four paper methods: shift by the agreed
/// power-of-two factor and cast into the layer's wire format with a
/// single rounding (the exact legacy wire path).
#[inline]
fn cast_encode(src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
    quantize_shifted_slice_into(src, ctx.factor_exp, ctx.fmt, ctx.rounding, out);
}

/// One uniform draw in `[0, 1)` at a `(seed, step, worker, layer, elem)`
/// stream position — the shared RNG of the stochastic codecs. Each codec
/// domain-separates its seed before calling so two codecs configured with
/// the same user seed never consume correlated uniforms.
#[inline]
fn unit_draw(seed: u64, step: u64, worker: u64, layer: u64, elem: u64) -> f32 {
    let mut h = crate::cpd::cast::splitmix64(seed ^ step);
    h = crate::cpd::cast::splitmix64(h ^ (worker << 32) ^ layer);
    h = crate::cpd::cast::splitmix64(h ^ elem);
    // apslint: allow(lossy_cast) -- exact: the shift keeps 24 bits, the f32 mantissa width; (1u64 << 24) is a power of two
    (h >> 40) as f32 / (1u64 << 24) as f32
}

/// Full-precision baseline: FP32 on the wire, no factors.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fp32Strategy;

impl SyncStrategy for Fp32Strategy {
    fn name(&self) -> &'static str {
        "fp32"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::FP32
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        wire::pack_cast_layer(encoded, ctx, out);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        wire::unpack_cast_range(packed, ctx, range, out);
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(*self))
    }
}

/// Cast to the low-precision wire format with no scaling (the paper's
/// "no APS" rows: underflow/overflow-prone).
#[derive(Clone, Copy, Debug)]
pub struct NaiveStrategy {
    fmt: FpFormat,
}

impl NaiveStrategy {
    pub fn new(fmt: FpFormat) -> Self {
        NaiveStrategy { fmt }
    }
}

impl SyncStrategy for NaiveStrategy {
    fn name(&self) -> &'static str {
        "naive"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        wire::pack_cast_layer(encoded, ctx, out);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        wire::unpack_cast_range(packed, ctx, range, out);
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(*self))
    }
}

/// One global, hand-chosen power-of-two factor for every layer
/// (Micikevicius et al. [21]).
#[derive(Clone, Copy, Debug)]
pub struct LossScalingStrategy {
    fmt: FpFormat,
    factor_exp: i32,
}

impl LossScalingStrategy {
    pub fn new(fmt: FpFormat, factor_exp: i32) -> Self {
        LossScalingStrategy { fmt, factor_exp }
    }
}

impl SyncStrategy for LossScalingStrategy {
    fn name(&self) -> &'static str {
        "loss_scaling"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn prepare(
        &mut self,
        _grads: &GradView,
        _collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        factors.exps.fill(self.factor_exp);
        ReduceStats::default()
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        wire::pack_cast_layer(encoded, ctx, out);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        wire::unpack_cast_range(packed, ctx, range, out);
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(*self))
    }
}

/// Auto-Precision Scaling (paper Algorithm 1): each layer is shifted by
/// the largest power-of-two factor that provably cannot overflow the
/// wire format even after summation across all workers (Eq. 1–4), agreed
/// via a 1-byte-per-layer exponent max-reduce.
#[derive(Clone, Copy, Debug)]
pub struct ApsStrategy {
    fmt: FpFormat,
}

impl ApsStrategy {
    pub fn new(fmt: FpFormat) -> Self {
        ApsStrategy { fmt }
    }
}

impl SyncStrategy for ApsStrategy {
    fn name(&self) -> &'static str {
        "aps"
    }
    fn wire_format(&self) -> FpFormat {
        self.fmt
    }
    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        let world = grads.world();
        let layers = grads.num_layers();
        factors.ensure_i8(world, layers);
        // Algorithm 1 lines 3–4: each worker contributes one i8 exponent
        // per layer, already inflated by the world size.
        for w in 0..world {
            for l in 0..layers {
                factors.i8_contribs[w][l] = local_max_exp(grads.layer_of(w, l), world)
                    .map(|e| e.clamp(-128, 127) as i8)
                    .unwrap_or(i8::MIN);
            }
        }
        let stats = collective.all_reduce_max_i8_into(&factors.i8_contribs, &mut factors.i8_max);
        for (l, &me) in factors.i8_max.iter().enumerate() {
            factors.exps[l] = if me == i8::MIN {
                0 // all-zero layer: no scaling needed
            } else {
                self.fmt.max_exponent() - me as i32
            };
        }
        stats
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        cast_encode(src, ctx, out);
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, ctx.factor_exp, ctx.world, ctx.average);
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        wire::pack_cast_layer(encoded, ctx, out);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        wire::unpack_cast_range(packed, ctx, range, out);
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(*self))
    }
}

/// TernGrad-style stochastic ternarization (net-new codec).
///
/// Per layer, workers agree (via the same 1-byte exponent max-reduce APS
/// uses) on a power-of-two scale `s = 2^e ≥ max_w max_i |g_i|`; each
/// element is then sent as one of `{-s, 0, +s}`, taking `±s` with
/// probability `|g|/s` (unbiased: `E[symbol] = g`). Symbols are
/// deterministic in `(seed, step, worker, layer, element)` so runs are
/// reproducible. The reduction runs in BF16 words — integer multiples of
/// `s` up to 256 workers are exact, and the simulation accounts 2 bytes
/// per element (a packed deployment would ship 2-bit symbols; see the
/// strategy-matrix bench notes). Under the fp32-last-layer policy the
/// final layer bypasses ternarization and is sent dense.
#[derive(Clone, Copy, Debug)]
pub struct TernaryStrategy {
    seed: u64,
}

impl TernaryStrategy {
    pub fn new(seed: u64) -> Self {
        TernaryStrategy { seed }
    }

    /// One uniform draw in `[0, 1)` from the stream position (ternary is
    /// the un-salted [`unit_draw`] stream, unchanged since the codec
    /// landed — sessions replay historic runs bit-identically).
    fn unit(&self, step: u64, worker: u64, layer: u64, elem: u64) -> f32 {
        unit_draw(self.seed, step, worker, layer, elem)
    }
}

impl SyncStrategy for TernaryStrategy {
    fn name(&self) -> &'static str {
        "ternary"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::BF16
    }
    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        let world = grads.world();
        // BF16's 7-bit mantissa keeps k·s exact only for |k| ≤ 256;
        // beyond that partial sums round and the codec's unbiasedness
        // silently breaks — fail fast instead.
        assert!(world <= 256, "TernaryStrategy's BF16 wire is exact only up to 256 workers");
        let layers = grads.num_layers();
        factors.ensure_i8(world, layers);
        // Agree on e = ceil(log2 max|g|) per layer (no world inflation —
        // symbols are summed at gradient scale, not shifted).
        for w in 0..world {
            for l in 0..layers {
                factors.i8_contribs[w][l] = local_max_exp(grads.layer_of(w, l), 1)
                    .map(|e| e.clamp(-128, 127) as i8)
                    .unwrap_or(i8::MIN);
            }
        }
        let stats = collective.all_reduce_max_i8_into(&factors.i8_contribs, &mut factors.i8_max);
        for (l, &me) in factors.i8_max.iter().enumerate() {
            factors.exps[l] = if me == i8::MIN { 0 } else { me as i32 };
        }
        stats
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        if ctx.fp32_passthrough {
            // fp32-last-layer policy: dense full-precision passthrough.
            out.copy_from_slice(src);
            return;
        }
        let s = crate::aps::ldexp_f32(1.0, ctx.factor_exp);
        // factor_exp came through an i8 clamp, so s ∈ [2^-128, 2^127].
        debug_assert!(s > 0.0 && s.is_finite(), "ternary scale 2^{}", ctx.factor_exp);
        for (i, (&x, o)) in src.iter().zip(out.iter_mut()).enumerate() {
            if x == 0.0 {
                *o = 0.0;
                continue;
            }
            if !x.is_finite() {
                // Propagate divergence onto the wire like every other
                // strategy (f32::min would otherwise turn NaN into +s).
                *o = x;
                continue;
            }
            let p = (x.abs() / s).min(1.0);
            let u = self.unit(ctx.step, ctx.worker as u64, ctx.layer as u64, i as u64);
            *o = if u < p { if x < 0.0 { -s } else { s } } else { 0.0 };
        }
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        // Symbols are already at gradient scale: only averaging remains.
        unscale_in_place(reduced, 0, ctx.world, ctx.average);
    }
    fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
        if ctx.fp32_passthrough || encoded.iter().any(|v| !v.is_finite()) {
            // Dense full-precision layers — and layers carrying divergence
            // (NaN/INF has no 2-bit symbol; the packed wire ships such a
            // layer as raw f32, and the cost accounting must match it).
            return WireCost::dense(encoded.len(), FpFormat::FP32);
        }
        // A packed deployment ships one 2-bit symbol per element; the
        // per-layer scale exponent already rides the prepare phase.
        WireCost { value_bits: 2 * encoded.len() as u64, index_bits: 0, metadata_bytes: 0 }
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        if ctx.fp32_passthrough {
            out.pack_raw_f32(encoded);
            return;
        }
        // Symbols are exactly {0, +s, −s}: 2 bits each (code 3 unused).
        // Packed optimistically in a single pass; a non-finite value
        // (divergence has no 2-bit symbol) aborts into the raw-f32
        // escape, so the common all-finite layer is never rescanned.
        out.reset(wire::TAG_TERNARY, encoded.len());
        let mut w = BitWriter::new(out.bytes_mut());
        let mut diverged = false;
        for &v in encoded {
            if !v.is_finite() {
                diverged = true;
                break;
            }
            let code = if v == 0.0 {
                0
            } else if v > 0.0 {
                1
            } else {
                2
            };
            w.put(code, 2);
        }
        let bits = w.finish();
        if diverged {
            out.pack_raw_f32(encoded);
            return;
        }
        out.set_bits(bits, 0);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        if packed.tag() == wire::TAG_RAW_F32 {
            packed.unpack_raw_f32(range, out);
            return;
        }
        debug_assert_eq!(packed.tag(), wire::TAG_TERNARY);
        // The same scale expression encode used — bit-identical symbols.
        let s = crate::aps::ldexp_f32(1.0, ctx.factor_exp);
        // Bulk multi-word extraction of the 2-bit symbols in
        // stack-resident batches (no allocation) — bit-identical to the
        // scalar BitReader loop this replaced.
        let mut codes = [0u32; 128];
        let mut off = range.start as u64 * 2;
        for blk in out.chunks_mut(codes.len()) {
            let codes = &mut codes[..blk.len()];
            packed.read_bits_at_many(off, 2, codes);
            for (o, &code) in blk.iter_mut().zip(codes.iter()) {
                *o = match code {
                    0 => 0.0,
                    1 => s,
                    _ => -s,
                };
            }
            off += blk.len() as u64 * 2;
        }
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(*self))
    }
}

/// Top-k magnitude sparsification (Deep Gradient Compression-style).
///
/// Each worker keeps its `frac` largest-magnitude elements per layer
/// (at least one; magnitude ties break to the lowest index) at full FP32
/// precision and zeroes the rest; the dense sum then averages as usual.
/// Dropped elements show up in the [`crate::aps::SyncReport`] as wire
/// underflow — exactly what they are from the optimizer's point of view.
/// Deterministic (total-order selection, no RNG), so sessions replay
/// bit-identically. The simulated reduction runs over dense FP32
/// buffers; the `(index, value)` pairs a real deployment ships are
/// accounted by [`SyncStrategy::wire_cost`] (32 value bits plus
/// `⌈log2 n⌉` index bits per survivor).
#[derive(Clone, Debug)]
pub struct TopKStrategy {
    frac: f32,
    /// `(|value|, index)` pairs for survivor selection, reused across
    /// steps. Selecting on pairs pins the survivor *set* directly, so
    /// encode does one fill + one select + one k-element scatter instead
    /// of fill + select + a full-layer threshold re-scan.
    scratch: Vec<(f32, u32)>,
}

impl TopKStrategy {
    pub fn new(frac: f32) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "top-k fraction must be in (0, 1]");
        TopKStrategy { frac, scratch: Vec::new() }
    }
}

impl SyncStrategy for TopKStrategy {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::FP32
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        if ctx.fp32_passthrough {
            // fp32-last-layer policy: the protected layer stays dense
            // (top-k's wire is FP32 everywhere, so the explicit flag is
            // the only way to see the policy).
            out.copy_from_slice(src);
            return;
        }
        let n = src.len();
        if n == 0 {
            return;
        }
        let k = ((self.frac as f64 * n as f64).ceil() as usize).clamp(1, n);
        if k == n {
            out.copy_from_slice(src);
            return;
        }
        // One fill + one select on (magnitude, index) pairs. The index
        // tiebreak makes the comparator a total order with no equal
        // elements, so the k survivors are a pure function of the input
        // (not of selection internals) and replay stays bit-stable.
        self.scratch.clear();
        self.scratch.extend(src.iter().enumerate().map(|(i, &x)| (x.abs(), i as u32)));
        self.scratch
            .select_nth_unstable_by(k - 1, |a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        out.fill(0.0);
        for &(_, i) in &self.scratch[..k] {
            out[i as usize] = src[i as usize];
        }
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        unscale_in_place(reduced, 0, ctx.world, ctx.average);
    }
    fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
        if ctx.fp32_passthrough {
            return WireCost::dense(encoded.len(), FpFormat::FP32);
        }
        // Honest sparse accounting: each survivor ships its FP32 value
        // plus a position index wide enough to address the layer.
        // Survivors are the bit-nonzero entries (−0.0 and NaN included —
        // the packed wire must reproduce their exact bits, so they ship).
        let nnz = encoded.iter().filter(|v| v.to_bits() != 0).count() as u64;
        let iw = index_width(encoded.len()) as u64;
        WireCost { value_bits: 32 * nnz, index_bits: iw * nnz, metadata_bytes: 0 }
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        if ctx.fp32_passthrough {
            out.pack_raw_f32(encoded);
            return;
        }
        // Layout: ascending fixed-width indices, then 32-bit raw values
        // (NaN payloads and −0.0 survive bit-exactly).
        let iw = index_width(encoded.len());
        out.reset(wire::TAG_SPARSE, encoded.len());
        let mut w = BitWriter::new(out.bytes_mut());
        for (i, v) in encoded.iter().enumerate() {
            if v.to_bits() != 0 {
                w.put(i as u32, iw);
            }
        }
        let ibits = w.bits();
        for v in encoded {
            if v.to_bits() != 0 {
                w.put(v.to_bits(), 32);
            }
        }
        let total = w.finish();
        out.set_bits(total - ibits, ibits);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        _ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        if packed.tag() == wire::TAG_RAW_F32 {
            packed.unpack_raw_f32(range, out);
            return;
        }
        debug_assert_eq!(packed.tag(), wire::TAG_SPARSE);
        let iw = index_width(packed.elems()) as u64;
        let nnz = packed.value_bits() / 32;
        out.fill(0.0);
        // Binary search the sorted index stream for the first survivor in
        // range, then scatter values until we leave it.
        let (mut lo, mut hi) = (0u64, nnz);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if (packed.read_bits_at(mid * iw, iw as u32) as usize) < range.start {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        let vbase = nnz * iw;
        for j in lo..nnz {
            let idx = packed.read_bits_at(j * iw, iw as u32) as usize;
            if idx >= range.end {
                break;
            }
            out[idx - range.start] = f32::from_bits(packed.read_bits_at(vbase + j * 32, 32));
        }
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(TopKStrategy::new(self.frac)))
    }
}

/// Fixed tree block for the QSGD bucket-norm scan: per-block finite
/// maxima combined in ascending block order. Compile-time so the combine
/// tree is a function of the data layout alone — never of the thread
/// count or the configured bucket size.
const QSGD_NORM_BLOCK: usize = 1024;

/// Leaf of the bucket-norm tree: max `|x|` over the block's *finite*
/// entries (non-finite values carry no representable magnitude).
fn finite_block_max(blk: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &x in blk {
        let a = x.abs();
        if a.is_finite() && a > m {
            m = a;
        }
    }
    m
}

/// Exact, associative max combine — no rounding, so the tree reduction
/// equals the serial scan bit-for-bit at any thread count.
fn exact_max(a: f32, b: f32) -> f32 {
    if b > a {
        b
    } else {
        a
    }
}

/// QSGD-style bucketed stochastic quantization (Alistarh et al.).
///
/// Each layer is cut into buckets of `bucket` elements. Within a bucket
/// the worker takes its max magnitude `m`, splits `[0, m]` into
/// `s = 2^(bits-1) - 1` levels, and stochastically rounds each `|g|·s/m`
/// to a neighbouring integer level so the symbol is unbiased
/// (`E[symbol] = g`). The wire value is `sign · level · m/s`; the
/// per-bucket scale `m` rides as 4 metadata bytes. Levels are
/// deterministic in `(seed, step, worker, layer, element)`, so runs
/// replay bit-identically. Scales are per-worker (no agreement phase),
/// and the simulated reduction sums the reconstructed values on a dense
/// FP32 wire; [`SyncStrategy::wire_cost`] accounts the packed
/// `bits`-per-element payload plus the bucket scales. Under the
/// fp32-last-layer policy the protected layer passes through dense.
#[derive(Clone, Debug)]
pub struct QsgdStrategy {
    bits: u8,
    bucket: usize,
    seed: u64,
    /// Per-element integer levels of the last encoded layer — the packed
    /// wire ships these directly instead of re-deriving them from the
    /// reconstructed f32 values (reused scratch, one byte per element).
    pack_levels: Vec<u8>,
    /// Per-bucket max-magnitude scales of the last encoded layer (the
    /// packed wire's metadata side channel).
    pack_scales: Vec<f32>,
}

impl QsgdStrategy {
    pub fn new(bits: u8, bucket: usize, seed: u64) -> Self {
        assert!(
            (2..=8).contains(&bits),
            "qsgd bits must be in 2..=8 (sign + at least one magnitude bit)"
        );
        assert!(bucket >= 1, "qsgd bucket size must be positive");
        QsgdStrategy { bits, bucket, seed, pack_levels: Vec::new(), pack_scales: Vec::new() }
    }

    /// Quantization levels per sign (`2^(bits-1) - 1`).
    fn levels(&self) -> u32 {
        (1u32 << (self.bits - 1)) - 1
    }

    /// One uniform draw in `[0, 1)` from the stream position. The seed is
    /// domain-separated from ternary's stream, so `qsgd` and `ternary`
    /// configured with the same user seed stay uncorrelated.
    fn unit(&self, step: u64, worker: u64, layer: u64, elem: u64) -> f32 {
        const QSGD_STREAM: u64 = 0x5147_5344_5354_524D; // "QGSD STRM" domain tag
        unit_draw(self.seed ^ QSGD_STREAM, step, worker, layer, elem)
    }
}

impl SyncStrategy for QsgdStrategy {
    fn name(&self) -> &'static str {
        "qsgd"
    }
    fn wire_format(&self) -> FpFormat {
        FpFormat::FP32
    }
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        if ctx.fp32_passthrough {
            out.copy_from_slice(src);
            return;
        }
        let s_levels = self.levels() as f32;
        // Reset the packed-wire caches for this layer (levels default 0).
        self.pack_scales.clear();
        self.pack_levels.clear();
        self.pack_levels.resize(src.len(), 0);
        for (b, (seg, oseg)) in
            src.chunks(self.bucket).zip(out.chunks_mut(self.bucket)).enumerate()
        {
            let base = b * self.bucket;
            // Bucket scale: max magnitude over the *finite* entries, as
            // a fixed-block tree reduction (threads engage only on huge
            // buckets; either way the result is the serial scan's,
            // bit-for-bit, because exact max is associative).
            let max_abs = par::par_block_reduce(
                seg,
                QSGD_NORM_BLOCK,
                par::reduce_threads(seg.len()),
                finite_block_max,
                exact_max,
            )
            .unwrap_or(0.0);
            self.pack_scales.push(max_abs);
            if max_abs == 0.0 {
                // Nothing representable: ship zeros, propagate divergence.
                for (&x, o) in seg.iter().zip(oseg.iter_mut()) {
                    *o = if x.is_finite() { 0.0 } else { x };
                }
                continue;
            }
            let unit_scale = max_abs / s_levels;
            for (j, (&x, o)) in seg.iter().zip(oseg.iter_mut()).enumerate() {
                if x == 0.0 {
                    *o = 0.0;
                    continue;
                }
                if !x.is_finite() {
                    *o = x;
                    continue;
                }
                // r ∈ [0, s]: |x|/max_abs ≤ 1.0 exactly in f32, and
                // multiplying by the (small-integer) level count cannot
                // round past s.
                let r = (x.abs() / max_abs) * s_levels;
                let level = r.floor();
                let frac = r - level;
                let u = self.unit(ctx.step, ctx.worker as u64, ctx.layer as u64, (base + j) as u64);
                let q = level + if u < frac { 1.0 } else { 0.0 };
                self.pack_levels[base + j] = q as u8; // q ≤ 127 by bits ≤ 8
                let v = q * unit_scale;
                *o = if x < 0.0 { -v } else { v };
            }
        }
    }
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        // Wire values are already at gradient scale: only averaging.
        unscale_in_place(reduced, 0, ctx.world, ctx.average);
    }
    fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
        if ctx.fp32_passthrough || encoded.iter().any(|v| !v.is_finite()) {
            // Divergent layers have no sign+level code; the packed wire
            // ships them raw, and the accounting must match.
            return WireCost::dense(encoded.len(), FpFormat::FP32);
        }
        let n = encoded.len();
        let buckets = n.div_ceil(self.bucket) as u64;
        WireCost {
            value_bits: n as u64 * self.bits as u64,
            index_bits: 0,
            metadata_bytes: 4 * buckets,
        }
    }
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        if ctx.fp32_passthrough {
            out.pack_raw_f32(encoded);
            return;
        }
        debug_assert_eq!(
            self.pack_levels.len(),
            encoded.len(),
            "encode_packed must follow encode on the same layer"
        );
        // sign ‖ level, `bits` per element; per-bucket scales as metadata.
        // Packed optimistically in one pass; a non-finite value (no
        // sign+level code exists for divergence) aborts into the raw-f32
        // escape — the common all-finite layer is never rescanned.
        let bits = self.bits as u32;
        out.reset(wire::TAG_QSGD, encoded.len());
        for &m in &self.pack_scales {
            out.push_meta_f32(m);
        }
        let levels = std::mem::take(&mut self.pack_levels);
        let mut w = BitWriter::new(out.bytes_mut());
        let mut diverged = false;
        for (&v, &lvl) in encoded.iter().zip(&levels) {
            if !v.is_finite() {
                diverged = true;
                break;
            }
            let sign = (v.is_sign_negative() as u32) << (bits - 1);
            w.put(sign | lvl as u32, bits);
        }
        let vbits = w.finish();
        self.pack_levels = levels;
        if diverged {
            // pack_raw_f32 resets the buffer (metadata included).
            out.pack_raw_f32(encoded);
            return;
        }
        out.set_bits(vbits, 0);
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        _ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        if packed.tag() == wire::TAG_RAW_F32 {
            packed.unpack_raw_f32(range, out);
            return;
        }
        debug_assert_eq!(packed.tag(), wire::TAG_QSGD);
        let bits = self.bits as u32;
        let s_levels = self.levels() as f32;
        let lvl_mask = (1u32 << (bits - 1)) - 1;
        let mut r = BitReader::at(packed.bytes(), range.start as u64 * bits as u64);
        let mut bucket_idx = usize::MAX;
        let mut unit_scale = 0.0f32;
        for (k, o) in out.iter_mut().enumerate() {
            let b = (range.start + k) / self.bucket;
            if b != bucket_idx {
                bucket_idx = b;
                // the exact expression encode used → identical products
                unit_scale = packed.meta_f32(b) / s_levels;
            }
            let code = r.read(bits);
            let v = (code & lvl_mask) as f32 * unit_scale;
            *o = if code >> (bits - 1) == 1 { -v } else { v };
        }
    }
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        Some(self)
    }
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        Some(Box::new(QsgdStrategy::new(self.bits, self.bucket, self.seed)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::RingCollective;
    use crate::cpd::Rounding;

    fn ctx(fmt: FpFormat, factor_exp: i32, world: usize) -> LayerCtx {
        LayerCtx {
            layer: 0,
            num_layers: 1,
            worker: 0,
            world,
            factor_exp,
            fmt,
            fp32_passthrough: false,
            rounding: Rounding::NearestEven,
            average: true,
            step: 0,
        }
    }

    #[test]
    fn fp32_passthrough_keeps_codec_layers_dense() {
        let src = vec![0.25f32, -0.125, 0.5, -1.0];
        let c = LayerCtx { fp32_passthrough: true, ..ctx(FpFormat::FP32, 0, 4) };
        let mut out = vec![0.0f32; 4];
        TernaryStrategy::new(3).encode(&src, &c, &mut out);
        assert_eq!(out, src);
        let mut out = vec![0.0f32; 4];
        TopKStrategy::new(0.25).encode(&src, &c, &mut out);
        assert_eq!(out, src);
        let mut out = vec![0.0f32; 4];
        QsgdStrategy::new(4, 2, 3).encode(&src, &c, &mut out);
        assert_eq!(out, src);
    }

    #[test]
    fn ternary_propagates_non_finite_gradients() {
        let mut t = TernaryStrategy::new(1);
        let src = vec![f32::NAN, f32::INFINITY, 0.5, -0.5];
        let mut out = vec![0.0f32; 4];
        t.encode(&src, &ctx(FpFormat::BF16, 0, 4), &mut out);
        assert!(out[0].is_nan(), "NaN must stay visible on the wire");
        assert!(out[1].is_infinite());
        assert!(out[2] == 0.0 || out[2] == 1.0);
    }

    #[test]
    fn ternary_symbols_are_ternary_and_unbiased_ish() {
        let mut t = TernaryStrategy::new(7);
        let grads = vec![vec![vec![0.3f32; 2000]]];
        let view = GradView::new(&grads);
        let coll = RingCollective::new(1);
        let mut factors = Factors::default();
        factors.reset(1);
        t.prepare(&view, &coll, &mut factors);
        let e = factors.exp(0);
        // ceil(log2 0.3) = -1 → s = 0.5
        assert_eq!(e, -1);
        let s = 0.5f32;
        let mut out = vec![0.0f32; 2000];
        let c = ctx(t.wire_format(), e, 1);
        t.encode(&grads[0][0], &c, &mut out);
        let mut mean = 0.0f64;
        for &o in &out {
            assert!(o == 0.0 || o == s || o == -s, "symbol {o}");
            mean += o as f64;
        }
        mean /= out.len() as f64;
        // E[symbol] = 0.3; loose 3-sigma-ish bound for 2000 draws.
        assert!((mean - 0.3).abs() < 0.04, "mean {mean}");
    }

    #[test]
    fn ternary_is_deterministic_per_stream() {
        let mut t = TernaryStrategy::new(9);
        let src = vec![0.1f32, -0.2, 0.05, 0.7];
        let c = ctx(FpFormat::BF16, 0, 4);
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        t.encode(&src, &c, &mut a);
        t.encode(&src, &c, &mut b);
        assert_eq!(a, b);
        // a different worker gets a different stream
        let c2 = LayerCtx { worker: 1, ..c };
        let mut w1 = vec![0.0f32; 4];
        t.encode(&src, &c2, &mut w1);
        let _ = w1; // may or may not differ element-wise; just must run
    }

    #[test]
    fn topk_keeps_largest_magnitudes_exactly() {
        let mut t = TopKStrategy::new(0.5);
        let src = vec![0.1f32, -4.0, 0.01, 2.0, -0.5, 0.0];
        let mut out = vec![0.0f32; 6];
        t.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out, vec![0.0, -4.0, 0.0, 2.0, -0.5, 0.0]);
        // survivors are bitwise the source values
        assert_eq!(out[1].to_bits(), src[1].to_bits());
    }

    #[test]
    fn topk_always_keeps_at_least_one() {
        let mut t = TopKStrategy::new(0.01);
        let src = vec![1.0f32, 2.0, 3.0];
        let mut out = vec![0.0f32; 3];
        t.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 1);
        assert_eq!(out[2], 3.0);
    }

    #[test]
    fn topk_breaks_magnitude_ties_to_the_lowest_index() {
        // Four elements, k = 2, with a three-way magnitude tie: the
        // index tiebreak keeps exactly k survivors — the lowest-indexed
        // ties — as a pure function of the input.
        let mut t = TopKStrategy::new(0.5);
        let src = vec![1.0f32, -1.0, 1.0, 0.5];
        let mut out = vec![9.0f32; 4];
        t.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn qsgd_norm_tree_matches_serial_scan() {
        // The fixed-block tree over a nasty bucket (non-finites, exact
        // ties, subnormals) must reproduce the serial finite-max scan.
        let seg: Vec<f32> = (0..5000)
            .map(|i| match i % 7 {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => -1e-40,
                _ => ((i * 37) % 101) as f32 * 0.125 - 6.0,
            })
            .collect();
        let mut serial = 0.0f32;
        for &x in &seg {
            let a = x.abs();
            if a.is_finite() && a > serial {
                serial = a;
            }
        }
        for threads in [1usize, 2, 4, 8] {
            let tree =
                par::par_block_reduce(&seg, QSGD_NORM_BLOCK, threads, finite_block_max, exact_max)
                    .unwrap();
            assert_eq!(tree.to_bits(), serial.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn topk_wire_cost_counts_survivors_and_indices() {
        let t = TopKStrategy::new(0.5);
        let c = ctx(FpFormat::FP32, 0, 2);
        // 3 nonzeros in a 6-element layer → 3×32 value bits + 3×3 index bits
        let encoded = vec![0.0f32, -4.0, 0.0, 2.0, -0.5, 0.0];
        let cost = t.wire_cost(&encoded, &c);
        assert_eq!(cost.value_bits, 96);
        assert_eq!(cost.index_bits, 9);
        assert_eq!(cost.metadata_bytes, 0);
        // passthrough layers are accounted dense
        let pass = LayerCtx { fp32_passthrough: true, ..c };
        assert_eq!(t.wire_cost(&encoded, &pass), WireCost::dense(6, FpFormat::FP32));
    }

    #[test]
    fn qsgd_symbols_live_on_the_bucket_grid() {
        let mut q = QsgdStrategy::new(4, 4, 7); // s = 7 levels
        let src = vec![0.7f32, -0.35, 0.1, 0.0, 100.0, -25.0, 1.0, 12.5];
        let mut out = vec![f32::NAN; 8];
        q.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        for (b, seg) in out.chunks(4).enumerate() {
            let max_abs =
                src[b * 4..b * 4 + 4].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let unit = max_abs / 7.0;
            for (j, &o) in seg.iter().enumerate() {
                let k = o / unit;
                assert!(
                    (k - k.round()).abs() < 1e-4 && k.abs() <= 7.0 + 1e-4,
                    "bucket {b} elem {j}: {o} is not a grid multiple of {unit}"
                );
                // sign preserved, magnitude never above the bucket max
                let x = src[b * 4 + j];
                assert!(o == 0.0 || (o < 0.0) == (x < 0.0));
                assert!(o.abs() <= max_abs * (1.0 + 1e-6));
            }
        }
    }

    #[test]
    fn qsgd_is_unbiased_ish_and_deterministic() {
        let n = 4000;
        // one max anchor at 0.3, the rest mid-level at 0.05: r = 0.5 sits
        // between levels 0 and 1, so rounding is genuinely stochastic
        let mut src = vec![0.05f32; n];
        src[0] = 0.3;
        // one big bucket: max = 0.3 → levels at 0.1·k for bits=3 (s=3)
        let mut q = QsgdStrategy::new(3, 4096, 11);
        let c = ctx(FpFormat::FP32, 0, 1);
        let mut a = vec![0.0f32; n];
        q.encode(&src, &c, &mut a);
        let mut b = vec![0.0f32; n];
        q.encode(&src, &c, &mut b);
        assert_eq!(a, b, "same stream position → same symbols");
        let mean = a[1..].iter().map(|&v| v as f64).sum::<f64>() / (n - 1) as f64;
        assert!((mean - 0.05).abs() < 0.005, "E[symbol] should be ≈ 0.05, got {mean}");
        assert!(a[1..].iter().any(|&v| v == 0.0) && a[1..].iter().any(|&v| v != 0.0));
        // all values exactly on the 3-level grid, max level included
        for &v in &a {
            let k = v / 0.1;
            assert!((k - k.round()).abs() < 1e-4 && (-1e-4..=3.0 + 1e-4).contains(&k), "{v}");
        }
    }

    #[test]
    fn qsgd_handles_non_finite_and_zero_buckets() {
        let mut q = QsgdStrategy::new(2, 2, 5);
        let src = vec![0.0f32, 0.0, f32::NAN, 0.0, f32::INFINITY, 1.0];
        let mut out = vec![7.0f32; 6];
        q.encode(&src, &ctx(FpFormat::FP32, 0, 2), &mut out);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 0.0);
        assert!(out[2].is_nan(), "NaN stays visible on the wire");
        assert_eq!(out[3], 0.0);
        assert!(out[4].is_infinite());
        assert!(out[5] == 0.0 || out[5] == 1.0);
    }

    #[test]
    fn qsgd_wire_cost_counts_bits_and_bucket_scales() {
        let q = QsgdStrategy::new(4, 64, 1);
        let c = ctx(FpFormat::FP32, 0, 2);
        let encoded = vec![0.5f32; 200]; // 200 elems → 4 buckets of ≤64
        let cost = q.wire_cost(&encoded, &c);
        assert_eq!(cost.value_bits, 800);
        assert_eq!(cost.index_bits, 0);
        assert_eq!(cost.metadata_bytes, 16);
        assert_eq!(cost.total_bytes(), 116);
    }

    #[test]
    #[should_panic(expected = "qsgd bits")]
    fn qsgd_rejects_degenerate_bit_width() {
        let _ = QsgdStrategy::new(1, 64, 0);
    }

    #[test]
    fn ternary_wire_cost_is_two_bits_per_element() {
        let t = TernaryStrategy::new(1);
        let c = ctx(FpFormat::BF16, 0, 4);
        let cost = t.wire_cost(&[0.5, 0.0, -0.5, 0.5], &c);
        assert_eq!(cost, WireCost { value_bits: 8, index_bits: 0, metadata_bytes: 0 });
        // divergent layers cost (and ship) dense FP32 — the raw escape
        let cost = t.wire_cost(&[0.5, f32::NAN, -0.5, 0.5], &c);
        assert_eq!(cost, WireCost::dense(4, FpFormat::FP32));
    }

    #[test]
    fn ternary_packs_two_bit_symbols_exactly() {
        let mut t = TernaryStrategy::new(1);
        let c = ctx(FpFormat::BF16, -1, 4); // s = 0.5
        let encoded = vec![0.5f32, 0.0, -0.5, 0.5, 0.0, -0.5, 0.5];
        let mut pw = PackedWire::default();
        t.encode_packed(&encoded, &c, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_TERNARY);
        assert_eq!(pw.moved_cost(), t.wire_cost(&encoded, &c));
        assert_eq!(pw.packed_len(), 2); // 14 bits → 2 bytes
        let mut out = vec![9.0f32; 7];
        t.decode_packed(&pw, &c, 0..7, &mut out);
        assert_eq!(out, encoded);
        // ranged decode across the byte boundary
        let mut seg = vec![0.0f32; 3];
        t.decode_packed(&pw, &c, 3..6, &mut seg);
        assert_eq!(seg, &encoded[3..6]);
        // non-finite layers escape to raw f32 and stay bit-exact
        let diverged = vec![0.5f32, f32::INFINITY, f32::NAN];
        t.encode_packed(&diverged, &c, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_RAW_F32);
        assert_eq!(pw.moved_cost(), t.wire_cost(&diverged, &c));
        let mut out = vec![0.0f32; 3];
        t.decode_packed(&pw, &c, 0..3, &mut out);
        assert_eq!(out[0], 0.5);
        assert!(out[1].is_infinite() && out[2].is_nan());
    }

    #[test]
    fn qsgd_packs_sign_level_codes_and_bucket_scales() {
        let mut q = QsgdStrategy::new(4, 4, 7);
        let c = ctx(FpFormat::FP32, 0, 2);
        let src = vec![0.7f32, -0.35, 0.1, 0.0, 100.0, -25.0, 1.0, 12.5, -0.25];
        let mut encoded = vec![0.0f32; src.len()];
        q.encode(&src, &c, &mut encoded);
        let mut pw = PackedWire::default();
        q.encode_packed(&encoded, &c, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_QSGD);
        // 9 elems × 4 bits + 3 bucket scales × 4 B
        assert_eq!(
            pw.moved_cost(),
            WireCost { value_bits: 36, index_bits: 0, metadata_bytes: 12 }
        );
        assert_eq!(pw.moved_cost(), q.wire_cost(&encoded, &c));
        let mut out = vec![f32::NAN; src.len()];
        q.decode_packed(&pw, &c, 0..src.len(), &mut out);
        for (i, (a, b)) in encoded.iter().zip(&out).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: {a:e} vs {b:e}");
        }
        // ranged decode starting mid-bucket
        let mut seg = vec![0.0f32; 4];
        q.decode_packed(&pw, &c, 3..7, &mut seg);
        for (k, b) in seg.iter().enumerate() {
            assert_eq!(encoded[3 + k].to_bits(), b.to_bits(), "offset {k}");
        }
    }

    #[test]
    fn topk_packs_sparse_pairs_with_exact_value_bits() {
        let mut t = TopKStrategy::new(0.5);
        let c = ctx(FpFormat::FP32, 0, 2);
        let encoded = vec![0.0f32, -4.0, 0.0, 2.0, -0.5, 0.0];
        let mut pw = PackedWire::default();
        t.encode_packed(&encoded, &c, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_SPARSE);
        // 3 survivors × (32 value + 3 index) bits — exactly wire_cost
        assert_eq!(
            pw.moved_cost(),
            WireCost { value_bits: 96, index_bits: 9, metadata_bytes: 0 }
        );
        assert_eq!(pw.moved_cost(), t.wire_cost(&encoded, &c));
        assert_eq!(pw.packed_len(), (96 + 9u64).div_ceil(8));
        let mut out = vec![f32::NAN; 6];
        t.decode_packed(&pw, &c, 0..6, &mut out);
        assert_eq!(out, encoded);
        // sub-ranges exercise the binary search on both sides
        let mut seg = vec![f32::NAN; 2];
        t.decode_packed(&pw, &c, 4..6, &mut seg);
        assert_eq!(seg, &encoded[4..6]);
        let mut seg = vec![f32::NAN; 2];
        t.decode_packed(&pw, &c, 0..2, &mut seg);
        assert_eq!(seg, &encoded[0..2]);
    }

    #[test]
    fn topk_ships_negative_zero_and_nan_survivors_bit_exactly() {
        // An all-±0 layer keeps its -0.0 (threshold 0), and NaN always
        // survives: the sparse wire must reproduce the exact bits.
        let t = TopKStrategy::new(0.5);
        let c = ctx(FpFormat::FP32, 0, 2);
        let encoded = vec![0.0f32, -0.0, f32::NAN, 0.0];
        let cost = t.wire_cost(&encoded, &c);
        assert_eq!(cost.value_bits, 64, "-0.0 and NaN are survivors");
        let mut t2 = TopKStrategy::new(0.5);
        let mut pw = PackedWire::default();
        t2.encode_packed(&encoded, &c, &mut pw);
        assert_eq!(pw.moved_cost(), cost);
        let mut out = vec![1.0f32; 4];
        t2.decode_packed(&pw, &c, 0..4, &mut out);
        assert_eq!(out[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(out[1].to_bits(), (-0.0f32).to_bits());
        assert!(out[2].is_nan());
        assert_eq!(out[3].to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn cast_strategies_pack_format_bit_codes() {
        let mut a = ApsStrategy::new(FpFormat::E5M2);
        let c = ctx(FpFormat::E5M2, 3, 4);
        let src = vec![0.111f32, -2.5e-4, 7.0, 0.0, -0.0, 3.3e4];
        let mut encoded = vec![0.0f32; src.len()];
        a.encode(&src, &c, &mut encoded);
        let mut pw = PackedWire::default();
        a.encode_packed(&encoded, &c, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_FMT_BITS);
        assert_eq!(pw.moved_cost(), WireCost::dense(6, FpFormat::E5M2));
        let mut out = vec![f32::NAN; 6];
        a.decode_packed(&pw, &c, 0..6, &mut out);
        for (i, (x, y)) in encoded.iter().zip(&out).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}");
        }
        // FP32-wire strategies (and passthrough layers) ship raw lanes
        let mut f = Fp32Strategy;
        let cf = ctx(FpFormat::FP32, 0, 4);
        f.encode_packed(&src, &cf, &mut pw);
        assert_eq!(pw.tag(), wire::TAG_RAW_F32);
        assert_eq!(pw.moved_cost(), WireCost::dense(6, FpFormat::FP32));
    }
}

//! [`SyncSession`] — the hot-path owner of one strategy, one collective,
//! and every buffer gradient synchronization needs step after step.
//!
//! The pre-trait `aps::synchronize` free function (removed; see
//! `aps::legacy` for the pinned historical implementation) re-allocated
//! all wire tensors, the output tensors and the report on every call. A
//! session allocates them once (growing to the largest layer on first
//! use) and then runs [`SyncSession::step`] with no per-step
//! element-storage allocation — only O(world) pointer bookkeeping inside
//! the ring split. The hierarchical collective keeps its per-group
//! partials in reusable scratch, Kahan compensation lives in
//! stack-resident blocks inside the fold kernels, and the packed wire's
//! byte buffers and unpack chunks are session-owned
//! (`rust/tests/session_alloc.rs` pins the steady state with a counting
//! allocator across all of ring/hierarchical/packed/Kahan).
//!
//! Under the default [`WireMode::Packed`], each worker's encoded layer is
//! transcoded into a [`PackedWire`] (2-bit ternary symbols, QSGD
//! sign+level codes, `FpFormat`-width bit-codes, sparse pairs) and the
//! collective reduces by unpacking cache-blocked chunks — the simulated
//! traffic that moves through memory is the codec's honest `WireCost`,
//! not dense f32 lanes, while decoded gradients and reports stay
//! bit-identical to [`WireMode::Simulated`]
//! (`rust/tests/packed_wire.rs`). [`SyncSession::wire_moved`] exposes the
//! measured packed traffic.
//!
//! Reports and reduced gradients are returned by reference into
//! session-owned storage; reusing a session yields bit-identical results
//! to fresh calls (pinned by `rust/tests/strategy_layer.rs`).

use super::transport::{
    auto_bucket_bytes, BucketPlan, FaultKind, TransportError, TransportSpec, TransportTraffic,
};
use super::wire::{PackScratch, PackedWire, WireMode};
use super::{ErrorFeedback, Factors, GradView, LayerCtx, StrategySpec, SyncStrategy, WireCost};
use crate::aps::{BucketStats, LayerReport, SyncOptions, SyncReport};
use crate::collectives::{Collective, ReduceOptions, ReduceStats, Topology};
use crate::cpd::{FpFormat, Rounding};
use crate::util::par;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Builder for [`SyncSession`] (the `SyncOptions` knobs carried over,
/// plus the strategy/collective plug points).
pub struct SyncSessionBuilder {
    world: usize,
    strategy: Option<Box<dyn SyncStrategy>>,
    topology: Topology,
    collective: Option<Box<dyn Collective>>,
    rounding: Rounding,
    kahan: bool,
    average: bool,
    fp32_last_layer: bool,
    fused: bool,
    error_feedback: bool,
    wire: WireMode,
    fold_threads: usize,
    encode_threads: usize,
    transport: TransportSpec,
    bucket_bytes: usize,
    /// The spec behind `strategy`, kept when the strategy came from
    /// [`Self::spec`] — the overlap pool builds per-thread decode twins
    /// from it. A custom [`Self::strategy`] clears it (no overlap).
    retained_spec: Option<StrategySpec>,
    /// False once a custom [`Self::collective`] replaces the topology —
    /// the pool cannot replicate an arbitrary collective per thread.
    retained_topology: bool,
}

impl SyncSessionBuilder {
    /// Start a builder for `world_size` workers. Defaults: FP32 strategy,
    /// ring collective, round-to-nearest-even, averaging on, no Kahan, no
    /// fp32-last-layer, unfused messages.
    pub fn new(world_size: usize) -> Self {
        assert!(world_size >= 1);
        SyncSessionBuilder {
            world: world_size,
            strategy: None,
            topology: Topology::Ring,
            collective: None,
            rounding: Rounding::NearestEven,
            kahan: false,
            average: true,
            fp32_last_layer: false,
            fused: false,
            error_feedback: false,
            wire: WireMode::default(),
            fold_threads: 0,
            encode_threads: 0,
            transport: TransportSpec::InProcess,
            bucket_bytes: 0,
            retained_spec: None,
            retained_topology: true,
        }
    }

    /// Carry every knob of a legacy [`SyncOptions`] over (the migration
    /// path for pre-trait callers).
    pub fn from_sync_options(world_size: usize, opts: &SyncOptions) -> Self {
        SyncSessionBuilder::new(world_size)
            .spec(StrategySpec::from(opts.method))
            .with_topology(opts.topo)
            .with_rounding(opts.rounding)
            .with_kahan(opts.kahan)
            .with_average(opts.average)
            .with_fp32_last_layer(opts.fp32_last_layer)
            .with_fused(opts.fused)
    }

    /// Plug in any strategy — the open extension point. A custom boxed
    /// strategy cannot be replicated onto the overlap pool's decode
    /// twins, so [`SyncSession::step_overlapped`] falls back to the
    /// synchronous path for it (results identical either way).
    pub fn strategy(mut self, strategy: Box<dyn SyncStrategy>) -> Self {
        self.strategy = Some(strategy);
        self.retained_spec = None;
        self
    }

    /// Use a built-in strategy described by `spec`.
    pub fn spec(self, spec: StrategySpec) -> Self {
        let mut b = self.strategy(spec.build());
        b.retained_spec = Some(spec);
        b
    }

    /// Wrap the chosen strategy in [`ErrorFeedback`] (residual memory).
    /// Applied at [`Self::build`] time, so it composes with
    /// [`Self::strategy`]/[`Self::spec`] in either order; with no strategy
    /// set it wraps the FP32 default, which is a harmless no-op.
    pub fn error_feedback(mut self) -> Self {
        self.error_feedback = true;
        self
    }

    /// Plug in any collective (overrides [`Self::with_topology`]). Like
    /// a custom strategy, a custom collective disables the overlapped
    /// path (the pool builds per-thread collectives from the topology).
    pub fn collective(mut self, collective: Box<dyn Collective>) -> Self {
        self.collective = Some(collective);
        self.retained_topology = false;
        self
    }

    /// Use the built-in collective for `topo`.
    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topology = topo;
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }

    pub fn with_kahan(mut self, kahan: bool) -> Self {
        self.kahan = kahan;
        self
    }

    pub fn with_average(mut self, yes: bool) -> Self {
        self.average = yes;
        self
    }

    pub fn with_fp32_last_layer(mut self, yes: bool) -> Self {
        self.fp32_last_layer = yes;
        self
    }

    /// Lazy all-reduce: account all layers as one fused message.
    pub fn with_fused(mut self, yes: bool) -> Self {
        self.fused = yes;
        self
    }

    /// Choose how wire traffic is materialized: [`WireMode::Packed`]
    /// (default — bit-packed buffers, payload-proportional simulated
    /// traffic) or [`WireMode::Simulated`] (legacy dense f32 lanes).
    /// Results are bit-identical either way.
    pub fn with_wire(mut self, mode: WireMode) -> Self {
        self.wire = mode;
        self
    }

    /// Cap the packed-fold thread count: `0` (default) sizes the pool
    /// automatically (single-threaded below the parallel threshold), any
    /// explicit `k` is honored exactly — `1` forces the single-threaded
    /// fold, `k > 1` forces a `k`-way split even on small layers. Results
    /// are bit-identical for every value (the split only regroups whole
    /// ring chunks / hierarchical groups onto threads; each element's fold
    /// chain is unchanged — pinned by `rust/tests/packed_parallel.rs`).
    ///
    /// The consumer-side half of the thread budget; the producer side is
    /// [`Self::with_encode_threads`]. In config files the pair is spelled
    /// `sync.threads = { fold, encode }` (the old flat `sync.fold_threads`
    /// key is still parsed as an alias).
    pub fn with_fold_threads(mut self, k: usize) -> Self {
        self.fold_threads = k;
        self
    }

    /// Cap the per-worker encode fan-out thread count — the producer-side
    /// mirror of [`Self::with_fold_threads`]. `0` (default) sizes the
    /// fan-out automatically per layer (single-threaded below the
    /// reduction-scan threshold), `1` forces the classic serial encode
    /// loop byte-for-byte (no twin pool is built at all), and `k > 1`
    /// forces a `k`-way split over workers even on small layers.
    ///
    /// Parallel encoding routes every worker's encode→
    /// [`SyncStrategy::encode_packed`] chain through that worker's
    /// dedicated *encode twin* (see [`SyncStrategy::parallel_encoder`]) —
    /// the whole chain stays on one thread and worker `w` maps to twin
    /// `w` forever, so stateful codecs (error-feedback residuals, QSGD
    /// draws) evolve exactly as in the serial loop and results are
    /// bit-identical at every thread count (pinned by
    /// `rust/tests/encode_parallel.rs`). Strategies that return `None`
    /// from [`SyncStrategy::parallel_encoder`] (third-party codecs that
    /// have not opted in) keep the serial loop regardless of this knob.
    pub fn with_encode_threads(mut self, k: usize) -> Self {
        self.encode_threads = k;
        self
    }

    /// Choose the [`Transport`](super::transport::Transport) the
    /// overlapped path moves packed bytes through (default:
    /// [`TransportSpec::InProcess`]). Only
    /// [`SyncSession::step_overlapped`] uses it; [`SyncSession::step`]
    /// is transport-free.
    pub fn with_transport(mut self, spec: TransportSpec) -> Self {
        self.transport = spec;
        self
    }

    /// Bucket fusion size for [`SyncSession::step_overlapped`] in dense
    /// f32 bytes: `0` (default) auto-sizes from the model footprint and
    /// pool width, `1` degenerates to one bucket per layer, a huge value
    /// fuses the whole model into one bucket. Reduced gradients are
    /// bit-identical for every value.
    pub fn with_bucket_bytes(mut self, bytes: usize) -> Self {
        self.bucket_bytes = bytes;
        self
    }

    pub fn build(self) -> SyncSession {
        let world = self.world;
        let collective = self.collective.unwrap_or_else(|| match self.topology {
            // The parameter server owns its transport (the push/pull
            // legs move real octets through it), so the builder's
            // `with_transport` choice reaches it here rather than via
            // the overlap pool.
            Topology::Ps { shards, staleness } => Box::new(
                super::ps::PsCollective::new(world, shards, staleness)
                    .with_transport(self.transport),
            ),
            _ => self.topology.collective(world),
        });
        assert_eq!(collective.world_size(), world, "collective world size mismatch");
        let mut strategy = self.strategy.unwrap_or_else(|| StrategySpec::Fp32.build());
        // Idempotent: a strategy that is already error-feedback-wrapped
        // (an `ef:` spec from config) is left alone — double residual
        // memory is never what the caller wants. Matches exactly the
        // names ErrorFeedback::name() can produce, so a custom codec
        // whose name merely begins with "ef" still gets wrapped.
        let already_wrapped =
            strategy.name() == "ef" || strategy.name().starts_with("ef:");
        if self.error_feedback && !already_wrapped {
            strategy = Box::new(ErrorFeedback::new(strategy));
        }
        // The overlapped path needs per-thread decode twins (spec) and
        // per-thread collectives (topology), and only the packed wire
        // moves bytes a transport can ship. Anything else falls back to
        // the synchronous path. Error feedback needs no special casing:
        // `decode_packed` forwards purely to the inner codec, so a
        // plain-spec twin decodes EF frames bit-identically.
        let overlap_cfg = match (&self.retained_spec, self.retained_topology, self.wire) {
            // The PS collective is stateful across rounds (staleness
            // queues, round clock); per-thread twins would fork that
            // state, so PS never overlaps — `step_overlapped` falls
            // back to the synchronous path automatically.
            _ if matches!(self.topology, Topology::Ps { .. }) => None,
            (Some(spec), true, WireMode::Packed) => Some(OverlapCfg {
                spec: spec.clone(),
                topology: self.topology,
                transport: self.transport,
            }),
            _ => None,
        };
        let encode = build_encode_pool(strategy.as_ref(), world, self.encode_threads);
        SyncSession {
            strategy,
            collective,
            rounding: self.rounding,
            kahan: self.kahan,
            average: self.average,
            fp32_last_layer: self.fp32_last_layer,
            fused: self.fused,
            wire_mode: self.wire,
            factors: Factors::default(),
            wire: Vec::new(),
            stage: Vec::new(),
            packed: Vec::new(),
            pack_scratch: PackScratch { max_threads: self.fold_threads, ..PackScratch::default() },
            encode,
            encode_threads: self.encode_threads,
            moved: None,
            reduced: Vec::new(),
            report: SyncReport::default(),
            steps_done: 0,
            bucket_bytes: self.bucket_bytes,
            overlap_cfg,
            overlap: None,
        }
    }
}

impl Default for SyncSessionBuilder {
    /// Single-worker FP32 session (mostly useful in tests).
    fn default() -> Self {
        SyncSessionBuilder::new(1)
    }
}

/// A long-lived gradient-synchronization pipeline: strategy + collective
/// + reusable scratch. See the module docs.
pub struct SyncSession {
    strategy: Box<dyn SyncStrategy>,
    collective: Box<dyn Collective>,
    rounding: Rounding,
    kahan: bool,
    average: bool,
    fp32_last_layer: bool,
    fused: bool,
    wire_mode: WireMode,
    factors: Factors,
    /// Per-worker dense wire buffers for the layer currently in flight —
    /// the [`WireMode::Simulated`] path (capacity grows to the largest
    /// layer, then stays).
    wire: Vec<Vec<f32>>,
    /// One shared encode-staging buffer for the packed path (each
    /// worker's f32 wire values exist only transiently here before being
    /// transcoded into its [`PackedWire`]).
    stage: Vec<f32>,
    /// Per-worker packed byte buffers — what the packed reduction
    /// actually consumes.
    packed: Vec<PackedWire>,
    /// Unpack scratch the collectives borrow during packed reductions.
    pack_scratch: PackScratch,
    /// The per-worker encode-twin lanes ([`SyncSessionBuilder::with_encode_threads`]);
    /// `None` keeps the classic serial encode loop (explicit
    /// `encode_threads == 1`, world 1, or a strategy that does not opt
    /// into [`SyncStrategy::parallel_encoder`]).
    encode: Option<EncodePool>,
    /// The builder's encode-thread knob, kept so [`Self::set_strategy`]
    /// can rebuild the pool for the replacement codec.
    encode_threads: usize,
    /// Measured packed traffic of the last step (None in simulated mode).
    moved: Option<WireCost>,
    /// Per-layer reduced gradients (the step output).
    reduced: Vec<Vec<f32>>,
    report: SyncReport,
    steps_done: u64,
    /// Bucket fusion size for the overlapped path (0 = auto).
    bucket_bytes: usize,
    /// What the overlap pool needs to replicate per thread; `None` when
    /// the session cannot overlap (custom strategy/collective or
    /// simulated wire) and `step_overlapped` falls back to `step`.
    overlap_cfg: Option<OverlapCfg>,
    /// The lazily spawned worker pool (first `step_overlapped` call).
    overlap: Option<OverlapState>,
}

/// Everything a pool thread rebuilds for itself: the decode twin, the
/// collective, and the transport. All plain data, so spawning moves
/// only values into the thread.
#[derive(Clone)]
struct OverlapCfg {
    spec: StrategySpec,
    topology: Topology,
    transport: TransportSpec,
}

/// One layer's fold job, shipped to a pool thread by value and shipped
/// back with the reduced output. Buffer ownership round-trips through
/// the channels, so the steady state allocates nothing.
struct LayerWork {
    layer: usize,
    /// The fold-time ctx, `worker == world - 1` exactly as `step()`
    /// leaves it after the encode loop.
    ctx: LayerCtx,
    ropts: ReduceOptions,
    /// Per-worker packed contributions for this layer.
    packed: Vec<PackedWire>,
    /// The reduced output (taken from `reduced[layer]`, returned at
    /// drain).
    out: Vec<f32>,
    stats: ReduceStats,
}

/// One bucket in flight: its layers' work plus per-bucket timing filled
/// in by the pool thread. Exactly one `BucketMsg` comes back per bucket
/// launched, error or not.
struct BucketMsg {
    bucket: usize,
    work: Vec<LayerWork>,
    sent: Instant,
    transit_ns: u64,
    fold_ns: u64,
    wait_ns: u64,
    octets: u64,
    err: Option<TransportError>,
}

enum WorkerMsg {
    Bucket(BucketMsg),
    /// Forward a fault injection to the thread's transport.
    Kill(usize),
}

/// The session side of the persistent worker pool.
struct OverlapState {
    threads: usize,
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    results: mpsc::Receiver<BucketMsg>,
    plan: BucketPlan,
    /// Recycled per-layer packed-contribution sets.
    packed_pool: Vec<Vec<PackedWire>>,
    /// Recycled bucket work containers.
    work_pool: Vec<Vec<LayerWork>>,
    /// Drain staging: finished work parked per layer so decode runs in
    /// ascending layer order regardless of completion order.
    slots: Vec<Option<LayerWork>>,
    traffic: TransportTraffic,
    /// Whether the transport serializes (claimed octets only counted
    /// then, so measured == claimed holds for `InProcess` too: 0 == 0).
    count_claimed: bool,
}

/// Per-step constants threaded into the per-bucket encode (mirrors the
/// loop-invariant part of `step()`).
#[derive(Clone, Copy)]
struct StepParams {
    world: usize,
    num_layers: usize,
    base_fmt: FpFormat,
    fp32_last_layer: bool,
    rounding: Rounding,
    kahan: bool,
    average: bool,
    step: u64,
}

/// Per-bucket encode-side accounting, merged into the step totals after
/// each bucket launch.
#[derive(Default)]
struct EncodeAccum {
    wire_cost: WireCost,
    moved: WireCost,
    claimed_octets: u64,
    elements: usize,
    bytes: u64,
}

/// One worker's private encode pipeline: its encode twin (state-
/// equivalent to the session strategy, see
/// [`SyncStrategy::parallel_encoder`]) plus a session-owned stage buffer
/// and the per-layer accounting the merge reads back. Worker `w` owns
/// lane `w` for the session's lifetime, so stateful codecs (error-
/// feedback residuals, QSGD's encode→pack coupling) see exactly the
/// per-worker call history the serial loop would give them.
struct EncodeLane {
    twin: Box<dyn SyncStrategy + Send>,
    /// This lane's dense f32 staging buffer (the packed path's analogue
    /// of the session's shared `stage`; grows to the largest layer once).
    stage: Vec<f32>,
    /// Honest wire cost of the last layer this lane encoded.
    cost: WireCost,
    /// Measured packed traffic of the last layer (zero in simulated mode).
    moved: WireCost,
    nonzero_in: usize,
    zero_out: usize,
    inf_out: usize,
}

impl EncodeLane {
    fn new(twin: Box<dyn SyncStrategy + Send>) -> Self {
        EncodeLane {
            twin,
            stage: Vec::new(),
            cost: WireCost::default(),
            moved: WireCost::default(),
            nonzero_in: 0,
            zero_out: 0,
            inf_out: 0,
        }
    }
}

/// The parallel-encode fan-out: one [`EncodeLane`] per worker, split
/// over threads with [`par::par_chunks_mut_pair`] so each lane is paired
/// with that worker's output buffer (packed bytes or dense wire). The
/// thread count only regroups whole lanes onto threads — every worker's
/// encode→pack chain runs start-to-finish on one thread with its own
/// twin and stage, so outputs are bit-identical at any thread count
/// (`rust/tests/encode_parallel.rs` pins 0/1/2/4/8 against the serial
/// loop).
struct EncodePool {
    lanes: Vec<EncodeLane>,
    /// The builder knob: 0 = auto (per-layer, gated like the prepare
    /// scans), explicit k honored exactly.
    threads: usize,
}

/// Per-layer totals merged from the lanes in ascending worker order —
/// integer sums and [`WireCost`] addition are order-independent, but the
/// fixed order makes the merge trivially the serial loop's.
#[derive(Default)]
struct EncodeTotals {
    wire_cost: WireCost,
    moved: WireCost,
    /// Σ over workers of that worker's packed `total_bytes()` — the
    /// per-worker rounding the bucket path claims to its transport.
    claimed_octets: u64,
    nonzero_in: usize,
    zero_out: usize,
    inf_out: usize,
}

impl EncodePool {
    /// Thread budget for one layer of `n` elements: the explicit knob if
    /// set, else the same auto gate as the prepare-phase reduction scans
    /// ([`par::reduce_threads`]) — encode does real per-element work, so
    /// the scan threshold is the right floor for spawn bookkeeping too.
    fn layer_threads(&self, n: usize) -> usize {
        if self.threads != 0 {
            self.threads
        } else {
            par::reduce_threads(n)
        }
    }

    /// Fan one layer's per-worker encode→pack chains over the lanes
    /// (packed wire). `ctx.worker` is ignored on entry; each lane sets
    /// its own.
    fn encode_layer_packed(&mut self, view: &GradView, ctx: &LayerCtx, packed: &mut [PackedWire]) {
        let threads = self.layer_threads(view.layer_len(ctx.layer));
        let base_ctx = *ctx;
        par::par_chunks_mut_pair(&mut self.lanes, packed, 1, threads, |start, lanes, packs| {
            for (i, (lane, pw)) in lanes.iter_mut().zip(packs.iter_mut()).enumerate() {
                let mut ctx = base_ctx;
                ctx.worker = start + i;
                let src = view.layer_of(ctx.worker, ctx.layer);
                // apslint: allow(alloc_in_hot_path) -- grows only when the model gains layers; steady state reuses the lane stages, pinned by rust/tests/session_alloc.rs
                lane.stage.resize(src.len(), 0.0);
                lane.twin.encode(src, &ctx, &mut lane.stage);
                lane.cost = lane.twin.wire_cost(&lane.stage, &ctx);
                count_quantization(src, &lane.stage, lane);
                lane.twin.encode_packed(&lane.stage, &ctx, pw);
                lane.moved = pw.moved_cost();
            }
        });
    }

    /// [`Self::encode_layer_packed`] for the simulated wire: each lane
    /// encodes straight into its worker's dense wire buffer (no pack
    /// step, no measured traffic).
    fn encode_layer_dense(&mut self, view: &GradView, ctx: &LayerCtx, wire: &mut [Vec<f32>]) {
        let threads = self.layer_threads(view.layer_len(ctx.layer));
        let base_ctx = *ctx;
        par::par_chunks_mut_pair(&mut self.lanes, wire, 1, threads, |start, lanes, bufs| {
            for (i, (lane, buf)) in lanes.iter_mut().zip(bufs.iter_mut()).enumerate() {
                let mut ctx = base_ctx;
                ctx.worker = start + i;
                let src = view.layer_of(ctx.worker, ctx.layer);
                // apslint: allow(alloc_in_hot_path) -- grows only when the model gains layers; steady state reuses the wire buffers, pinned by rust/tests/session_alloc.rs
                buf.resize(src.len(), 0.0);
                lane.twin.encode(src, &ctx, buf);
                lane.cost = lane.twin.wire_cost(buf, &ctx);
                count_quantization(src, buf, lane);
                lane.moved = WireCost::default();
            }
        });
    }

    /// Merge the lanes' per-worker accounting for the layer just encoded.
    fn totals(&self) -> EncodeTotals {
        let mut t = EncodeTotals::default();
        for lane in &self.lanes {
            t.wire_cost += lane.cost;
            t.moved += lane.moved;
            t.claimed_octets += lane.moved.total_bytes();
            t.nonzero_in += lane.nonzero_in;
            t.zero_out += lane.zero_out;
            t.inf_out += lane.inf_out;
        }
        t
    }
}

/// The underflow/overflow census of the serial encode loop, verbatim:
/// one extra read pass comparing the raw gradient against its wire image.
fn count_quantization(src: &[f32], quantized: &[f32], lane: &mut EncodeLane) {
    lane.nonzero_in = 0;
    lane.zero_out = 0;
    lane.inf_out = 0;
    for (&x, &q) in src.iter().zip(quantized.iter()) {
        if x != 0.0 {
            lane.nonzero_in += 1;
            if q == 0.0 {
                lane.zero_out += 1;
            }
        }
        if q.is_infinite() {
            lane.inf_out += 1;
        }
    }
}

/// Build the per-worker encode-twin pool: one lane per worker, each
/// owning a fresh state-equivalent twin from
/// [`SyncStrategy::parallel_encoder`]. Returns `None` — and the session
/// keeps the serial encode loop byte-for-byte — when the caller forced
/// `encode_threads == 1`, when there is only one worker, or when the
/// strategy does not opt in (third-party codecs stay serial by default).
/// All-or-nothing: once a pool exists, *every* encode routes through the
/// twins, so stateful codecs never see a mixed call history.
fn build_encode_pool(
    strategy: &dyn SyncStrategy,
    world: usize,
    encode_threads: usize,
) -> Option<EncodePool> {
    if encode_threads == 1 || world <= 1 {
        return None;
    }
    let mut lanes = Vec::with_capacity(world);
    for _ in 0..world {
        lanes.push(EncodeLane::new(strategy.parallel_encoder()?));
    }
    Some(EncodePool { lanes, threads: encode_threads })
}

impl SyncSession {
    /// Synchronize one training step's gradients (`grads[w][l]` = worker
    /// `w`'s layer-`l` gradient). Returns the reduced per-layer gradients
    /// and the step's [`SyncReport`], both borrowed from session storage
    /// (valid until the next `step` call).
    pub fn step(&mut self, grads: &[Vec<Vec<f32>>]) -> (&[Vec<f32>], &SyncReport) {
        let view = GradView::new(grads);
        let world = self.collective.world_size();
        assert_eq!(view.world(), world, "one gradient set per worker");
        let num_layers = view.num_layers();

        // Reset the report in place (no reallocation in steady state).
        self.report.layers.clear();
        self.report.layers.resize(num_layers, LayerReport::default());
        self.report.payload_bytes = 0;
        self.report.exponent_bytes = 0;
        self.report.steps = 0;
        self.report.messages = if self.fused { 1 } else { num_layers };
        self.report.encode_ns = 0;
        // Honest per-worker wire cost, summed over workers and layers here
        // and averaged into the report at the end of the step — and, on
        // the packed path, the independently measured packed traffic that
        // must come out equal.
        let mut wire_cost = WireCost::default();
        let mut moved = WireCost::default();
        let packed_mode = self.wire_mode == WireMode::Packed;

        // ---- Phase 1: agree on per-layer factors. ----------------------
        self.factors.reset(num_layers);
        let pstats =
            self.strategy.prepare(&view, self.collective.as_ref(), &mut self.factors);
        self.report.exponent_bytes = pstats.bytes_per_worker;
        self.report.steps += pstats.steps;

        // ---- Phase 2: encode (→ pack), reduce, decode — per layer. -----
        if packed_mode {
            self.packed.resize_with(world, PackedWire::default);
        } else {
            // apslint: allow(alloc_in_hot_path) -- grows only on world-size change (empty Vec::new never allocates); steady state reuses the buffers, pinned by rust/tests/session_alloc.rs
            self.wire.resize(world, Vec::new());
        }
        // apslint: allow(alloc_in_hot_path) -- grows only when the model gains layers; steady state reuses the buffers, pinned by rust/tests/session_alloc.rs
        self.reduced.resize(num_layers, Vec::new());
        let base_fmt = self.strategy.wire_format();

        for l in 0..num_layers {
            let n = view.layer_len(l);
            let fp32_passthrough = self.fp32_last_layer && l == num_layers - 1;
            let layer_fmt = if fp32_passthrough { FpFormat::FP32 } else { base_fmt };
            let fe = if layer_fmt.is_fp32() { 0 } else { self.factors.exp(l) };
            let mut ctx = LayerCtx {
                layer: l,
                num_layers,
                worker: 0,
                world,
                factor_exp: fe,
                fmt: layer_fmt,
                fp32_passthrough,
                rounding: self.rounding,
                average: self.average,
                step: self.steps_done,
            };

            let mut nonzero_in = 0usize;
            let mut zero_out = 0usize;
            let mut inf_out = 0usize;
            // apslint: allow(nondeterminism) -- wall-clock feeds SyncReport::encode_ns observability only; results are pinned bit-identical by rust/tests/encode_parallel.rs
            let enc0 = Instant::now();
            if let Some(pool) = self.encode.as_mut() {
                // Parallel fan-out: each worker's encode→pack chain runs
                // on its dedicated twin lane; the merge below reproduces
                // the serial loop's accounting in worker order.
                if packed_mode {
                    pool.encode_layer_packed(&view, &ctx, &mut self.packed);
                } else {
                    pool.encode_layer_dense(&view, &ctx, &mut self.wire);
                }
                let t = pool.totals();
                wire_cost += t.wire_cost;
                moved += t.moved;
                nonzero_in = t.nonzero_in;
                zero_out = t.zero_out;
                inf_out = t.inf_out;
                // Leave ctx exactly as the serial loop does: the fold and
                // decode below run with the last worker's ctx.
                ctx.worker = world - 1;
            } else {
                for w in 0..world {
                    ctx.worker = w;
                    let src = view.layer_of(w, l);
                    // Packed mode stages each worker's f32 wire values in
                    // one shared buffer: the only dense copy is transient,
                    // and the per-worker storage is the packed bytes.
                    let buf: &mut Vec<f32> =
                        if packed_mode { &mut self.stage } else { &mut self.wire[w] };
                    buf.resize(n, 0.0);
                    self.strategy.encode(src, &ctx, buf);
                    // One extra read pass for sparse codecs (nnz counting);
                    // dense costs are O(1). Kept as a trait call so the
                    // session never assumes how a codec maps zeros.
                    wire_cost += self.strategy.wire_cost(buf, &ctx);
                    for (&x, &q) in src.iter().zip(buf.iter()) {
                        if x != 0.0 {
                            nonzero_in += 1;
                            if q == 0.0 {
                                zero_out += 1;
                            }
                        }
                        if q.is_infinite() {
                            inf_out += 1;
                        }
                    }
                    if packed_mode {
                        // Fused encode → pack: transcode this worker's
                        // wire values into its packed buffer and count the
                        // bytes that will actually move through the
                        // reduction.
                        self.strategy.encode_packed(&self.stage, &ctx, &mut self.packed[w]);
                        moved += self.packed[w].moved_cost();
                    }
                }
            }
            self.report.encode_ns += enc0.elapsed().as_nanos() as u64;

            let ropts = ReduceOptions { fmt: layer_fmt, mode: self.rounding, kahan: self.kahan };
            let out = &mut self.reduced[l];
            out.resize(n, 0.0);
            let stats = if packed_mode {
                self.collective.all_reduce_packed_sum_into(
                    &self.packed,
                    self.strategy.as_ref(),
                    &ctx,
                    out,
                    &ropts,
                    &mut self.pack_scratch,
                )
            } else {
                self.collective.all_reduce_sum_into(&self.wire, out, &ropts)
            };
            self.strategy.decode(out, &ctx);

            self.report.layers[l] = LayerReport {
                factor_exp: fe,
                underflow_frac: if nonzero_in == 0 {
                    0.0
                } else {
                    zero_out as f64 / nonzero_in as f64
                },
                overflow_frac: inf_out as f64 / (n * world).max(1) as f64,
                elements: n,
            };
            self.report.payload_bytes += stats.bytes_per_worker;
            if !self.fused {
                self.report.steps += stats.steps;
            }
        }
        if self.fused {
            // One fused message: pay the per-message step count once.
            self.report.steps += self.collective.steps_per_message();
        }
        self.report.wire = wire_cost.per_worker(world);
        // Measured packed traffic, aggregated exactly like `report.wire`
        // so the bench-pinned equality is apples to apples.
        self.moved = packed_mode.then(|| moved.per_worker(world));
        self.steps_done += 1;
        (&self.reduced, &self.report)
    }

    /// Bucketed asynchronous all-reduce: fuse layers into ~N-byte
    /// buckets in `ready_order` (backprop order — last layer first) and
    /// launch each bucket's encode→pack→exchange→fold onto the
    /// session-owned worker pool as soon as it is encoded, overlapping
    /// the pool's transit+fold with the main thread's encode of later
    /// buckets. The drain decodes in ascending layer order with the
    /// stored per-layer ctx, so reduced gradients, reports and
    /// [`Self::wire_moved`] are **bit-identical** to [`Self::step`] for
    /// every codec, transport and bucket size
    /// (`rust/tests/transport_overlap.rs` pins all of it): per-element
    /// fold chains stay on one thread (`max_threads == 1` twins), sums
    /// over integer accounting are order-independent, and every codec's
    /// encode state is keyed by `(step, layer, worker)` rather than call
    /// order.
    ///
    /// Falls back to [`Self::step`] (same results, no overlap) when the
    /// session cannot replicate its strategy or collective onto the
    /// pool — custom [`SyncSessionBuilder::strategy`]/
    /// [`SyncSessionBuilder::collective`], [`WireMode::Simulated`], or
    /// after [`Self::set_strategy`].
    ///
    /// On a transport failure the step yields `Err`: no partial fold is
    /// applied ([`Self::reduced`] is emptied, the report cleared,
    /// [`Self::steps_done`] unchanged so a retry replays the same
    /// stochastic draws — note error-feedback residuals *have* advanced,
    /// so EF codecs are not retry-safe).
    pub fn step_overlapped(
        &mut self,
        grads: &[Vec<Vec<f32>>],
        ready_order: &[usize],
    ) -> Result<(&[Vec<f32>], &SyncReport), TransportError> {
        if !self.ensure_overlap() {
            validate_ready_order(grads, ready_order);
            return Ok(self.step(grads));
        }
        let view = GradView::new(grads);
        let world = self.collective.world_size();
        assert_eq!(view.world(), world, "one gradient set per worker");
        let num_layers = view.num_layers();

        // Mirror step(): reset the report in place.
        self.report.layers.clear();
        self.report.layers.resize(num_layers, LayerReport::default());
        self.report.payload_bytes = 0;
        self.report.exponent_bytes = 0;
        self.report.steps = 0;
        self.report.messages = if self.fused { 1 } else { num_layers };
        self.report.buckets.clear();
        self.report.encode_ns = 0;
        let mut wire_cost = WireCost::default();
        let mut moved = WireCost::default();
        let mut claimed_octets = 0u64;

        // Phase 1 runs on the main thread, exactly as in step().
        self.factors.reset(num_layers);
        let pstats =
            self.strategy.prepare(&view, self.collective.as_ref(), &mut self.factors);
        self.report.exponent_bytes = pstats.bytes_per_worker;
        self.report.steps += pstats.steps;

        // apslint: allow(alloc_in_hot_path) -- grows only when the model gains layers; steady state reuses the buffers, pinned by rust/tests/session_alloc.rs
        self.reduced.resize(num_layers, Vec::new());

        let params = StepParams {
            world,
            num_layers,
            base_fmt: self.strategy.wire_format(),
            fp32_last_layer: self.fp32_last_layer,
            rounding: self.rounding,
            kahan: self.kahan,
            average: self.average,
            step: self.steps_done,
        };

        let Some(ov) = self.overlap.as_mut() else {
            // ensure_overlap() returned true, so this is unreachable;
            // degrade to the synchronous path rather than panic.
            return Ok(self.step(grads));
        };
        let bucket_bytes = if self.bucket_bytes == 0 {
            let mut total = 0u64;
            for l in 0..num_layers {
                total += view.layer_len(l) as u64 * 4;
            }
            auto_bucket_bytes(total, ov.threads)
        } else {
            self.bucket_bytes as u64
        };
        ov.plan.rebuild(&view, ready_order, bucket_bytes);
        let num_buckets = ov.plan.num_buckets();
        self.report.buckets.resize(num_buckets, BucketStats::default());
        ov.slots.clear();
        ov.slots.resize_with(num_layers, || None);

        // ---- Launch: encode each bucket, hand it to the pool. ----------
        let mut first_err: Option<TransportError> = None;
        let mut sent = 0usize;
        for b in 0..num_buckets {
            let mut work = ov.work_pool.pop().unwrap_or_default();
            work.clear();
            let mut acc = EncodeAccum::default();
            // apslint: allow(nondeterminism) -- wall-clock feeds BucketStats observability only; results are pinned bit-identical by rust/tests/transport_overlap.rs
            let t0 = Instant::now();
            encode_bucket_layers(
                self.strategy.as_mut(),
                self.encode.as_mut(),
                &mut self.stage,
                &view,
                ov.plan.bucket(b),
                &self.factors,
                &params,
                &mut self.report,
                &mut self.reduced,
                &mut ov.packed_pool,
                &mut work,
                &mut acc,
            );
            wire_cost += acc.wire_cost;
            moved += acc.moved;
            claimed_octets += acc.claimed_octets;
            let encode_ns = t0.elapsed().as_nanos() as u64;
            self.report.encode_ns += encode_ns;
            self.report.buckets[b] = BucketStats {
                bucket: b,
                layers: ov.plan.bucket(b).len(),
                elements: acc.elements,
                bytes: acc.bytes,
                encode_ns,
                transit_ns: 0,
                fold_ns: 0,
                wait_ns: 0,
            };
            let msg = BucketMsg {
                bucket: b,
                work,
                // apslint: allow(nondeterminism) -- wall-clock feeds BucketStats observability only; results are pinned bit-identical by rust/tests/transport_overlap.rs
                sent: Instant::now(),
                transit_ns: 0,
                fold_ns: 0,
                wait_ns: 0,
                octets: 0,
                err: None,
            };
            if ov.senders[b % ov.threads].send(WorkerMsg::Bucket(msg)).is_err() {
                first_err = Some(TransportError {
                    transport: "pool",
                    worker: b % ov.threads,
                    kind: FaultKind::Dead,
                    detail: "overlap worker thread exited".into(),
                });
                break;
            }
            sent += 1;
        }

        // ---- Drain barrier: exactly one message per launched bucket. ---
        let mut poison = false;
        for _ in 0..sent {
            match ov.results.recv_timeout(Duration::from_secs(60)) {
                Ok(mut msg) => {
                    let bs = &mut self.report.buckets[msg.bucket];
                    bs.transit_ns = msg.transit_ns;
                    bs.fold_ns = msg.fold_ns;
                    bs.wait_ns = msg.wait_ns;
                    ov.traffic.octets += msg.octets;
                    if let Some(e) = msg.err.take() {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    for lw in msg.work.drain(..) {
                        ov.slots[lw.layer] = Some(lw);
                    }
                    ov.work_pool.push(msg.work);
                }
                Err(_) => {
                    first_err = Some(TransportError {
                        transport: "pool",
                        worker: usize::MAX,
                        kind: FaultKind::Dead,
                        detail: "overlap worker result timed out or disconnected".into(),
                    });
                    // In-flight replies may still land in the channel;
                    // poison the pool so the next step starts fresh
                    // instead of draining a stale step's messages.
                    poison = true;
                    break;
                }
            }
        }

        if let Some(err) = first_err {
            // Clean failure: recycle the buffers, surface *no* partial
            // fold (reduced emptied, report zeroed, steps_done
            // unchanged).
            for slot in ov.slots.iter_mut() {
                if let Some(mut lw) = slot.take() {
                    ov.packed_pool.push(core::mem::take(&mut lw.packed));
                    self.reduced[lw.layer] = lw.out;
                }
            }
            for v in &mut self.reduced {
                v.clear();
            }
            self.report.layers.clear();
            self.report.buckets.clear();
            self.report.payload_bytes = 0;
            self.report.exponent_bytes = 0;
            self.report.steps = 0;
            self.report.messages = 0;
            self.report.encode_ns = 0;
            self.report.wire = WireCost::default();
            self.moved = None;
            if poison {
                self.overlap = None;
            }
            return Err(err);
        }

        // ---- Finalize: decode in ascending layer order (as step()
        // decodes l after fold l — every decode is ctx-pure, so only the
        // per-layer ctx matters, and it rides in LayerWork).
        for l in 0..num_layers {
            let slot = ov.slots[l].take();
            assert!(slot.is_some(), "bucket plan must cover layer {l}");
            if let Some(mut lw) = slot {
                self.strategy.decode(&mut lw.out, &lw.ctx);
                self.report.payload_bytes += lw.stats.bytes_per_worker;
                if !self.fused {
                    self.report.steps += lw.stats.steps;
                }
                ov.packed_pool.push(core::mem::take(&mut lw.packed));
                self.reduced[l] = lw.out;
            }
        }
        if self.fused {
            self.report.steps += self.collective.steps_per_message();
        }
        self.report.wire = wire_cost.per_worker(world);
        self.moved = Some(moved.per_worker(world));
        if ov.count_claimed {
            ov.traffic.claimed_octets += claimed_octets;
        }
        self.steps_done += 1;
        Ok((&self.reduced, &self.report))
    }

    /// Spawn the overlap pool if this session can overlap and it is not
    /// up yet. Cold: once per session. Returns whether the overlapped
    /// path is available.
    fn ensure_overlap(&mut self) -> bool {
        if self.overlap.is_some() {
            return true;
        }
        let Some(cfg) = self.overlap_cfg.clone() else {
            return false;
        };
        let world = self.collective.world_size();
        let threads = overlap_pool_threads();
        let (result_tx, results) = mpsc::channel();
        let mut senders = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = mpsc::channel();
            let spec = cfg.spec.clone();
            let topology = cfg.topology;
            let transport = cfg.transport;
            let out = result_tx.clone();
            std::thread::spawn(move || overlap_worker(spec, topology, transport, world, rx, out));
            senders.push(tx);
        }
        self.overlap = Some(OverlapState {
            threads,
            senders,
            results,
            plan: BucketPlan::default(),
            packed_pool: Vec::new(),
            work_pool: Vec::new(),
            slots: Vec::new(),
            traffic: TransportTraffic::default(),
            count_claimed: cfg.transport != TransportSpec::InProcess,
        });
        true
    }

    /// Inject a peer failure into every pool thread's transport (fault
    /// testing; only meaningful for transports with real channels, i.e.
    /// [`TransportSpec::Tcp`]). Returns false when the session cannot
    /// overlap at all.
    pub fn kill_transport_peer(&mut self, worker: usize) -> bool {
        if !self.ensure_overlap() {
            // No overlap pool: the collective may own a transport of
            // its own (the parameter server does) — forward there.
            return self.collective.kill_transport_peer(worker);
        }
        let Some(ov) = self.overlap.as_ref() else {
            return false;
        };
        for s in &ov.senders {
            let _ = s.send(WorkerMsg::Kill(worker));
        }
        true
    }

    /// Synchronize one step through a fault-aware collective (the
    /// parameter server): run [`Self::step`], then harvest any transport
    /// fault the collective parked via
    /// [`Collective::take_fault`](crate::collectives::Collective::take_fault).
    /// On fault the step rolls back exactly like a failed
    /// [`Self::step_overlapped`] — reduced gradients emptied, report
    /// zeroed, `steps_done` unchanged — so no partial fold ever escapes;
    /// the session stays usable for the next step once the cause is
    /// repaired. For fault-free collectives (ring/hierarchical) this is
    /// `step()` that always returns `Ok`.
    pub fn step_checked(
        &mut self,
        grads: &[Vec<Vec<f32>>],
    ) -> Result<(&[Vec<f32>], &SyncReport), TransportError> {
        {
            let _ = self.step(grads);
        }
        if let Some(err) = self.collective.take_fault() {
            for v in &mut self.reduced {
                v.clear();
            }
            self.report.layers.clear();
            self.report.buckets.clear();
            self.report.payload_bytes = 0;
            self.report.exponent_bytes = 0;
            self.report.steps = 0;
            self.report.messages = 0;
            self.report.encode_ns = 0;
            self.report.wire = WireCost::default();
            self.moved = None;
            // step() counted the faulted step; a rolled-back step never
            // happened as far as replay determinism is concerned.
            self.steps_done -= 1;
            return Err(err);
        }
        Ok((&self.reduced, &self.report))
    }

    /// Cumulative octet accounting of the collective's own transport
    /// (the parameter-server push/pull legs) — `None` for collectives
    /// that own no transport. Complements [`Self::transport_traffic`],
    /// which covers the overlap pool's transports.
    pub fn collective_traffic(&self) -> Option<TransportTraffic> {
        self.collective.transport_traffic()
    }

    /// Elastic membership: (de)activate `worker` in a membership-aware
    /// collective (the parameter server re-shards on the next fold).
    /// Returns false when the collective has no membership notion.
    pub fn set_member_active(&mut self, worker: usize, active: bool) -> bool {
        self.collective.set_member_active(worker, active)
    }

    /// Straggler schedule: delay `worker`'s contributions by `rounds`
    /// reduce calls in a staleness-aware collective (clamped to its
    /// staleness budget). Returns false when unsupported.
    pub fn set_arrival_delay(&mut self, worker: usize, rounds: usize) -> bool {
        self.collective.set_arrival_delay(worker, rounds)
    }

    /// Forward a read-patience budget (timeout per read, tolerated
    /// consecutive timeouts) to the collective's own transport.
    pub fn set_transport_patience(&mut self, read_timeout_ms: u64, max_timeouts: usize) -> bool {
        self.collective.set_transport_patience(read_timeout_ms, max_timeouts)
    }

    /// Inject a per-send delay for `worker` into the collective's own
    /// transport (a wire-level straggler, as opposed to the round-level
    /// [`Self::set_arrival_delay`]).
    pub fn inject_transport_delay(&mut self, worker: usize, delay_ms: u64) -> bool {
        self.collective.inject_transport_delay(worker, delay_ms)
    }

    /// Cumulative serialized-octet accounting across every overlapped
    /// step so far (`None` before the pool exists). For serializing
    /// transports, `octets == claimed_octets` pins transport-level wire
    /// honesty; for [`TransportSpec::InProcess`] both stay 0.
    pub fn transport_traffic(&self) -> Option<TransportTraffic> {
        self.overlap.as_ref().map(|ov| ov.traffic)
    }

    /// The transport the overlapped path would use (`None` when the
    /// session cannot overlap).
    pub fn overlap_transport(&self) -> Option<TransportSpec> {
        self.overlap_cfg.as_ref().map(|c| c.transport)
    }

    /// The packed wire traffic the last step *actually moved* through the
    /// reduction, per worker (payload bits + metadata, measured from the
    /// [`PackedWire`] buffers) — `None` before the first step and in
    /// [`WireMode::Simulated`]. For every built-in codec on finite
    /// gradients this equals [`SyncReport::wire`] exactly; the strategy
    /// benches assert it (measured bytes-moved == honest accounting).
    pub fn wire_moved(&self) -> Option<WireCost> {
        self.moved
    }

    /// The wire mode this session runs.
    pub fn wire_mode(&self) -> WireMode {
        self.wire_mode
    }

    /// Swap the strategy, keeping the collective and all scratch (the
    /// hybrid-precision schedule's epoch switch). The pool's decode
    /// twins no longer match an arbitrary replacement, so the overlap
    /// pool is dropped (its threads exit when the senders drop) and
    /// [`Self::step_overlapped`] falls back to the synchronous path
    /// afterwards — results are identical either way.
    pub fn set_strategy(&mut self, strategy: Box<dyn SyncStrategy>) {
        self.strategy = strategy;
        // Fresh twins for the replacement codec (or back to the serial
        // loop if it does not opt in) — stale lanes would replay the old
        // codec's state.
        self.encode = build_encode_pool(
            self.strategy.as_ref(),
            self.collective.world_size(),
            self.encode_threads,
        );
        self.overlap_cfg = None;
        self.overlap = None;
    }

    /// The last step's report (empty before the first step).
    pub fn report(&self) -> &SyncReport {
        &self.report
    }

    /// The last step's reduced per-layer gradients.
    pub fn reduced(&self) -> &[Vec<f32>] {
        &self.reduced
    }

    pub fn world_size(&self) -> usize {
        self.collective.world_size()
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn collective_name(&self) -> &'static str {
        self.collective.name()
    }

    /// Whether the session divides reduced sums by the world size.
    pub fn averages(&self) -> bool {
        self.average
    }

    /// Steps synchronized so far.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }
}

/// Encode→pack one bucket's layers on the main thread, bit-for-bit the
/// inner loop of [`SyncSession::step`]: per layer, per worker, `encode`
/// into the shared stage then `encode_packed` into that worker's packed
/// buffer, with the same wire-cost/underflow/overflow accounting. A free
/// function (not a method) so it can run while the overlap state is
/// mutably borrowed — every piece of session state it needs comes in as
/// a disjoint field borrow.
#[allow(clippy::too_many_arguments)]
fn encode_bucket_layers(
    strategy: &mut dyn SyncStrategy,
    mut pool: Option<&mut EncodePool>,
    stage: &mut Vec<f32>,
    view: &GradView,
    layers: &[usize],
    factors: &Factors,
    params: &StepParams,
    report: &mut SyncReport,
    reduced: &mut [Vec<f32>],
    packed_pool: &mut Vec<Vec<PackedWire>>,
    work: &mut Vec<LayerWork>,
    acc: &mut EncodeAccum,
) {
    for &l in layers {
        let n = view.layer_len(l);
        let fp32_passthrough = params.fp32_last_layer && l == params.num_layers - 1;
        let layer_fmt = if fp32_passthrough { FpFormat::FP32 } else { params.base_fmt };
        let fe = if layer_fmt.is_fp32() { 0 } else { factors.exp(l) };
        let mut ctx = LayerCtx {
            layer: l,
            num_layers: params.num_layers,
            worker: 0,
            world: params.world,
            factor_exp: fe,
            fmt: layer_fmt,
            fp32_passthrough,
            rounding: params.rounding,
            average: params.average,
            step: params.step,
        };

        let mut packed = packed_pool.pop().unwrap_or_default();
        packed.resize_with(params.world, PackedWire::default);
        let mut nonzero_in = 0usize;
        let mut zero_out = 0usize;
        let mut inf_out = 0usize;
        if let Some(pool) = pool.as_deref_mut() {
            // Same fan-out as the synchronous step: one twin lane per
            // worker, merged in worker order.
            pool.encode_layer_packed(view, &ctx, &mut packed);
            let t = pool.totals();
            acc.wire_cost += t.wire_cost;
            acc.moved += t.moved;
            acc.claimed_octets += t.claimed_octets;
            acc.bytes += t.claimed_octets;
            nonzero_in = t.nonzero_in;
            zero_out = t.zero_out;
            inf_out = t.inf_out;
            ctx.worker = params.world - 1;
        } else {
            for w in 0..params.world {
                ctx.worker = w;
                let src = view.layer_of(w, l);
                stage.resize(n, 0.0);
                strategy.encode(src, &ctx, stage);
                acc.wire_cost += strategy.wire_cost(stage, &ctx);
                for (&x, &q) in src.iter().zip(stage.iter()) {
                    if x != 0.0 {
                        nonzero_in += 1;
                        if q == 0.0 {
                            zero_out += 1;
                        }
                    }
                    if q.is_infinite() {
                        inf_out += 1;
                    }
                }
                strategy.encode_packed(stage, &ctx, &mut packed[w]);
                let cost = packed[w].moved_cost();
                acc.moved += cost;
                acc.claimed_octets += cost.total_bytes();
                acc.bytes += cost.total_bytes();
            }
        }
        // ctx.worker is now world - 1, exactly the fold-time ctx step()
        // passes to the packed reduction and to decode.
        report.layers[l] = LayerReport {
            factor_exp: fe,
            underflow_frac: if nonzero_in == 0 {
                0.0
            } else {
                zero_out as f64 / nonzero_in as f64
            },
            overflow_frac: inf_out as f64 / (n * params.world).max(1) as f64,
            elements: n,
        };
        acc.elements += n;

        let mut out = core::mem::take(&mut reduced[l]);
        out.resize(n, 0.0);
        let ropts =
            ReduceOptions { fmt: layer_fmt, mode: params.rounding, kahan: params.kahan };
        work.push(LayerWork {
            layer: l,
            ctx,
            ropts,
            packed,
            out,
            stats: ReduceStats::default(),
        });
    }
}

/// The persistent pool thread: owns its own decode twin (spec-built —
/// `decode_packed` is `&self`-pure and config-pure for every built-in
/// codec, so a twin decodes bit-identically to the session's strategy),
/// its own collective (the hierarchical one carries `RefCell` scratch,
/// so instances cannot be shared), its own transport, and a
/// single-threaded fold scratch (`max_threads == 1` keeps every
/// per-element fold chain on this one thread — the PR 7
/// schedule-independence discipline). Exactly one [`BucketMsg`] goes
/// back per bucket received, error or not; the thread exits when the
/// session drops its sender.
fn overlap_worker(
    spec: StrategySpec,
    topology: Topology,
    transport_spec: TransportSpec,
    world: usize,
    jobs: mpsc::Receiver<WorkerMsg>,
    results: mpsc::Sender<BucketMsg>,
) {
    let twin: Box<dyn SyncStrategy> = spec.build();
    let collective = topology.collective(world);
    let mut transport = transport_spec.build(world);
    let mut scratch = PackScratch { max_threads: 1, ..PackScratch::default() };
    while let Ok(msg) = jobs.recv() {
        let mut m = match msg {
            WorkerMsg::Kill(w) => {
                transport.kill_peer(w);
                continue;
            }
            WorkerMsg::Bucket(m) => m,
        };
        m.wait_ns = m.sent.elapsed().as_nanos() as u64;
        transport.reset_moved();
        for lw in &mut m.work {
            if m.err.is_some() {
                // No partial fold past a failed exchange: the remaining
                // layers ship back untouched and the session discards
                // everything.
                break;
            }
            // apslint: allow(nondeterminism) -- wall-clock feeds BucketStats observability only; results are pinned bit-identical by rust/tests/transport_overlap.rs
            let t0 = Instant::now();
            match transport.exchange(&lw.packed) {
                Ok(delivered) => {
                    // apslint: allow(nondeterminism) -- wall-clock feeds BucketStats observability only; results are pinned bit-identical by rust/tests/transport_overlap.rs
                    let t1 = Instant::now();
                    lw.stats = collective.all_reduce_packed_sum_into(
                        delivered,
                        twin.as_ref(),
                        &lw.ctx,
                        &mut lw.out,
                        &lw.ropts,
                        &mut scratch,
                    );
                    m.transit_ns += t1.duration_since(t0).as_nanos() as u64;
                    m.fold_ns += t1.elapsed().as_nanos() as u64;
                }
                Err(e) => {
                    m.err = Some(e);
                }
            }
        }
        m.octets = transport.octets_moved();
        if results.send(m).is_err() {
            return;
        }
    }
}

/// Pool width for the overlapped path. Cold (called once per session);
/// only bucket *boundaries* depend on it — reduced gradients are
/// schedule-independent, so the machine-dependent width never reaches
/// the numerics.
fn overlap_pool_threads() -> usize {
    crate::util::par::num_threads().clamp(2, 8)
}

/// The fallback path skips plan building, but `ready_order` must be
/// held to the same contract either way.
fn validate_ready_order(grads: &[Vec<Vec<f32>>], ready_order: &[usize]) {
    let num_layers = grads.first().map_or(0, |g| g.len());
    assert_eq!(
        ready_order.len(),
        num_layers,
        "ready_order must list every layer exactly once"
    );
    let mut seen = vec![false; num_layers];
    for &l in ready_order {
        assert!(l < num_layers, "ready_order layer {l} out of range");
        assert!(!seen[l], "ready_order lists layer {l} twice");
        seen[l] = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aps::SyncMethod;

    fn grads(world: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
        (0..world)
            .map(|w| {
                layers
                    .iter()
                    .enumerate()
                    .map(|(l, &n)| {
                        (0..n).map(|i| ((w * 31 + l * 7 + i) % 13) as f32 * 0.25 - 1.5).collect()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn builder_defaults_and_knobs() {
        let s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_rounding(Rounding::TowardZero)
            .with_fused(true)
            .build();
        assert_eq!(s.world_size(), 4);
        assert_eq!(s.strategy_name(), "aps");
        assert_eq!(s.collective_name(), "ring");
        let d = SyncSessionBuilder::default().build();
        assert_eq!(d.world_size(), 1);
        assert_eq!(d.strategy_name(), "fp32");
    }

    #[test]
    fn fp32_session_averages_exactly_for_world_1() {
        let g = grads(1, &[16]);
        let mut s = SyncSessionBuilder::new(1).spec(StrategySpec::Fp32).build();
        let (out, report) = s.step(&g);
        assert_eq!(out[0], g[0][0]);
        assert_eq!(report.payload_bytes, 0);
        assert_eq!(report.messages, 1);
    }

    #[test]
    fn session_reports_match_legacy_shape() {
        let g = grads(8, &[64, 32]);
        let mut s = SyncSessionBuilder::new(8)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .build();
        let (_, report) = s.step(&g);
        assert_eq!(report.layers.len(), 2);
        assert_eq!(report.messages, 2);
        assert!(report.exponent_bytes > 0, "APS pays the exponent phase");
        assert!(report.payload_bytes > 0);
        assert_eq!(s.steps_done(), 1);
    }

    #[test]
    fn session_reports_honest_wire_costs() {
        let g = grads(4, &[64, 32]);
        // fp32: honest cost == dense FP32 payload of one gradient set
        let mut s = SyncSessionBuilder::new(4).spec(StrategySpec::Fp32).build();
        let (_, report) = s.step(&g);
        assert_eq!(report.wire, WireCost::dense(96, FpFormat::FP32));
        assert_eq!(report.wire.total_bytes(), 96 * 4);
        // top-k: index traffic finally shows up, and the honest figure is
        // far below the dense payload
        let mut s = SyncSessionBuilder::new(4).spec(StrategySpec::TopK { frac: 0.25 }).build();
        let (_, report) = s.step(&g);
        assert!(report.wire.index_bits > 0, "top-k must account index bits");
        assert!(report.wire.total_bytes() < 96 * 4, "{:?}", report.wire);
        // qsgd: packed value bits + per-bucket scales
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 1 })
            .build();
        let (_, report) = s.step(&g);
        assert_eq!(report.wire.value_bits, 96 * 4);
        assert_eq!(report.wire.metadata_bytes, 4 * 3);
        // the packed 4-bit payload beats the simulated dense FP32 figure
        assert!(report.honest_bytes() < report.total_bytes(), "{report:?}");
    }

    #[test]
    fn packed_mode_is_default_and_measures_what_it_claims() {
        let g = grads(4, &[64, 32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 5 })
            .build();
        assert_eq!(s.wire_mode(), WireMode::Packed);
        assert!(s.wire_moved().is_none(), "no traffic before the first step");
        let (_, report) = s.step(&g);
        let wire = report.wire;
        // measured packed traffic == honest accounting, field for field
        assert_eq!(s.wire_moved(), Some(wire));
        // ternary: 2 bits per element → 96 elems = 24 bytes per worker
        assert_eq!(wire.value_bits, 2 * 96);

        // simulated mode reports no packed measurement
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 5 })
            .with_wire(WireMode::Simulated)
            .build();
        let (_, report) = s.step(&g);
        let sim_wire = report.wire;
        assert_eq!(sim_wire, wire, "accounting is mode-independent");
        assert_eq!(s.wire_moved(), None);
    }

    #[test]
    fn packed_and_simulated_sessions_are_bit_identical() {
        // The in-crate smoke version of rust/tests/packed_wire.rs: same
        // inputs through both wire modes → same bits, same reports.
        let g = grads(8, &[96, 33]);
        for spec in [
            StrategySpec::Aps { fmt: FpFormat::E5M2 },
            StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 },
            StrategySpec::TopK { frac: 0.25 },
        ] {
            let mut packed = SyncSessionBuilder::new(8).spec(spec.clone()).build();
            let mut sim = SyncSessionBuilder::new(8)
                .spec(spec.clone())
                .with_wire(WireMode::Simulated)
                .build();
            let (po, pr) = packed.step(&g);
            let po = po.to_vec();
            let pr = pr.clone();
            let (so, sr) = sim.step(&g);
            for (l, (a, b)) in po.iter().zip(so.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{spec:?} layer {l} elem {i}");
                }
            }
            assert_eq!(&pr, sr, "{spec:?} report");
        }
    }

    #[test]
    fn error_feedback_builder_wraps_the_strategy() {
        let g = grads(4, &[32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 3 })
            .error_feedback()
            .build();
        assert_eq!(s.strategy_name(), "ef:ternary");
        let (_, report) = s.step(&g);
        assert_eq!(report.layers.len(), 1);
        // applied at build time → order-independent w.r.t. spec()
        let s = SyncSessionBuilder::new(4)
            .error_feedback()
            .spec(StrategySpec::Ternary { seed: 3 })
            .build();
        assert_eq!(s.strategy_name(), "ef:ternary");
        // bare error_feedback() wraps the FP32 default
        let d = SyncSessionBuilder::new(2).error_feedback().build();
        assert_eq!(d.strategy_name(), "ef:fp32");
    }

    #[test]
    fn step_overlapped_matches_step_bit_for_bit() {
        let g = grads(4, &[96, 33, 7]);
        let order = [2usize, 1, 0];
        for spec in [
            StrategySpec::Aps { fmt: FpFormat::E5M2 },
            StrategySpec::Ternary { seed: 7 },
        ] {
            let mut sync = SyncSessionBuilder::new(4).spec(spec.clone()).build();
            let mut over = SyncSessionBuilder::new(4).spec(spec.clone()).build();
            for step in 0..2 {
                let (so, sr) = sync.step(&g);
                let so = so.to_vec();
                let sr = sr.clone();
                let (oo, or) = over.step_overlapped(&g, &order).expect("in-process overlap");
                for (l, (a, b)) in so.iter().zip(oo.iter()).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{spec:?} step {step} layer {l} elem {i}"
                        );
                    }
                }
                assert_eq!(&sr, or, "{spec:?} step {step} report");
                assert!(!or.buckets.is_empty(), "overlapped path reports buckets");
                assert_eq!(sync.wire_moved(), over.wire_moved());
            }
        }
    }

    #[test]
    fn custom_strategy_falls_back_to_synchronous_path() {
        let g = grads(2, &[16]);
        let mut s = SyncSessionBuilder::new(2)
            .strategy(StrategySpec::Fp32.build())
            .build();
        assert_eq!(s.overlap_transport(), None, "custom strategy cannot overlap");
        let (out, report) = s.step_overlapped(&g, &[0]).expect("fallback cannot fail");
        assert_eq!(out.len(), 1);
        assert!(report.buckets.is_empty(), "fallback is the synchronous path");
        assert_eq!(s.transport_traffic(), None);
    }

    #[test]
    fn set_strategy_drops_the_overlap_pool() {
        let g = grads(2, &[16]);
        let mut s = SyncSessionBuilder::new(2).spec(StrategySpec::Fp32).build();
        assert_eq!(s.overlap_transport(), Some(super::TransportSpec::InProcess));
        let _ = s.step_overlapped(&g, &[0]).unwrap();
        s.set_strategy(StrategySpec::from(SyncMethod::Fp32).build());
        assert_eq!(s.overlap_transport(), None);
        let (_, report) = s.step_overlapped(&g, &[0]).expect("fallback after swap");
        assert!(report.buckets.is_empty());
    }

    #[test]
    fn set_strategy_keeps_buffers_and_switches_codec() {
        let g = grads(4, &[32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Naive { fmt: FpFormat::E5M2 })
            .build();
        let _ = s.step(&g);
        assert_eq!(s.strategy_name(), "naive");
        s.set_strategy(StrategySpec::from(SyncMethod::Fp32).build());
        let (_, report) = s.step(&g);
        assert_eq!(s.strategy_name(), "fp32");
        assert_eq!(report.exponent_bytes, 0);
    }
}

//! [`SyncSession`] — the hot-path owner of one strategy, one collective,
//! and every buffer gradient synchronization needs step after step.
//!
//! The pre-trait `aps::synchronize` free function (removed; see
//! `aps::legacy` for the pinned historical implementation) re-allocated
//! all wire tensors, the output tensors and the report on every call. A
//! session allocates them once (growing to the largest layer on first
//! use) and then runs [`SyncSession::step`] with no per-step
//! element-storage allocation — only O(world) pointer bookkeeping inside
//! the ring split. The hierarchical collective keeps its per-group
//! partials in reusable scratch, Kahan compensation lives in
//! stack-resident blocks inside the fold kernels, and the packed wire's
//! byte buffers and unpack chunks are session-owned
//! (`rust/tests/session_alloc.rs` pins the steady state with a counting
//! allocator across all of ring/hierarchical/packed/Kahan).
//!
//! Under the default [`WireMode::Packed`], each worker's encoded layer is
//! transcoded into a [`PackedWire`] (2-bit ternary symbols, QSGD
//! sign+level codes, `FpFormat`-width bit-codes, sparse pairs) and the
//! collective reduces by unpacking cache-blocked chunks — the simulated
//! traffic that moves through memory is the codec's honest `WireCost`,
//! not dense f32 lanes, while decoded gradients and reports stay
//! bit-identical to [`WireMode::Simulated`]
//! (`rust/tests/packed_wire.rs`). [`SyncSession::wire_moved`] exposes the
//! measured packed traffic.
//!
//! Reports and reduced gradients are returned by reference into
//! session-owned storage; reusing a session yields bit-identical results
//! to fresh calls (pinned by `rust/tests/strategy_layer.rs`).

use super::wire::{PackScratch, PackedWire, WireMode};
use super::{ErrorFeedback, Factors, GradView, LayerCtx, StrategySpec, SyncStrategy, WireCost};
use crate::aps::{LayerReport, SyncOptions, SyncReport};
use crate::collectives::{Collective, ReduceOptions, Topology};
use crate::cpd::{FpFormat, Rounding};

/// Builder for [`SyncSession`] (the `SyncOptions` knobs carried over,
/// plus the strategy/collective plug points).
pub struct SyncSessionBuilder {
    world: usize,
    strategy: Option<Box<dyn SyncStrategy>>,
    topology: Topology,
    collective: Option<Box<dyn Collective>>,
    rounding: Rounding,
    kahan: bool,
    average: bool,
    fp32_last_layer: bool,
    fused: bool,
    error_feedback: bool,
    wire: WireMode,
    fold_threads: usize,
}

impl SyncSessionBuilder {
    /// Start a builder for `world_size` workers. Defaults: FP32 strategy,
    /// ring collective, round-to-nearest-even, averaging on, no Kahan, no
    /// fp32-last-layer, unfused messages.
    pub fn new(world_size: usize) -> Self {
        assert!(world_size >= 1);
        SyncSessionBuilder {
            world: world_size,
            strategy: None,
            topology: Topology::Ring,
            collective: None,
            rounding: Rounding::NearestEven,
            kahan: false,
            average: true,
            fp32_last_layer: false,
            fused: false,
            error_feedback: false,
            wire: WireMode::default(),
            fold_threads: 0,
        }
    }

    /// Carry every knob of a legacy [`SyncOptions`] over (the migration
    /// path for pre-trait callers).
    pub fn from_sync_options(world_size: usize, opts: &SyncOptions) -> Self {
        SyncSessionBuilder::new(world_size)
            .spec(StrategySpec::from(opts.method))
            .with_topology(opts.topo)
            .with_rounding(opts.rounding)
            .with_kahan(opts.kahan)
            .with_average(opts.average)
            .with_fp32_last_layer(opts.fp32_last_layer)
            .with_fused(opts.fused)
    }

    /// Plug in any strategy — the open extension point.
    pub fn strategy(mut self, strategy: Box<dyn SyncStrategy>) -> Self {
        self.strategy = Some(strategy);
        self
    }

    /// Use a built-in strategy described by `spec`.
    pub fn spec(self, spec: StrategySpec) -> Self {
        self.strategy(spec.build())
    }

    /// Wrap the chosen strategy in [`ErrorFeedback`] (residual memory).
    /// Applied at [`Self::build`] time, so it composes with
    /// [`Self::strategy`]/[`Self::spec`] in either order; with no strategy
    /// set it wraps the FP32 default, which is a harmless no-op.
    pub fn error_feedback(mut self) -> Self {
        self.error_feedback = true;
        self
    }

    /// Plug in any collective (overrides [`Self::with_topology`]).
    pub fn collective(mut self, collective: Box<dyn Collective>) -> Self {
        self.collective = Some(collective);
        self
    }

    /// Use the built-in collective for `topo`.
    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topology = topo;
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }

    pub fn with_kahan(mut self, kahan: bool) -> Self {
        self.kahan = kahan;
        self
    }

    pub fn with_average(mut self, yes: bool) -> Self {
        self.average = yes;
        self
    }

    pub fn with_fp32_last_layer(mut self, yes: bool) -> Self {
        self.fp32_last_layer = yes;
        self
    }

    /// Lazy all-reduce: account all layers as one fused message.
    pub fn with_fused(mut self, yes: bool) -> Self {
        self.fused = yes;
        self
    }

    /// Choose how wire traffic is materialized: [`WireMode::Packed`]
    /// (default — bit-packed buffers, payload-proportional simulated
    /// traffic) or [`WireMode::Simulated`] (legacy dense f32 lanes).
    /// Results are bit-identical either way.
    pub fn with_wire(mut self, mode: WireMode) -> Self {
        self.wire = mode;
        self
    }

    /// Cap the packed-fold thread count: `0` (default) sizes the pool
    /// automatically (single-threaded below the parallel threshold), any
    /// explicit `k` is honored exactly — `1` forces the single-threaded
    /// fold, `k > 1` forces a `k`-way split even on small layers. Results
    /// are bit-identical for every value (the split only regroups whole
    /// ring chunks / hierarchical groups onto threads; each element's fold
    /// chain is unchanged — pinned by `rust/tests/packed_parallel.rs`).
    pub fn with_fold_threads(mut self, k: usize) -> Self {
        self.fold_threads = k;
        self
    }

    pub fn build(self) -> SyncSession {
        let world = self.world;
        let collective =
            self.collective.unwrap_or_else(|| self.topology.collective(world));
        assert_eq!(collective.world_size(), world, "collective world size mismatch");
        let mut strategy = self.strategy.unwrap_or_else(|| StrategySpec::Fp32.build());
        // Idempotent: a strategy that is already error-feedback-wrapped
        // (an `ef:` spec from config) is left alone — double residual
        // memory is never what the caller wants. Matches exactly the
        // names ErrorFeedback::name() can produce, so a custom codec
        // whose name merely begins with "ef" still gets wrapped.
        let already_wrapped =
            strategy.name() == "ef" || strategy.name().starts_with("ef:");
        if self.error_feedback && !already_wrapped {
            strategy = Box::new(ErrorFeedback::new(strategy));
        }
        SyncSession {
            strategy,
            collective,
            rounding: self.rounding,
            kahan: self.kahan,
            average: self.average,
            fp32_last_layer: self.fp32_last_layer,
            fused: self.fused,
            wire_mode: self.wire,
            factors: Factors::default(),
            wire: Vec::new(),
            stage: Vec::new(),
            packed: Vec::new(),
            pack_scratch: PackScratch { max_threads: self.fold_threads, ..PackScratch::default() },
            moved: None,
            reduced: Vec::new(),
            report: SyncReport::default(),
            steps_done: 0,
        }
    }
}

impl Default for SyncSessionBuilder {
    /// Single-worker FP32 session (mostly useful in tests).
    fn default() -> Self {
        SyncSessionBuilder::new(1)
    }
}

/// A long-lived gradient-synchronization pipeline: strategy + collective
/// + reusable scratch. See the module docs.
pub struct SyncSession {
    strategy: Box<dyn SyncStrategy>,
    collective: Box<dyn Collective>,
    rounding: Rounding,
    kahan: bool,
    average: bool,
    fp32_last_layer: bool,
    fused: bool,
    wire_mode: WireMode,
    factors: Factors,
    /// Per-worker dense wire buffers for the layer currently in flight —
    /// the [`WireMode::Simulated`] path (capacity grows to the largest
    /// layer, then stays).
    wire: Vec<Vec<f32>>,
    /// One shared encode-staging buffer for the packed path (each
    /// worker's f32 wire values exist only transiently here before being
    /// transcoded into its [`PackedWire`]).
    stage: Vec<f32>,
    /// Per-worker packed byte buffers — what the packed reduction
    /// actually consumes.
    packed: Vec<PackedWire>,
    /// Unpack scratch the collectives borrow during packed reductions.
    pack_scratch: PackScratch,
    /// Measured packed traffic of the last step (None in simulated mode).
    moved: Option<WireCost>,
    /// Per-layer reduced gradients (the step output).
    reduced: Vec<Vec<f32>>,
    report: SyncReport,
    steps_done: u64,
}

impl SyncSession {
    /// Synchronize one training step's gradients (`grads[w][l]` = worker
    /// `w`'s layer-`l` gradient). Returns the reduced per-layer gradients
    /// and the step's [`SyncReport`], both borrowed from session storage
    /// (valid until the next `step` call).
    pub fn step(&mut self, grads: &[Vec<Vec<f32>>]) -> (&[Vec<f32>], &SyncReport) {
        let view = GradView::new(grads);
        let world = self.collective.world_size();
        assert_eq!(view.world(), world, "one gradient set per worker");
        let num_layers = view.num_layers();

        // Reset the report in place (no reallocation in steady state).
        self.report.layers.clear();
        self.report.layers.resize(num_layers, LayerReport::default());
        self.report.payload_bytes = 0;
        self.report.exponent_bytes = 0;
        self.report.steps = 0;
        self.report.messages = if self.fused { 1 } else { num_layers };
        // Honest per-worker wire cost, summed over workers and layers here
        // and averaged into the report at the end of the step — and, on
        // the packed path, the independently measured packed traffic that
        // must come out equal.
        let mut wire_cost = WireCost::default();
        let mut moved = WireCost::default();
        let packed_mode = self.wire_mode == WireMode::Packed;

        // ---- Phase 1: agree on per-layer factors. ----------------------
        self.factors.reset(num_layers);
        let pstats =
            self.strategy.prepare(&view, self.collective.as_ref(), &mut self.factors);
        self.report.exponent_bytes = pstats.bytes_per_worker;
        self.report.steps += pstats.steps;

        // ---- Phase 2: encode (→ pack), reduce, decode — per layer. -----
        if packed_mode {
            self.packed.resize_with(world, PackedWire::default);
        } else {
            // apslint: allow(alloc_in_hot_path) -- grows only on world-size change (empty Vec::new never allocates); steady state reuses the buffers, pinned by rust/tests/session_alloc.rs
            self.wire.resize(world, Vec::new());
        }
        // apslint: allow(alloc_in_hot_path) -- grows only when the model gains layers; steady state reuses the buffers, pinned by rust/tests/session_alloc.rs
        self.reduced.resize(num_layers, Vec::new());
        let base_fmt = self.strategy.wire_format();

        for l in 0..num_layers {
            let n = view.layer_len(l);
            let fp32_passthrough = self.fp32_last_layer && l == num_layers - 1;
            let layer_fmt = if fp32_passthrough { FpFormat::FP32 } else { base_fmt };
            let fe = if layer_fmt.is_fp32() { 0 } else { self.factors.exp(l) };
            let mut ctx = LayerCtx {
                layer: l,
                num_layers,
                worker: 0,
                world,
                factor_exp: fe,
                fmt: layer_fmt,
                fp32_passthrough,
                rounding: self.rounding,
                average: self.average,
                step: self.steps_done,
            };

            let mut nonzero_in = 0usize;
            let mut zero_out = 0usize;
            let mut inf_out = 0usize;
            for w in 0..world {
                ctx.worker = w;
                let src = view.layer_of(w, l);
                // Packed mode stages each worker's f32 wire values in one
                // shared buffer: the only dense copy is transient, and the
                // per-worker storage is the packed bytes.
                let buf: &mut Vec<f32> =
                    if packed_mode { &mut self.stage } else { &mut self.wire[w] };
                buf.resize(n, 0.0);
                self.strategy.encode(src, &ctx, buf);
                // One extra read pass for sparse codecs (nnz counting);
                // dense costs are O(1). Kept as a trait call so the
                // session never assumes how a codec maps zeros.
                wire_cost += self.strategy.wire_cost(buf, &ctx);
                for (&x, &q) in src.iter().zip(buf.iter()) {
                    if x != 0.0 {
                        nonzero_in += 1;
                        if q == 0.0 {
                            zero_out += 1;
                        }
                    }
                    if q.is_infinite() {
                        inf_out += 1;
                    }
                }
                if packed_mode {
                    // Fused encode → pack: transcode this worker's wire
                    // values into its packed buffer and count the bytes
                    // that will actually move through the reduction.
                    self.strategy.encode_packed(&self.stage, &ctx, &mut self.packed[w]);
                    moved += self.packed[w].moved_cost();
                }
            }

            let ropts = ReduceOptions { fmt: layer_fmt, mode: self.rounding, kahan: self.kahan };
            let out = &mut self.reduced[l];
            out.resize(n, 0.0);
            let stats = if packed_mode {
                self.collective.all_reduce_packed_sum_into(
                    &self.packed,
                    self.strategy.as_ref(),
                    &ctx,
                    out,
                    &ropts,
                    &mut self.pack_scratch,
                )
            } else {
                self.collective.all_reduce_sum_into(&self.wire, out, &ropts)
            };
            self.strategy.decode(out, &ctx);

            self.report.layers[l] = LayerReport {
                factor_exp: fe,
                underflow_frac: if nonzero_in == 0 {
                    0.0
                } else {
                    zero_out as f64 / nonzero_in as f64
                },
                overflow_frac: inf_out as f64 / (n * world).max(1) as f64,
                elements: n,
            };
            self.report.payload_bytes += stats.bytes_per_worker;
            if !self.fused {
                self.report.steps += stats.steps;
            }
        }
        if self.fused {
            // One fused message: pay the per-message step count once.
            self.report.steps += self.collective.steps_per_message();
        }
        self.report.wire = wire_cost.per_worker(world);
        // Measured packed traffic, aggregated exactly like `report.wire`
        // so the bench-pinned equality is apples to apples.
        self.moved = packed_mode.then(|| moved.per_worker(world));
        self.steps_done += 1;
        (&self.reduced, &self.report)
    }

    /// The packed wire traffic the last step *actually moved* through the
    /// reduction, per worker (payload bits + metadata, measured from the
    /// [`PackedWire`] buffers) — `None` before the first step and in
    /// [`WireMode::Simulated`]. For every built-in codec on finite
    /// gradients this equals [`SyncReport::wire`] exactly; the strategy
    /// benches assert it (measured bytes-moved == honest accounting).
    pub fn wire_moved(&self) -> Option<WireCost> {
        self.moved
    }

    /// The wire mode this session runs.
    pub fn wire_mode(&self) -> WireMode {
        self.wire_mode
    }

    /// Swap the strategy, keeping the collective and all scratch (the
    /// hybrid-precision schedule's epoch switch).
    pub fn set_strategy(&mut self, strategy: Box<dyn SyncStrategy>) {
        self.strategy = strategy;
    }

    /// The last step's report (empty before the first step).
    pub fn report(&self) -> &SyncReport {
        &self.report
    }

    /// The last step's reduced per-layer gradients.
    pub fn reduced(&self) -> &[Vec<f32>] {
        &self.reduced
    }

    pub fn world_size(&self) -> usize {
        self.collective.world_size()
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn collective_name(&self) -> &'static str {
        self.collective.name()
    }

    /// Whether the session divides reduced sums by the world size.
    pub fn averages(&self) -> bool {
        self.average
    }

    /// Steps synchronized so far.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aps::SyncMethod;

    fn grads(world: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
        (0..world)
            .map(|w| {
                layers
                    .iter()
                    .enumerate()
                    .map(|(l, &n)| {
                        (0..n).map(|i| ((w * 31 + l * 7 + i) % 13) as f32 * 0.25 - 1.5).collect()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn builder_defaults_and_knobs() {
        let s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_rounding(Rounding::TowardZero)
            .with_fused(true)
            .build();
        assert_eq!(s.world_size(), 4);
        assert_eq!(s.strategy_name(), "aps");
        assert_eq!(s.collective_name(), "ring");
        let d = SyncSessionBuilder::default().build();
        assert_eq!(d.world_size(), 1);
        assert_eq!(d.strategy_name(), "fp32");
    }

    #[test]
    fn fp32_session_averages_exactly_for_world_1() {
        let g = grads(1, &[16]);
        let mut s = SyncSessionBuilder::new(1).spec(StrategySpec::Fp32).build();
        let (out, report) = s.step(&g);
        assert_eq!(out[0], g[0][0]);
        assert_eq!(report.payload_bytes, 0);
        assert_eq!(report.messages, 1);
    }

    #[test]
    fn session_reports_match_legacy_shape() {
        let g = grads(8, &[64, 32]);
        let mut s = SyncSessionBuilder::new(8)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .build();
        let (_, report) = s.step(&g);
        assert_eq!(report.layers.len(), 2);
        assert_eq!(report.messages, 2);
        assert!(report.exponent_bytes > 0, "APS pays the exponent phase");
        assert!(report.payload_bytes > 0);
        assert_eq!(s.steps_done(), 1);
    }

    #[test]
    fn session_reports_honest_wire_costs() {
        let g = grads(4, &[64, 32]);
        // fp32: honest cost == dense FP32 payload of one gradient set
        let mut s = SyncSessionBuilder::new(4).spec(StrategySpec::Fp32).build();
        let (_, report) = s.step(&g);
        assert_eq!(report.wire, WireCost::dense(96, FpFormat::FP32));
        assert_eq!(report.wire.total_bytes(), 96 * 4);
        // top-k: index traffic finally shows up, and the honest figure is
        // far below the dense payload
        let mut s = SyncSessionBuilder::new(4).spec(StrategySpec::TopK { frac: 0.25 }).build();
        let (_, report) = s.step(&g);
        assert!(report.wire.index_bits > 0, "top-k must account index bits");
        assert!(report.wire.total_bytes() < 96 * 4, "{:?}", report.wire);
        // qsgd: packed value bits + per-bucket scales
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 1 })
            .build();
        let (_, report) = s.step(&g);
        assert_eq!(report.wire.value_bits, 96 * 4);
        assert_eq!(report.wire.metadata_bytes, 4 * 3);
        // the packed 4-bit payload beats the simulated dense FP32 figure
        assert!(report.honest_bytes() < report.total_bytes(), "{report:?}");
    }

    #[test]
    fn packed_mode_is_default_and_measures_what_it_claims() {
        let g = grads(4, &[64, 32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 5 })
            .build();
        assert_eq!(s.wire_mode(), WireMode::Packed);
        assert!(s.wire_moved().is_none(), "no traffic before the first step");
        let (_, report) = s.step(&g);
        let wire = report.wire;
        // measured packed traffic == honest accounting, field for field
        assert_eq!(s.wire_moved(), Some(wire));
        // ternary: 2 bits per element → 96 elems = 24 bytes per worker
        assert_eq!(wire.value_bits, 2 * 96);

        // simulated mode reports no packed measurement
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 5 })
            .with_wire(WireMode::Simulated)
            .build();
        let (_, report) = s.step(&g);
        let sim_wire = report.wire;
        assert_eq!(sim_wire, wire, "accounting is mode-independent");
        assert_eq!(s.wire_moved(), None);
    }

    #[test]
    fn packed_and_simulated_sessions_are_bit_identical() {
        // The in-crate smoke version of rust/tests/packed_wire.rs: same
        // inputs through both wire modes → same bits, same reports.
        let g = grads(8, &[96, 33]);
        for spec in [
            StrategySpec::Aps { fmt: FpFormat::E5M2 },
            StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 },
            StrategySpec::TopK { frac: 0.25 },
        ] {
            let mut packed = SyncSessionBuilder::new(8).spec(spec.clone()).build();
            let mut sim = SyncSessionBuilder::new(8)
                .spec(spec.clone())
                .with_wire(WireMode::Simulated)
                .build();
            let (po, pr) = packed.step(&g);
            let po = po.to_vec();
            let pr = pr.clone();
            let (so, sr) = sim.step(&g);
            for (l, (a, b)) in po.iter().zip(so.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{spec:?} layer {l} elem {i}");
                }
            }
            assert_eq!(&pr, sr, "{spec:?} report");
        }
    }

    #[test]
    fn error_feedback_builder_wraps_the_strategy() {
        let g = grads(4, &[32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Ternary { seed: 3 })
            .error_feedback()
            .build();
        assert_eq!(s.strategy_name(), "ef:ternary");
        let (_, report) = s.step(&g);
        assert_eq!(report.layers.len(), 1);
        // applied at build time → order-independent w.r.t. spec()
        let s = SyncSessionBuilder::new(4)
            .error_feedback()
            .spec(StrategySpec::Ternary { seed: 3 })
            .build();
        assert_eq!(s.strategy_name(), "ef:ternary");
        // bare error_feedback() wraps the FP32 default
        let d = SyncSessionBuilder::new(2).error_feedback().build();
        assert_eq!(d.strategy_name(), "ef:fp32");
    }

    #[test]
    fn set_strategy_keeps_buffers_and_switches_codec() {
        let g = grads(4, &[32]);
        let mut s = SyncSessionBuilder::new(4)
            .spec(StrategySpec::Naive { fmt: FpFormat::E5M2 })
            .build();
        let _ = s.step(&g);
        assert_eq!(s.strategy_name(), "naive");
        s.set_strategy(StrategySpec::from(SyncMethod::Fp32).build());
        let (_, report) = s.step(&g);
        assert_eq!(s.strategy_name(), "fp32");
        assert_eq!(report.exponent_bytes, 0);
    }
}

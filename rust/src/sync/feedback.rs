//! [`ErrorFeedback`] — residual error feedback over any [`SyncStrategy`].
//!
//! Deep Gradient Compression (Lin et al.) and the 1-bit-SGD line of work
//! show that what makes aggressive gradient compression *converge* is not
//! the codec but the memory: keep the part of the gradient the codec
//! dropped this step and add it back next step. This wrapper implements
//! that as a composable layer:
//!
//! ```text
//! corrected = grad + residual[worker][layer]     (before inner.encode)
//! wire      = inner.encode(corrected)
//! residual[worker][layer] = corrected - reconstruct(wire)
//! ```
//!
//! where `reconstruct` is the inner codec's own `decode` run on a copy of
//! this worker's wire values with averaging disabled — i.e. exactly the
//! gradient-scale value this worker's contribution adds to the reduced
//! sum. No cooperation from the inner codec is needed, so *any* strategy
//! (built-in or user-supplied) can be wrapped.
//!
//! Properties the tests pin:
//!
//! * a lossless inner codec ([`super::Fp32Strategy`]) keeps every residual
//!   exactly zero, and an all-zero residual is bit-transparent (the
//!   wrapper adds nothing to the wire path — `rust/tests/strategy_layer.rs`
//!   pins bit-identity against the unwrapped paper strategies);
//! * residual-corrected ternary / top-k / QSGD reach lower loss than
//!   their memoryless versions on a heterogeneous quadratic workload
//!   (`rust/tests/error_feedback.rs`);
//! * non-finite residual entries are flushed to zero, so one divergent
//!   step cannot poison the memory forever (divergence still reaches the
//!   optimizer through the wire values themselves).
//!
//! All residual and reconstruction scratch lives in the wrapper and is
//! reused step after step — the session's no-per-step-allocation
//! guarantee extends to wrapped codecs once buffers reach steady state.
//! One caveat worth knowing: `prepare` (factor agreement) runs on the
//! *raw* gradients, so codecs whose scale is agreed there encode
//! corrected values against a scale chosen for uncorrected ones.
//! Residuals are geometrically bounded by the codec's relative
//! quantization error (steady-state `|corrected| ≲ |g|/(1-ε)`), which
//! every scale-agreeing codec absorbs: ternary clamps its symbol
//! probability at 1, and the cast codecs (APS/loss-scaling) keep ~2×
//! headroom by construction — APS bounds the shifted worst-case sum at
//! `2^max_exponent` while the format represents up to
//! `(2-2^-m)·2^max_exponent` — far more than the few-percent inflation
//! a residual can add.

use super::wire::PackedWire;
use super::{Factors, GradView, LayerCtx, SyncStrategy, WireCost};
use crate::collectives::{Collective, ReduceStats};
use crate::cpd::FpFormat;
use core::ops::Range;

/// Residual error feedback around an inner [`SyncStrategy`].
///
/// `S` may be a concrete strategy (`ErrorFeedback<TernaryStrategy>`) or a
/// boxed one (`ErrorFeedback<Box<dyn SyncStrategy>>`, which is what
/// `StrategySpec::ErrorFeedback` builds).
pub struct ErrorFeedback<S: SyncStrategy> {
    inner: S,
    /// `residual[worker][layer]` — the signal the codec dropped, at
    /// gradient scale. Lazily sized; reset to zeros when a layer's length
    /// changes between steps.
    residual: Vec<Vec<Vec<f32>>>,
    /// Scratch for `grad + residual` (the codec's actual input).
    corrected: Vec<f32>,
    /// Scratch for the per-worker decode reconstruction.
    recon: Vec<f32>,
}

impl<S: SyncStrategy> ErrorFeedback<S> {
    pub fn new(inner: S) -> Self {
        ErrorFeedback { inner, residual: Vec::new(), corrected: Vec::new(), recon: Vec::new() }
    }

    /// The wrapped codec.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// This worker × layer's current residual (empty before the first
    /// encode of that slot). Exposed so tests can pin residual behaviour.
    pub fn residual(&self, worker: usize, layer: usize) -> &[f32] {
        self.residual
            .get(worker)
            .and_then(|w| w.get(layer))
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// Sum of |residual| over every worker and layer — a cheap "how much
    /// signal is in flight" diagnostic.
    pub fn residual_l1(&self) -> f64 {
        self.residual
            .iter()
            .flat_map(|w| w.iter())
            .flat_map(|l| l.iter())
            .map(|&v| v.abs() as f64)
            .sum()
    }

    /// Make `residual[worker][layer]` exist with length `n` (zeroed on
    /// first use or when the layer shape changed).
    fn ensure_slot(&mut self, worker: usize, layer: usize, n: usize) {
        if self.residual.len() <= worker {
            self.residual.resize_with(worker + 1, Vec::new);
        }
        let per_layer = &mut self.residual[worker];
        if per_layer.len() <= layer {
            per_layer.resize_with(layer + 1, Vec::new);
        }
        let slot = &mut per_layer[layer];
        if slot.len() != n {
            slot.clear();
            slot.resize(n, 0.0);
        }
    }
}

impl<S: SyncStrategy> SyncStrategy for ErrorFeedback<S> {
    fn name(&self) -> &'static str {
        // `&'static` forces a closed mapping; unknown inner codecs get the
        // bare prefix (their session label, not correctness, is affected).
        match self.inner.name() {
            "fp32" => "ef:fp32",
            "naive" => "ef:naive",
            "loss_scaling" => "ef:loss_scaling",
            "aps" => "ef:aps",
            "ternary" => "ef:ternary",
            "topk" => "ef:topk",
            "qsgd" => "ef:qsgd",
            _ => "ef",
        }
    }

    fn wire_format(&self) -> FpFormat {
        self.inner.wire_format()
    }

    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        self.inner.prepare(grads, collective, factors)
    }

    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
        let n = src.len();
        self.ensure_slot(ctx.worker, ctx.layer, n);

        // corrected = src + residual. A zero residual entry passes the
        // source bits through untouched (adding +0.0 would flip -0.0 to
        // +0.0 and break bit-transparency of the zero-residual state).
        self.corrected.clear();
        self.corrected.extend(
            src.iter()
                .zip(self.residual[ctx.worker][ctx.layer].iter())
                .map(|(&s, &r)| if r == 0.0 { s } else { s + r }),
        );

        self.inner.encode(&self.corrected, ctx, out);

        // Reconstruct this worker's effective contribution at gradient
        // scale: the inner decode with averaging off (world division is
        // the only cross-worker part of decode for every shipped codec).
        self.recon.clear();
        self.recon.extend_from_slice(out);
        let solo = LayerCtx { average: false, ..*ctx };
        self.inner.decode(&mut self.recon, &solo);

        let res = &mut self.residual[ctx.worker][ctx.layer];
        for i in 0..n {
            let d = self.corrected[i] - self.recon[i];
            res[i] = if d.is_finite() { d } else { 0.0 };
        }
    }

    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
        self.inner.decode(reduced, ctx);
    }

    fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
        self.inner.wire_cost(encoded, ctx)
    }

    /// The residual correction already happened inside [`Self::encode`];
    /// packing is a pure transcode of the inner codec's wire values, so
    /// both packed hooks forward unchanged.
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        self.inner.encode_packed(encoded, ctx, out)
    }
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        self.inner.decode_packed(packed, ctx, range, out)
    }
    /// Forward the inner codec's opt-in: this wrapper's `decode_packed`
    /// is a pure forward to the inner one, so parallel decode is safe
    /// exactly when the inner codec says it is. (The inner reference is
    /// returned directly — residual state never participates in decode.)
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        self.inner.parallel_decoder()
    }
    /// An encode twin is a fresh `ErrorFeedback` around the inner
    /// codec's own twin. Its residual store starts empty — exactly the
    /// state of a fresh serial wrapper — and because the session pins
    /// worker `w`'s every encode to twin `w` from the first step on,
    /// each twin's `residual[w]` history evolves identically to what the
    /// serial wrapper's slot `w` would hold. Opt-in requires the inner
    /// codec's opt-in.
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        self.inner
            .parallel_encoder()
            .map(|inner| Box::new(ErrorFeedback::new(inner)) as Box<dyn SyncStrategy + Send>)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::Rounding;
    use crate::sync::strategies::{Fp32Strategy, TernaryStrategy, TopKStrategy};

    fn ctx(world: usize, worker: usize) -> LayerCtx {
        LayerCtx {
            layer: 0,
            num_layers: 1,
            worker,
            world,
            factor_exp: 0,
            fmt: FpFormat::FP32,
            fp32_passthrough: false,
            rounding: Rounding::NearestEven,
            average: true,
            step: 0,
        }
    }

    #[test]
    fn lossless_inner_keeps_residual_exactly_zero() {
        let mut ef = ErrorFeedback::new(Fp32Strategy);
        let src = vec![1.5f32, -0.25, 0.0, -0.0, 3.0e-40, 1.0e20];
        let mut out = vec![0.0f32; src.len()];
        for _ in 0..3 {
            ef.encode(&src, &ctx(4, 0), &mut out);
            assert_eq!(out, src);
            assert!(ef.residual(0, 0).iter().all(|&r| r == 0.0), "{:?}", ef.residual(0, 0));
        }
        assert_eq!(ef.residual_l1(), 0.0);
    }

    #[test]
    fn zero_residual_is_bit_transparent() {
        // -0.0 must survive the corrected-gradient construction.
        let mut ef = ErrorFeedback::new(Fp32Strategy);
        let src = vec![-0.0f32, 0.0];
        let mut out = vec![1.0f32; 2];
        ef.encode(&src, &ctx(2, 1), &mut out);
        assert_eq!(out[0].to_bits(), (-0.0f32).to_bits());
        assert_eq!(out[1].to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn lossy_inner_accumulates_the_dropped_signal() {
        // top-k keeps 1 of 4 values; the other three must land in the
        // residual, and get re-offered (and eventually sent) next steps.
        let mut ef = ErrorFeedback::new(TopKStrategy::new(0.25));
        let src = vec![0.1f32, -4.0, 0.2, 0.3];
        let mut out = vec![0.0f32; 4];
        ef.encode(&src, &ctx(1, 0), &mut out);
        assert_eq!(out, vec![0.0, -4.0, 0.0, 0.0]);
        assert_eq!(ef.residual(0, 0), &[0.1, 0.0, 0.2, 0.3]);
        // second step, zero gradient: the biggest residual goes out.
        let zeros = vec![0.0f32; 4];
        ef.encode(&zeros, &ctx(1, 0), &mut out);
        assert_eq!(out, vec![0.0, 0.0, 0.0, 0.3]);
        assert_eq!(ef.residual(0, 0), &[0.1, 0.0, 0.2, 0.0]);
    }

    #[test]
    fn residual_slots_are_per_worker_and_reset_on_shape_change() {
        let mut ef = ErrorFeedback::new(TopKStrategy::new(0.5));
        let mut out = vec![0.0f32; 2];
        ef.encode(&[1.0, 0.5], &ctx(2, 0), &mut out);
        ef.encode(&[2.0, 0.25], &ctx(2, 1), &mut out);
        assert_eq!(ef.residual(2, 0), &[] as &[f32], "untouched worker slot");
        assert_eq!(ef.residual(0, 0), &[0.0, 0.5]);
        assert_eq!(ef.residual(1, 0), &[0.0, 0.25]);
        // shape change resets the slot to zeros before use
        let mut out3 = vec![0.0f32; 3];
        ef.encode(&[1.0, 2.0, 3.0], &ctx(2, 0), &mut out3);
        assert_eq!(ef.residual(0, 0).len(), 3);
    }

    #[test]
    fn non_finite_residuals_are_flushed() {
        let mut ef = ErrorFeedback::new(TernaryStrategy::new(1));
        let src = vec![f32::INFINITY, 0.5];
        let mut out = vec![0.0f32; 2];
        ef.encode(&src, &ctx(1, 0), &mut out);
        assert!(out[0].is_infinite(), "divergence still reaches the wire");
        assert!(ef.residual(0, 0).iter().all(|r| r.is_finite()));
    }
}

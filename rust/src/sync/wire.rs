//! The packed wire: bit-exact byte buffers for encoded gradients.
//!
//! The simulated collectives historically moved every "low-precision"
//! wire value as a full 32-bit `f32` lane, so an 8-bit (or 2-bit) codec
//! paid FP32 memory traffic and the strategy benches could not show the
//! bandwidth win the codecs exist for. This module is the missing layer:
//!
//! * [`BitWriter`] / [`BitReader`] — branch-light word-at-a-time kernels
//!   packing/unpacking values of any width 1..=32 into a byte stream
//!   (little-endian bit order: the first value occupies the lowest bits
//!   of the first byte).
//! * [`PackedWire`] — one worker's encoded layer as the bytes a real
//!   deployment would ship: a representation tag, the bit-packed
//!   value/index payload, and side-channel metadata (per-bucket scales).
//!   Its [`PackedWire::moved_cost`] mirrors [`super::WireCost`]
//!   *exactly* (bit-level accounting before byte rounding), which is what
//!   lets the benches assert measured-bytes-moved ==
//!   `SyncReport::honest_bytes`.
//! * [`PackScratch`] — the session-owned unpack scratch the collectives
//!   borrow during a packed reduction, so the zero-steady-state
//!   allocation invariant extends to the packed path.
//! * [`WireMode`] — the session knob (`packed` is the default;
//!   `simulated` keeps the legacy dense-f32 lanes).
//!
//! Packing is a pure *transcode* of the f32 wire values a strategy's
//! `encode` produced: for every shipped codec,
//! `decode_packed(encode_packed(x)) == x` bit-for-bit, so the packed
//! reduction (same fold order, same operand precision) is bit-identical
//! to the simulated-f32 path — pinned by `rust/tests/packed_wire.rs`.
//!
//! Escape hatch: representations that cannot carry a value in-band
//! (non-finite gradients through a 2-bit ternary wire, NaN through a
//! zero-mantissa float format) fall back to [`PackedWire::pack_raw_f32`]
//! for that layer, and the codec's `wire_cost` reports the same dense
//! FP32 figure, keeping `moved == wire_cost` exact. (The one documented
//! exception: NaN through a `man_bits == 0` cast format escapes to raw
//! f32 while `wire_cost` stays dense — such formats cannot represent NaN
//! at all, and no shipped codec/format combination hits it.)

use super::{LayerCtx, WireCost};
use crate::cpd::cast::{decode_bits, encode_bits_slice_into};
use crate::cpd::{FpFormat, Rounding};
use core::ops::Range;

/// How a [`crate::sync::SyncSession`] materializes wire traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WireMode {
    /// Encoded tensors are transcoded into bit-packed [`PackedWire`]
    /// buffers and the reduction consumes them in cache-blocked chunks —
    /// simulated traffic moves `WireCost` bits, not f32 lanes.
    #[default]
    Packed,
    /// Legacy dense accounting: one `f32` lane per wire value.
    Simulated,
}

/// Representation tags for the built-in packed layouts. Third-party
/// codecs that override `SyncStrategy::{encode_packed, decode_packed}`
/// may use any tag ≥ [`TAG_CUSTOM`].
pub const TAG_RAW_F32: u8 = 0;
/// `FpFormat` bit-codes, `fmt.total_bits()` per element.
pub const TAG_FMT_BITS: u8 = 1;
/// 2-bit ternary symbols (0, +s, −s).
pub const TAG_TERNARY: u8 = 2;
/// QSGD sign+level codes, `bits` per element, per-bucket f32 scales in
/// the metadata channel.
pub const TAG_QSGD: u8 = 3;
/// Sparse `(index, value)` pairs: all indices (ascending, fixed width),
/// then all values (32 bits each).
pub const TAG_SPARSE: u8 = 4;
/// First tag available to out-of-tree representations.
pub const TAG_CUSTOM: u8 = 16;

/// Position bits needed to address one element of an `n`-element layer
/// (`⌈log2 n⌉`, at least 1) — shared by top-k's `wire_cost` and its
/// packed layout so the two never drift apart.
#[inline]
pub fn index_width(n: usize) -> u32 {
    (usize::BITS - n.saturating_sub(1).leading_zeros()).max(1)
}

/// Low byte of the bit accumulator — the one intentional 8-bit
/// truncation at the heart of the LSB-first packer, kept in a named
/// helper so the flush sites read as what they are.
#[inline]
fn low_byte(acc: u64) -> u8 {
    // apslint: allow(lossy_cast) -- explicit low-byte extraction: exactly the 8 bits being flushed
    (acc & 0xFF) as u8
}

/// Byte index of `bit_offset` within an in-memory buffer. Slices are
/// bounded by `isize::MAX` bytes, so the quotient fits `usize` on every
/// target (including 32-bit); the debug assert pins that contract
/// instead of truncating silently.
#[inline]
fn byte_index(bit_offset: u64) -> usize {
    let byte: u64 = bit_offset / 8;
    debug_assert!(
        usize::try_from(byte).is_ok(),
        "bit offset {bit_offset} is beyond addressable memory"
    );
    // apslint: allow(lossy_cast) -- asserted above: byte index of an in-memory slice fits usize
    byte as usize
}

/// Bit position of `bit_offset` within its byte (0..8).
#[inline]
fn bit_rem(bit_offset: u64) -> u32 {
    // apslint: allow(lossy_cast) -- remainder mod 8 is < 8, exact in u32
    (bit_offset % 8) as u32
}

/// Low 32 bits of the bit accumulator — [`low_byte`]'s word-at-a-time
/// sibling for the bulk flush in [`BitWriter::put_many`].
#[inline]
fn low_word(acc: u64) -> u32 {
    // apslint: allow(lossy_cast) -- explicit low-word extraction: exactly the 32 bits being flushed
    (acc & 0xFFFF_FFFF) as u32
}

/// Bulk ranged unpack: extract `out.len()` consecutive `width`-bit codes
/// starting at `bit_offset` of `bytes`. Bit-identical to a
/// [`BitReader::at`] + [`BitReader::read`] loop over the same buffer
/// (reads past the end yield zero bits), but refills the accumulator
/// four bytes at a time (`u32::from_le_bytes`), so the inner loop is one
/// word load + shift/mask per element for widths ≤ 32 — the
/// SIMD-friendly shape the 2-bit ternary and `FpFormat`-width decodes
/// want. Pinned against the scalar loop by the bit-kernel property
/// tests in `rust/tests/packed_parallel.rs`.
pub fn unpack_bits_into(bytes: &[u8], bit_offset: u64, width: u32, out: &mut [u32]) {
    debug_assert!((1..=32).contains(&width));
    let mut pos = byte_index(bit_offset);
    let mut acc: u64 = 0;
    let mut avail: u32 = 0;
    let rem = bit_rem(bit_offset);
    if rem > 0 && pos < bytes.len() {
        acc = (bytes[pos] as u64) >> rem;
        avail = 8 - rem;
        pos += 1;
    }
    let mask = (1u64 << width) - 1;
    for o in out.iter_mut() {
        // Refill: `avail < width ≤ 32` implies the word gulp always
        // fits the 64-bit accumulator; the byte path only runs within
        // four bytes of the buffer's end.
        while avail < width && pos < bytes.len() {
            if pos + 4 <= bytes.len() {
                // apslint: allow(panic_in_hot_path) -- try_into on a 4-byte slice is infallible; bounds checked one line up
                let w = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
                acc |= (w as u64) << avail;
                pos += 4;
                avail += 32;
            } else {
                acc |= (bytes[pos] as u64) << avail;
                pos += 1;
                avail += 8;
            }
        }
        *o = (acc & mask) as u32;
        acc >>= width;
        avail = avail.saturating_sub(width);
    }
}

/// Append-only bit packer over a byte buffer (LSB-first within bytes).
pub struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    acc: u64,
    pending: u32,
    bits: u64,
}

impl<'a> BitWriter<'a> {
    /// Start writing at the current end of `buf`.
    pub fn new(buf: &'a mut Vec<u8>) -> Self {
        BitWriter { buf, acc: 0, pending: 0, bits: 0 }
    }

    /// Append the low `width` bits of `value` (width in 1..=32).
    #[inline]
    pub fn put(&mut self, value: u32, width: u32) {
        debug_assert!((1..=32).contains(&width));
        debug_assert!(width == 32 || value >> width == 0, "value wider than {width} bits");
        self.acc |= (value as u64) << self.pending;
        self.pending += width;
        self.bits += width as u64;
        while self.pending >= 8 {
            self.buf.push(low_byte(self.acc));
            self.acc >>= 8;
            self.pending -= 8;
        }
    }

    /// Append the low `width` bits of each value (width in 1..=32),
    /// flushing the accumulator a 32-bit word at a time. Produces the
    /// exact byte stream of a [`Self::put`] loop — flush granularity
    /// never changes the LSB-first bit stream — and leaves the writer in
    /// a `put`/[`Self::finish`]-compatible state (< 8 pending bits), so
    /// bulk and scalar appends mix freely.
    pub fn put_many(&mut self, values: &[u32], width: u32) {
        debug_assert!((1..=32).contains(&width));
        for &v in values {
            debug_assert!(width == 32 || v >> width == 0, "value wider than {width} bits");
            // `pending < 32` on entry to each iteration (the word flush
            // below restores it), so the shifted value fits the u64
            // accumulator exactly.
            self.acc |= (v as u64) << self.pending;
            self.pending += width;
            self.bits += width as u64;
            while self.pending >= 32 {
                self.buf.extend_from_slice(&low_word(self.acc).to_le_bytes());
                self.acc >>= 32;
                self.pending -= 32;
            }
        }
        while self.pending >= 8 {
            self.buf.push(low_byte(self.acc));
            self.acc >>= 8;
            self.pending -= 8;
        }
    }

    /// Bits appended so far (whether or not flushed to the buffer).
    pub fn bits(&self) -> u64 {
        self.bits
    }

    /// Flush the final partial byte and return the total bits written.
    pub fn finish(self) -> u64 {
        if self.pending > 0 {
            self.buf.push(low_byte(self.acc));
        }
        self.bits
    }
}

/// Sequential bit reader over a byte slice (the mirror of [`BitWriter`]).
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    avail: u32,
}

impl<'a> BitReader<'a> {
    /// Read from the first bit of `bytes`.
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0, acc: 0, avail: 0 }
    }

    /// Read starting at an arbitrary bit offset.
    pub fn at(bytes: &'a [u8], bit_offset: u64) -> Self {
        let mut r = BitReader {
            bytes,
            pos: byte_index(bit_offset),
            acc: 0,
            avail: 0,
        };
        let rem = bit_rem(bit_offset);
        if rem > 0 && r.pos < bytes.len() {
            r.acc = (bytes[r.pos] as u64) >> rem;
            r.avail = 8 - rem;
            r.pos += 1;
        }
        r
    }

    /// Read the next `width` bits (width in 1..=32). Reading past the end
    /// of the buffer yields zero bits.
    #[inline]
    pub fn read(&mut self, width: u32) -> u32 {
        debug_assert!((1..=32).contains(&width));
        while self.avail < width && self.pos < self.bytes.len() {
            self.acc |= (self.bytes[self.pos] as u64) << self.avail;
            self.pos += 1;
            self.avail += 8;
        }
        let mask = (1u64 << width) - 1;
        let v = (self.acc & mask) as u32;
        self.acc >>= width;
        self.avail = self.avail.saturating_sub(width);
        v
    }

    /// Bulk read of `out.len()` consecutive `width`-bit codes — the
    /// multi-word counterpart of a [`Self::read`] loop, bit-identical to
    /// it, delegating to [`unpack_bits_into`]. Afterwards the reader is
    /// positioned exactly past the codes read, so scalar and bulk reads
    /// mix freely.
    #[inline]
    pub fn read_many(&mut self, width: u32, out: &mut [u32]) {
        debug_assert!((1..=32).contains(&width));
        // The accumulator's `avail` bits are the stream bits immediately
        // preceding byte `pos`, so the logical cursor is:
        let start = self.pos as u64 * 8 - self.avail as u64;
        unpack_bits_into(self.bytes, start, width, out);
        let next = start + out.len() as u64 * width as u64;
        self.pos = byte_index(next).min(self.bytes.len());
        self.acc = 0;
        self.avail = 0;
        let rem = bit_rem(next);
        if rem > 0 && self.pos < self.bytes.len() {
            self.acc = (self.bytes[self.pos] as u64) >> rem;
            self.avail = 8 - rem;
            self.pos += 1;
        }
    }
}

/// One worker's encoded layer as packed bytes — see the module docs.
#[derive(Clone, Debug, Default)]
pub struct PackedWire {
    tag: u8,
    elems: usize,
    bytes: Vec<u8>,
    meta: Vec<u8>,
    value_bits: u64,
    index_bits: u64,
    /// Scratch for the bulk format-bit transcode (reused across layers).
    codes: Vec<u32>,
}

impl PackedWire {
    /// Reset for a fresh layer under representation `tag`, keeping all
    /// buffer capacity (no steady-state allocation).
    pub fn reset(&mut self, tag: u8, elems: usize) {
        self.tag = tag;
        self.elems = elems;
        self.bytes.clear();
        self.meta.clear();
        self.value_bits = 0;
        self.index_bits = 0;
    }

    /// Representation tag (`TAG_*`).
    pub fn tag(&self) -> u8 {
        self.tag
    }
    /// Number of encoded elements this buffer represents.
    pub fn elems(&self) -> usize {
        self.elems
    }
    /// The bit-packed payload bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
    /// Mutable payload access for strategy-side [`BitWriter`]s.
    pub fn bytes_mut(&mut self) -> &mut Vec<u8> {
        &mut self.bytes
    }
    /// Payload value bits (accounting, pre byte-rounding).
    pub fn value_bits(&self) -> u64 {
        self.value_bits
    }
    /// Sparse index bits (accounting, pre byte-rounding).
    pub fn index_bits(&self) -> u64 {
        self.index_bits
    }
    /// Record the payload split after writing through [`Self::bytes_mut`].
    pub fn set_bits(&mut self, value_bits: u64, index_bits: u64) {
        debug_assert!(
            (value_bits + index_bits).div_ceil(8) <= self.bytes.len() as u64,
            "recorded bits exceed the packed payload"
        );
        self.value_bits = value_bits;
        self.index_bits = index_bits;
    }

    /// Total payload bytes a deployment would ship for this layer
    /// (value+index bits rounded up, plus metadata).
    pub fn packed_len(&self) -> u64 {
        (self.value_bits + self.index_bits).div_ceil(8) + self.meta.len() as u64
    }

    /// The traffic this buffer actually represents, in [`WireCost`]
    /// terms — the packed path's measured counterpart of
    /// [`crate::sync::SyncStrategy::wire_cost`].
    pub fn moved_cost(&self) -> WireCost {
        WireCost {
            value_bits: self.value_bits,
            index_bits: self.index_bits,
            metadata_bytes: self.meta.len() as u64,
        }
    }

    /// Append one f32 to the metadata side channel (LE bytes).
    pub fn push_meta_f32(&mut self, v: f32) {
        self.meta.extend_from_slice(&v.to_le_bytes());
    }
    /// Read metadata f32 `i` (panics when out of range).
    pub fn meta_f32(&self, i: usize) -> f32 {
        let b = i * 4;
        // apslint: allow(panic_in_hot_path) -- try_into on a 4-byte slice is infallible; the slicing itself is the documented out-of-range panic
        f32::from_le_bytes(self.meta[b..b + 4].try_into().unwrap())
    }
    /// The raw metadata side channel (transport serialization reads it
    /// verbatim; decoding stays with [`Self::meta_f32`]).
    pub fn meta_bytes(&self) -> &[u8] {
        &self.meta
    }

    /// Reassemble a buffer from deserialized frame parts (the transport
    /// seam's counterpart of [`Self::reset`] + writer calls). Keeps all
    /// buffer capacity, including the `codes` transcode scratch.
    pub fn assign_parts(
        &mut self,
        tag: u8,
        elems: usize,
        value_bits: u64,
        index_bits: u64,
        payload: &[u8],
        meta: &[u8],
    ) {
        self.tag = tag;
        self.elems = elems;
        self.bytes.clear();
        self.bytes.extend_from_slice(payload);
        self.meta.clear();
        self.meta.extend_from_slice(meta);
        self.value_bits = value_bits;
        self.index_bits = index_bits;
        debug_assert!(
            (value_bits + index_bits).div_ceil(8) <= self.bytes.len() as u64,
            "deserialized bits exceed the packed payload"
        );
    }

    /// Random-access read of `width` bits at `bit_offset` in the payload
    /// (used by sparse binary search; reads past the end yield zeros).
    pub fn read_bits_at(&self, bit_offset: u64, width: u32) -> u32 {
        debug_assert!((1..=32).contains(&width));
        let byte = byte_index(bit_offset);
        let sh = bit_rem(bit_offset);
        let mut acc = 0u64;
        for (i, &b) in self.bytes.iter().skip(byte).take(8).enumerate() {
            acc |= (b as u64) << (8 * i);
        }
        ((acc >> sh) & ((1u64 << width) - 1)) as u32
    }

    /// Bulk ranged unpack: `out.len()` consecutive `width`-bit codes
    /// starting at `bit_offset` — bit-identical to a [`Self::read_bits_at`]
    /// stride loop, via the multi-word [`unpack_bits_into`] kernel.
    pub fn read_bits_at_many(&self, bit_offset: u64, width: u32, out: &mut [u32]) {
        unpack_bits_into(&self.bytes, bit_offset, width, out);
    }

    // ---- built-in representations -----------------------------------

    /// The universal fallback: raw little-endian f32 lanes. Exact for
    /// every value including NaN payloads; costs dense FP32.
    pub fn pack_raw_f32(&mut self, values: &[f32]) {
        self.reset(TAG_RAW_F32, values.len());
        for v in values {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.value_bits = values.len() as u64 * 32;
    }

    /// Unpack `range` of a [`Self::pack_raw_f32`] buffer into `out`.
    pub fn unpack_raw_f32(&self, range: Range<usize>, out: &mut [f32]) {
        assert_eq!(
            self.tag, TAG_RAW_F32,
            "default decode_packed only understands raw-f32 payloads; \
             override SyncStrategy::decode_packed for custom representations"
        );
        debug_assert_eq!(out.len(), range.len());
        for (k, o) in out.iter_mut().enumerate() {
            let b = (range.start + k) * 4;
            // apslint: allow(panic_in_hot_path) -- try_into on a 4-byte slice is infallible; the slicing itself is the documented out-of-range panic
            *o = f32::from_le_bytes(self.bytes[b..b + 4].try_into().unwrap());
        }
    }

    /// Pack already-quantized wire values as `fmt` bit-codes
    /// (`fmt.total_bits()` per element) via the bulk
    /// [`crate::cpd::cast::encode_bits_slice_into`] kernel. Re-quantizing
    /// a representable value is the identity, so this is a pure
    /// transcode for any `mode`.
    pub fn pack_format_bits(&mut self, encoded: &[f32], fmt: FpFormat, mode: Rounding) {
        self.reset(TAG_FMT_BITS, encoded.len());
        let mut codes = std::mem::take(&mut self.codes);
        codes.clear();
        codes.resize(encoded.len(), 0);
        encode_bits_slice_into(encoded, fmt, mode, &mut codes);
        let width = fmt.total_bits();
        let mut w = BitWriter::new(&mut self.bytes);
        w.put_many(&codes, width);
        self.value_bits = w.finish();
        self.codes = codes;
    }

    /// Unpack `range` of a [`Self::pack_format_bits`] buffer. Codes are
    /// extracted through the multi-word [`unpack_bits_into`] kernel in
    /// stack-resident batches (no allocation), then decoded — the exact
    /// values a scalar [`BitReader`] loop would produce.
    pub fn unpack_format_bits(&self, fmt: FpFormat, range: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(self.tag, TAG_FMT_BITS);
        debug_assert_eq!(out.len(), range.len());
        let width = fmt.total_bits();
        let mut codes = [0u32; 64];
        let mut off = range.start as u64 * width as u64;
        for blk in out.chunks_mut(codes.len()) {
            let codes = &mut codes[..blk.len()];
            unpack_bits_into(&self.bytes, off, width, codes);
            for (o, &c) in blk.iter_mut().zip(codes.iter()) {
                *o = decode_bits(c, fmt);
            }
            off += blk.len() as u64 * width as u64;
        }
    }
}

/// Shared packed encode for the cast codecs (FP32 / naive / loss-scaling
/// / APS): format bit-codes at the layer's wire width, with the raw-f32
/// escape for the identity format and for NaN through zero-mantissa
/// formats (which have no NaN code).
pub(crate) fn pack_cast_layer(encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
    let fmt = ctx.fmt;
    if fmt.is_fp32() || (fmt.man_bits == 0 && encoded.iter().any(|v| v.is_nan())) {
        out.pack_raw_f32(encoded);
    } else {
        out.pack_format_bits(encoded, fmt, ctx.rounding);
    }
}

/// Shared packed decode for the cast codecs.
pub(crate) fn unpack_cast_range(
    packed: &PackedWire,
    ctx: &LayerCtx,
    range: Range<usize>,
    out: &mut [f32],
) {
    match packed.tag() {
        TAG_RAW_F32 => packed.unpack_raw_f32(range, out),
        _ => packed.unpack_format_bits(ctx.fmt, range, out),
    }
}

/// Session-owned scratch the collectives borrow during a packed
/// reduction: one cache-block unpack buffer for the built-in chunked
/// folds, plus dense per-worker buffers for the compatibility default of
/// [`crate::collectives::Collective::all_reduce_packed_sum_into`].
#[derive(Clone, Debug, Default)]
pub struct PackScratch {
    /// One unpack block (`collectives::FOLD_BLOCK` elements once warm).
    pub chunk: Vec<f32>,
    /// Per-thread unpack blocks for the parallel packed fold, one slot
    /// per worker thread. Session-owned (grown on first parallel fold,
    /// reused every step after) so the zero-steady-state-allocation pin
    /// extends to the parallel path.
    pub chunks: Vec<Vec<f32>>,
    /// Thread-count cap for the parallel packed fold. `0` (the default)
    /// auto-selects: [`crate::util::par::num_threads`] capped by the
    /// tensor size against [`crate::util::par::PAR_THRESHOLD`]. Any
    /// explicit value is honored exactly — `1` forces the
    /// single-threaded fold, `k > 1` forces a `k`-way split regardless
    /// of size, which is the determinism test hook
    /// (`rust/tests/packed_parallel.rs` permutes it and asserts
    /// bit-identical results).
    pub max_threads: usize,
    /// Dense per-worker staging for collectives without a packed fold.
    pub dense: Vec<Vec<f32>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn bitwriter_bitreader_roundtrip_all_widths() {
        for width in 1..=32u32 {
            let mut rng = Rng::new(100 + width as u64);
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let vals: Vec<u32> = (0..97).map(|_| rng.next_u64() as u32 & mask).collect();
            let mut buf = Vec::new();
            let mut w = BitWriter::new(&mut buf);
            for &v in &vals {
                w.put(v, width);
            }
            let bits = w.finish();
            assert_eq!(bits, 97 * width as u64);
            assert_eq!(buf.len() as u64, bits.div_ceil(8));
            let mut r = BitReader::new(&buf);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(r.read(width), v, "width {width} elem {i}");
            }
        }
    }

    #[test]
    fn bitreader_at_arbitrary_offsets() {
        // Mixed widths; then re-read each value via BitReader::at and
        // read_bits_at at its recorded offset (word-boundary crossings
        // included by construction).
        let mut rng = Rng::new(7);
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        let mut entries = Vec::new(); // (offset, width, value)
        let mut off = 0u64;
        for _ in 0..500 {
            let width = 1 + rng.below(32) as u32;
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let v = rng.next_u64() as u32 & mask;
            entries.push((off, width, v));
            w.put(v, width);
            off += width as u64;
        }
        let total = w.finish();
        assert_eq!(total, off);
        let mut pw = PackedWire::default();
        pw.reset(TAG_CUSTOM, 500);
        pw.bytes_mut().extend_from_slice(&buf);
        for &(off, width, v) in &entries {
            let mut r = BitReader::at(&buf, off);
            assert_eq!(r.read(width), v, "seq at {off}");
            assert_eq!(pw.read_bits_at(off, width), v, "random at {off}");
        }
    }

    #[test]
    fn raw_f32_roundtrip_preserves_all_bits() {
        let vals = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::NAN,
            f32::from_bits(0x7fa0_0001), // non-canonical NaN payload
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(1), // min subnormal
            f32::MAX,
        ];
        let mut pw = PackedWire::default();
        pw.pack_raw_f32(&vals);
        assert_eq!(pw.tag(), TAG_RAW_F32);
        assert_eq!(pw.value_bits(), vals.len() as u64 * 32);
        assert_eq!(pw.moved_cost(), WireCost::dense(vals.len(), FpFormat::FP32));
        let mut out = vec![0.0f32; vals.len()];
        pw.unpack_raw_f32(0..vals.len(), &mut out);
        for (a, b) in vals.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // ranged unpack
        let mut mid = vec![0.0f32; 3];
        pw.unpack_raw_f32(2..5, &mut mid);
        assert_eq!(mid[0], 1.5);
        assert!(mid[1].is_nan());
        assert_eq!(mid[2].to_bits(), 0x7fa0_0001);
    }

    #[test]
    fn format_bits_roundtrip_on_quantized_values() {
        use crate::cpd::{quantize, Rounding::NearestEven};
        for fmt in [FpFormat::E5M2, FpFormat::E4M3, FpFormat::BF16, FpFormat::new(6, 9)] {
            let mut rng = Rng::new(fmt.total_bits() as u64);
            let raw: Vec<f32> = (0..300)
                .map(|_| rng.normal() * (rng.range(-20.0, 20.0)).exp2())
                .collect();
            let q: Vec<f32> = raw.iter().map(|&x| quantize(x, fmt, NearestEven)).collect();
            let mut pw = PackedWire::default();
            let ctx_rounding = NearestEven;
            pw.pack_format_bits(&q, fmt, ctx_rounding);
            assert_eq!(pw.value_bits(), 300 * fmt.total_bits() as u64);
            assert_eq!(pw.moved_cost(), WireCost::dense(300, fmt));
            let mut out = vec![0.0f32; 300];
            pw.unpack_format_bits(fmt, 0..300, &mut out);
            for (i, (a, b)) in q.iter().zip(&out).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt} elem {i}: {a:e} vs {b:e}");
            }
            // ranged unpack across a word boundary
            let mut seg = vec![0.0f32; 7];
            pw.unpack_format_bits(fmt, 13..20, &mut seg);
            for (k, o) in seg.iter().enumerate() {
                assert_eq!(o.to_bits(), q[13 + k].to_bits());
            }
        }
    }

    #[test]
    fn put_many_matches_put_loop_and_mixes_with_scalar() {
        for width in 1..=32u32 {
            let mut rng = Rng::new(4000 + width as u64);
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let vals: Vec<u32> = (0..133).map(|_| rng.next_u64() as u32 & mask).collect();
            let mut scalar = Vec::new();
            let mut w = BitWriter::new(&mut scalar);
            for &v in &vals {
                w.put(v, width);
            }
            let scalar_bits = w.finish();
            let mut bulk = Vec::new();
            let mut w = BitWriter::new(&mut bulk);
            // Mix scalar and bulk appends: prefix scalar, middle bulk,
            // suffix scalar — the byte stream must not care.
            w.put(vals[0], width);
            w.put_many(&vals[1..vals.len() - 1], width);
            w.put(vals[vals.len() - 1], width);
            assert_eq!(w.finish(), scalar_bits, "width {width}");
            assert_eq!(bulk, scalar, "width {width}");
        }
    }

    #[test]
    fn bulk_unpack_matches_scalar_readers() {
        // Fixed-width streams at every width, read back four ways.
        for width in 1..=32u32 {
            let mut rng = Rng::new(9000 + width as u64);
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let vals: Vec<u32> = (0..157).map(|_| rng.next_u64() as u32 & mask).collect();
            let mut buf = Vec::new();
            let mut w = BitWriter::new(&mut buf);
            w.put_many(&vals, width);
            w.finish();
            // Bulk from offset 0, from a mid offset, and past the end.
            for start in [0usize, 1, 57, 150, 157] {
                let off = start as u64 * width as u64;
                let mut bulk = vec![0u32; vals.len() + 8 - start];
                unpack_bits_into(&buf, off, width, &mut bulk);
                let mut r = BitReader::at(&buf, off);
                for (k, &b) in bulk.iter().enumerate() {
                    assert_eq!(b, r.read(width), "width {width} start {start} elem {k}");
                    if start + k < vals.len() {
                        assert_eq!(b, vals[start + k]);
                    } else {
                        assert_eq!(b, 0, "past-end reads must yield zeros");
                    }
                }
            }
            // read_many interleaved with scalar reads stays in sync.
            let mut r = BitReader::new(&buf);
            let mut out = vec![0u32; 40];
            assert_eq!(r.read(width), vals[0]);
            r.read_many(width, &mut out);
            assert_eq!(out, vals[1..41], "width {width}");
            assert_eq!(r.read(width), vals[41], "width {width}");
        }
    }

    #[test]
    fn index_width_matches_ceil_log2() {
        assert_eq!(index_width(1), 1);
        assert_eq!(index_width(2), 1);
        assert_eq!(index_width(3), 2);
        assert_eq!(index_width(6), 3);
        assert_eq!(index_width(256), 8);
        assert_eq!(index_width(257), 9);
        assert_eq!(index_width(65536), 16);
    }

    #[test]
    fn packed_len_rounds_bits_to_bytes_plus_meta() {
        let mut pw = PackedWire::default();
        pw.reset(TAG_QSGD, 5);
        let mut w = BitWriter::new(pw.bytes_mut());
        for i in 0..5 {
            w.put(i, 3);
        }
        let bits = w.finish();
        pw.set_bits(bits, 0);
        pw.push_meta_f32(0.5);
        assert_eq!(pw.packed_len(), 2 + 4); // 15 bits → 2 bytes, + 4 meta
        assert_eq!(
            pw.moved_cost(),
            WireCost { value_bits: 15, index_bits: 0, metadata_bytes: 4 }
        );
        assert_eq!(pw.meta_f32(0), 0.5);
    }
}

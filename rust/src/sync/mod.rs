//! The pluggable gradient-synchronization layer.
//!
//! The paper treats APS as one point in an open family of low-precision
//! gradient-synchronization codecs (FP32, naive cast, loss scaling, APS,
//! hybrid — and beyond: TernGrad, QSGD, Deep Gradient Compression, …).
//! This module is the extension point that makes the family open:
//!
//! * [`SyncStrategy`] — a codec: `prepare` (agree on per-layer scale
//!   factors across workers), `encode` (one worker's layer → wire
//!   values), `decode` (reduced wire values → gradient scale), plus
//!   [`SyncStrategy::wire_format`] for the reduction precision and
//!   [`SyncStrategy::wire_cost`] for honest traffic accounting. The four
//!   paper methods are [`strategies::Fp32Strategy`],
//!   [`strategies::NaiveStrategy`], [`strategies::LossScalingStrategy`]
//!   and [`strategies::ApsStrategy`]; [`strategies::TernaryStrategy`]
//!   (TernGrad-style), [`strategies::TopKStrategy`] (sparsification) and
//!   [`strategies::QsgdStrategy`] (bucketed stochastic quantization) are
//!   net-new codecs proving extensibility.
//! * [`ErrorFeedback`] — a composable wrapper that layers residual memory
//!   (Deep-Gradient-Compression-style error feedback) over any strategy:
//!   the quantization error of each step is stored per worker × layer and
//!   added back to the next step's gradient before encoding, turning
//!   lossy codecs into convergent ones. Configs spell it `ef:<codec>`.
//! * [`WireCost`] — the structured per-worker traffic model a codec
//!   reports through [`SyncStrategy::wire_cost`]: packed payload *value
//!   bits*, sparse-codec *index bits*, and side-channel *metadata bytes*
//!   (per-bucket scales and the like). Sparse codecs such as top-k
//!   finally account their index traffic honestly; the session aggregates
//!   the per-layer costs into [`crate::aps::SyncReport::wire`].
//! * [`wire`] — the packed wire: [`wire::PackedWire`] byte buffers with
//!   [`wire::BitWriter`]/[`wire::BitReader`] kernels. Under the default
//!   [`wire::WireMode::Packed`], [`SyncStrategy::encode_packed`]
//!   transcodes each worker's encoded layer into `WireCost`-tight bytes
//!   (2-bit ternary symbols, QSGD `bits`/element + bucket scales,
//!   `FpFormat`-width bit-codes, sparse index/value pairs) and the
//!   collectives reduce by unpacking cache-blocked chunks — so the
//!   simulated traffic moves what `WireCost` claims, not f32 lanes,
//!   while staying bit-identical to the simulated path
//!   (`rust/tests/packed_wire.rs`).
//! * [`crate::collectives::Collective`] — a pluggable all-reduce
//!   (ring / hierarchical today), consumed by strategies and the session,
//!   with a packed entry point (`all_reduce_packed_sum_into`) whose
//!   default unpacks to the dense path so third-party collectives keep
//!   working.
//! * [`SyncSession`] — owns one strategy, one collective and all scratch
//!   buffers (wire tensors, packed buffers, exponent vectors, per-layer
//!   reports); [`SyncSession::step`] synchronizes one training step's
//!   gradients with no per-step element-storage allocation — Kahan
//!   compensation included (stack-blocked in the fold kernels). Build it
//!   with [`SyncSessionBuilder`]; [`SyncSession::wire_moved`] reports the
//!   packed bytes a step actually moved.
//!
//! Every shipped codec (and every future one) is pinned by the shared
//! conformance contract in `rust/tests/codec_conformance.rs` (run in both
//! wire modes): encode writes every element, round-trips stay bounded on
//! hostile inputs, wire costs never under-report, replays are
//! deterministic, and ragged inputs panic.
//!
//! The deprecated `aps::synchronize` one-shot shim has been removed after
//! its one-release grace period — build a [`SyncSession`];
//! `aps::legacy::synchronize` keeps the pre-trait implementation for the
//! bit-identity equivalence suite.

pub mod feedback;
pub mod ps;
pub mod session;
pub mod strategies;
pub mod transport;
pub mod wire;

pub use crate::aps::{BucketStats, LayerReport, SyncReport};
pub use feedback::ErrorFeedback;
pub use ps::PsCollective;
pub use session::{SyncSession, SyncSessionBuilder};
pub use transport::{
    BucketPlan, FaultKind, Transport, TransportError, TransportSpec, TransportTraffic,
};
pub use strategies::{
    ApsStrategy, Fp32Strategy, LossScalingStrategy, NaiveStrategy, QsgdStrategy, TernaryStrategy,
    TopKStrategy,
};
pub use wire::{unpack_bits_into, BitReader, BitWriter, PackScratch, PackedWire, WireMode};

use crate::aps::SyncMethod;
use crate::collectives::{Collective, ReduceStats};
use crate::cpd::{FpFormat, Rounding};
use core::ops::Range;

/// Borrowed view of every worker's per-layer gradients for one step
/// (`grads[w][l]` = worker `w`'s gradient tensor for layer `l`).
pub struct GradView<'a> {
    workers: &'a [Vec<Vec<f32>>],
}

impl<'a> GradView<'a> {
    /// Wrap worker-major gradients, checking all workers agree on the
    /// layer count and every layer's length (codecs and the session size
    /// wire buffers from worker 0, so ragged inputs must fail loudly
    /// here, as the legacy reduce's assert did).
    pub fn new(workers: &'a [Vec<Vec<f32>>]) -> Self {
        assert!(!workers.is_empty(), "need at least one worker");
        let layers = workers[0].len();
        assert!(workers.iter().all(|g| g.len() == layers), "ragged layer counts");
        for l in 0..layers {
            let n = workers[0][l].len();
            assert!(
                workers.iter().all(|g| g[l].len() == n),
                "ragged layer lengths at layer {l}"
            );
        }
        GradView { workers }
    }

    pub fn world(&self) -> usize {
        self.workers.len()
    }
    pub fn num_layers(&self) -> usize {
        self.workers[0].len()
    }
    pub fn layer_len(&self, layer: usize) -> usize {
        self.workers[0][layer].len()
    }
    /// Worker `w`'s gradient for `layer`.
    pub fn layer_of(&self, w: usize, layer: usize) -> &'a [f32] {
        &self.workers[w][layer]
    }
    /// All worker tensors for use as collective contributions.
    pub fn workers(&self) -> &'a [Vec<Vec<f32>>] {
        self.workers
    }
}

/// Per-layer power-of-two factors agreed in a strategy's prepare phase,
/// plus the agreement scratch (owned by the session, reused every step).
#[derive(Debug, Default)]
pub struct Factors {
    /// Per-layer factor exponent (the shift APS/loss-scaling applies, or
    /// the scale exponent of a ternary codec). Zero for unscaled codecs.
    pub(crate) exps: Vec<i32>,
    /// Per-worker × per-layer i8 contributions to the exponent max-reduce.
    pub(crate) i8_contribs: Vec<Vec<i8>>,
    /// Reduced per-layer maxima.
    pub(crate) i8_max: Vec<i8>,
}

impl Factors {
    /// The agreed factor exponent for `layer`.
    pub fn exp(&self, layer: usize) -> i32 {
        self.exps[layer]
    }
    /// All per-layer factor exponents.
    pub fn exps(&self) -> &[i32] {
        &self.exps
    }

    /// Reset to `num_layers` zeroed factors (reusing storage).
    pub(crate) fn reset(&mut self, num_layers: usize) {
        self.exps.clear();
        self.exps.resize(num_layers, 0);
    }

    /// Size the i8 agreement scratch for `world × num_layers`.
    pub(crate) fn ensure_i8(&mut self, world: usize, num_layers: usize) {
        self.i8_contribs.resize(world, Vec::new());
        for c in &mut self.i8_contribs {
            c.clear();
            c.resize(num_layers, 0);
        }
        self.i8_max.clear();
        self.i8_max.resize(num_layers, i8::MIN);
    }
}

/// Everything [`SyncStrategy::encode`] / [`SyncStrategy::decode`] need to
/// know about the layer being processed.
#[derive(Clone, Copy, Debug)]
pub struct LayerCtx {
    /// Layer index and total layer count.
    pub layer: usize,
    pub num_layers: usize,
    /// Worker whose gradient is being encoded (encode only).
    pub worker: usize,
    /// Number of data-parallel workers.
    pub world: usize,
    /// The factor exponent agreed for this layer (0 when the layer's wire
    /// format is FP32 — e.g. under the fp32-last-layer policy).
    pub factor_exp: i32,
    /// The wire format for *this* layer (fp32-last-layer already applied).
    pub fmt: FpFormat,
    /// True when the fp32-last-layer policy protects this layer: codecs
    /// must send it dense at full precision. Explicit because FP32-wire
    /// codecs (e.g. top-k) cannot infer the policy from `fmt` alone.
    pub fp32_passthrough: bool,
    /// Rounding for wire casts.
    pub rounding: Rounding,
    /// Whether the session divides the reduced sum by the world size.
    pub average: bool,
    /// Monotone step counter (seeds stochastic codecs deterministically).
    pub step: u64,
}

/// Structured per-worker wire cost of one encoded tensor — what a real
/// deployment would put on the network for it, as opposed to the
/// simulation's dense `f32` buffers.
///
/// The three components keep sparse and quantized codecs honest:
///
/// * `value_bits` — packed payload bits for the values actually shipped
///   (`n × format bits` for dense codecs, `nnz × 32` for top-k,
///   `n × qsgd_bits` for QSGD, `2n` for packed ternary symbols);
/// * `index_bits` — position bits a sparse codec needs so the receiver
///   can place the values (`nnz × ⌈log2 n⌉` for top-k; zero for dense);
/// * `metadata_bytes` — side-channel constants shipped alongside the
///   payload (QSGD's per-bucket scales; zero when the prepare phase
///   already carries the scale, as for APS/ternary exponent agreement).
///
/// Costs add ([`core::ops::AddAssign`]) across layers and workers; the
/// session folds one cost per worker × layer into
/// [`crate::aps::SyncReport::wire`] as a per-worker mean.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireCost {
    /// Packed payload bits for the transmitted values.
    pub value_bits: u64,
    /// Sparse-codec position/index bits (zero for dense codecs).
    pub index_bits: u64,
    /// Side-channel metadata bytes (scales, bucket norms, …).
    pub metadata_bytes: u64,
}

impl WireCost {
    /// Dense accounting: every element ships in `fmt`, no indices, no
    /// metadata.
    pub fn dense(elements: usize, fmt: FpFormat) -> Self {
        WireCost {
            value_bits: elements as u64 * fmt.total_bits() as u64,
            index_bits: 0,
            metadata_bytes: 0,
        }
    }

    /// Total bytes on the wire (value+index bits rounded up to whole
    /// bytes, plus metadata).
    pub fn total_bytes(&self) -> u64 {
        (self.value_bits + self.index_bits).div_ceil(8) + self.metadata_bytes
    }

    /// Per-worker mean of a cost summed over `world` workers. Rounds up
    /// so the mean never under-reports (exact whenever all workers ship
    /// the same shape, as dense codecs do — the legacy bit-identity
    /// equivalence relies on that exactness).
    pub(crate) fn per_worker(self, world: usize) -> WireCost {
        let w = world as u64;
        WireCost {
            value_bits: self.value_bits.div_ceil(w),
            index_bits: self.index_bits.div_ceil(w),
            metadata_bytes: self.metadata_bytes.div_ceil(w),
        }
    }
}

impl core::ops::AddAssign for WireCost {
    fn add_assign(&mut self, rhs: WireCost) {
        self.value_bits += rhs.value_bits;
        self.index_bits += rhs.index_bits;
        self.metadata_bytes += rhs.metadata_bytes;
    }
}

/// A gradient-synchronization codec.
///
/// A strategy is pure policy: it never owns communication or reduction
/// buffers (the [`SyncSession`] does) and talks to the network only via
/// the [`Collective`] handed into [`SyncStrategy::prepare`]. Methods take
/// `&mut self` so implementations may keep internal scratch (e.g. the
/// top-k selection buffer).
pub trait SyncStrategy {
    /// Short human name (config/report/bench labels).
    fn name(&self) -> &'static str;

    /// The wire format gradient payloads travel (and partial sums are
    /// re-quantized) in. `FP32` means the codec is full-precision.
    fn wire_format(&self) -> FpFormat;

    /// Phase 1: agree on per-layer factors across workers, writing them
    /// into `factors` (already reset to zeros) and returning the wire
    /// traffic of the agreement. The default needs no agreement.
    fn prepare(
        &mut self,
        grads: &GradView,
        collective: &dyn Collective,
        factors: &mut Factors,
    ) -> ReduceStats {
        let _ = (grads, collective, factors);
        ReduceStats::default()
    }

    /// Phase 2: encode one worker's layer gradient into wire values
    /// (`out.len() == src.len()`; every element must be written).
    fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]);

    /// Phase 3: transform the reduced wire values back to gradient scale
    /// in place (undo the factor shift, apply averaging).
    fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx);

    /// The honest per-worker wire cost of one encoded layer (`encoded` is
    /// this worker's [`SyncStrategy::encode`] output). The default is
    /// dense shipping in the layer's wire format; sparse/quantized codecs
    /// override it to account index traffic and metadata. Must never
    /// under-report: the conformance suite checks
    /// `value_bits + index_bits ≥ nnz(encoded)`. On the packed wire path
    /// it must also *match* what [`SyncStrategy::encode_packed`] ships
    /// (`PackedWire::moved_cost`), which the packed-wire suite and the
    /// bytes-moved bench column pin.
    fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
        WireCost::dense(encoded.len(), ctx.fmt)
    }

    /// Transcode this worker's already-encoded f32 wire values (`encoded`
    /// is the output of the immediately preceding [`SyncStrategy::encode`]
    /// call for the same layer) into packed bytes. The contract:
    /// `decode_packed` over any range must reproduce `encoded`
    /// bit-for-bit, so the packed reduction stays bit-identical to the
    /// simulated-f32 path.
    ///
    /// The default falls back to raw f32 lanes
    /// ([`PackedWire::pack_raw_f32`]) — third-party codecs keep working
    /// on the packed path, merely without the bandwidth win. Built-in
    /// codecs override it to pack `WireCost`-tight layouts (format
    /// bit-codes, 2-bit ternary symbols, QSGD sign+level codes, sparse
    /// index/value pairs).
    fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
        let _ = ctx;
        out.pack_raw_f32(encoded);
    }

    /// Unpack `range` (element indices) of one worker's packed layer back
    /// into dense f32 wire values — the exact inverse of
    /// [`SyncStrategy::encode_packed`]. Called by collectives in
    /// cache-blocked chunks during a packed reduction; must be pure
    /// (`&self`) and support arbitrary sub-ranges.
    fn decode_packed(
        &self,
        packed: &PackedWire,
        ctx: &LayerCtx,
        range: Range<usize>,
        out: &mut [f32],
    ) {
        let _ = ctx;
        packed.unpack_raw_f32(range, out);
    }

    /// Opt into the parallel packed fold: return `Some(self)` when this
    /// strategy's [`SyncStrategy::decode_packed`] may be called from
    /// multiple threads concurrently (it is `&self`-pure and the type is
    /// `Sync`). The collectives then split the fold across chunk
    /// boundaries — fold order within each element's chain is unchanged,
    /// so results stay bit-identical to the single-threaded path
    /// (`rust/tests/packed_parallel.rs` pins this at 1/2/4/8 threads).
    ///
    /// The default is `None`: third-party codecs keep the
    /// single-threaded fold unless they explicitly opt in. All built-in
    /// strategies opt in.
    fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
        None
    }

    /// Opt into the parallel encode fan-out: return a fresh *encode
    /// twin* — an independently owned strategy configured identically to
    /// `self` (same format, seed, sparsity, …) with empty scratch. The
    /// session builds one twin per worker and pins worker `w`'s entire
    /// encode→[`SyncStrategy::encode_packed`] chain to twin `w` forever,
    /// so per-worker codec state (error-feedback residuals, the QSGD
    /// encode→pack coupling, selection scratch) lives in exactly one
    /// object and evolves independently of how twins are scheduled onto
    /// threads — outputs are bit-identical at any encode thread count
    /// (`rust/tests/encode_parallel.rs` pins this at 0/1/2/4/8 threads).
    ///
    /// The default is `None`: third-party codecs keep the
    /// single-threaded encode loop unless they explicitly opt in. All
    /// built-in strategies opt in.
    fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
        None
    }
}

/// Forwarding impls so boxed strategies compose (e.g.
/// `ErrorFeedback<Box<dyn SyncStrategy>>`, which is what
/// [`StrategySpec::build`] produces for `ef:`-prefixed specs, and
/// `ErrorFeedback<Box<dyn SyncStrategy + Send>>`, which is what its
/// [`SyncStrategy::parallel_encoder`] twin wraps).
macro_rules! forward_sync_strategy {
    ($ty:ty) => {
        impl SyncStrategy for $ty {
            fn name(&self) -> &'static str {
                (**self).name()
            }
            fn wire_format(&self) -> FpFormat {
                (**self).wire_format()
            }
            fn prepare(
                &mut self,
                grads: &GradView,
                collective: &dyn Collective,
                factors: &mut Factors,
            ) -> ReduceStats {
                (**self).prepare(grads, collective, factors)
            }
            fn encode(&mut self, src: &[f32], ctx: &LayerCtx, out: &mut [f32]) {
                (**self).encode(src, ctx, out)
            }
            fn decode(&mut self, reduced: &mut [f32], ctx: &LayerCtx) {
                (**self).decode(reduced, ctx)
            }
            fn wire_cost(&self, encoded: &[f32], ctx: &LayerCtx) -> WireCost {
                (**self).wire_cost(encoded, ctx)
            }
            fn encode_packed(&mut self, encoded: &[f32], ctx: &LayerCtx, out: &mut PackedWire) {
                (**self).encode_packed(encoded, ctx, out)
            }
            fn decode_packed(
                &self,
                packed: &PackedWire,
                ctx: &LayerCtx,
                range: Range<usize>,
                out: &mut [f32],
            ) {
                (**self).decode_packed(packed, ctx, range, out)
            }
            fn parallel_decoder(&self) -> Option<&(dyn SyncStrategy + Sync)> {
                (**self).parallel_decoder()
            }
            fn parallel_encoder(&self) -> Option<Box<dyn SyncStrategy + Send>> {
                (**self).parallel_encoder()
            }
        }
    };
}

forward_sync_strategy!(Box<dyn SyncStrategy>);
forward_sync_strategy!(Box<dyn SyncStrategy + Send>);

/// Undo the power-of-two shift and apply data-parallel averaging —
/// bit-identical to the pre-trait `aps::synchronize` epilogue (f64
/// arithmetic, single rounding back to f32).
pub(crate) fn unscale_in_place(xs: &mut [f32], factor_exp: i32, world: usize, average: bool) {
    // apslint: allow(lossy_cast) -- factor_exp is a small FP exponent (|fe| < 2^15), so its negation is exact in i32
    let unscale = -(factor_exp as i64) as i32;
    let div = if average { world as f64 } else { 1.0 };
    let m = (unscale as f64).exp2() / div;
    for v in xs.iter_mut() {
        *v = (*v as f64 * m) as f32;
    }
}

/// A buildable description of a built-in strategy — what configs and CLI
/// flags parse into. The *open* extension point is
/// [`SyncSessionBuilder::strategy`], which accepts any boxed
/// [`SyncStrategy`]; this enum only enumerates the codecs shipped in-tree.
#[derive(Clone, Debug, PartialEq)]
pub enum StrategySpec {
    /// Full-precision baseline.
    Fp32,
    /// Low-precision cast, no scaling.
    Naive { fmt: FpFormat },
    /// One global hand-chosen power-of-two factor.
    LossScaling { fmt: FpFormat, factor_exp: i32 },
    /// Auto-Precision Scaling (Algorithm 1).
    Aps { fmt: FpFormat },
    /// TernGrad-style stochastic ternarization.
    Ternary { seed: u64 },
    /// Top-k magnitude sparsification (keep the largest `frac` share).
    TopK { frac: f32 },
    /// QSGD-style bucketed stochastic quantization (`bits` per value
    /// including sign, per-bucket max-norm scale).
    Qsgd { bits: u8, bucket: usize, seed: u64 },
    /// Residual error feedback layered over any built-in codec
    /// (config name `ef:<codec>`).
    ErrorFeedback { inner: Box<StrategySpec> },
}

impl StrategySpec {
    /// Instantiate the strategy this spec describes.
    pub fn build(&self) -> Box<dyn SyncStrategy> {
        match self {
            StrategySpec::Fp32 => Box::new(Fp32Strategy),
            StrategySpec::Naive { fmt } => Box::new(NaiveStrategy::new(*fmt)),
            StrategySpec::LossScaling { fmt, factor_exp } => {
                Box::new(LossScalingStrategy::new(*fmt, *factor_exp))
            }
            StrategySpec::Aps { fmt } => Box::new(ApsStrategy::new(*fmt)),
            StrategySpec::Ternary { seed } => Box::new(TernaryStrategy::new(*seed)),
            StrategySpec::TopK { frac } => Box::new(TopKStrategy::new(*frac)),
            StrategySpec::Qsgd { bits, bucket, seed } => {
                Box::new(QsgdStrategy::new(*bits, *bucket, *seed))
            }
            StrategySpec::ErrorFeedback { inner } => Box::new(ErrorFeedback::new(inner.build())),
        }
    }

    /// The legacy closed-enum method, when this spec has one.
    pub fn as_sync_method(&self) -> Option<SyncMethod> {
        match self {
            StrategySpec::Fp32 => Some(SyncMethod::Fp32),
            StrategySpec::Naive { fmt } => Some(SyncMethod::Naive { fmt: *fmt }),
            StrategySpec::LossScaling { fmt, factor_exp } => {
                Some(SyncMethod::LossScaling { fmt: *fmt, factor_exp: *factor_exp })
            }
            StrategySpec::Aps { fmt } => Some(SyncMethod::Aps { fmt: *fmt }),
            StrategySpec::Ternary { .. }
            | StrategySpec::TopK { .. }
            | StrategySpec::Qsgd { .. }
            | StrategySpec::ErrorFeedback { .. } => None,
        }
    }

    /// Compact config-style label (`aps/e5m2`, `topk@0.25`, `qsgd b4/256`,
    /// `ef:ternary`) for tables and bench rows.
    pub fn label(&self) -> String {
        match self {
            StrategySpec::Fp32 => "fp32".to_string(),
            StrategySpec::Naive { fmt } => format!("naive/{fmt}"),
            StrategySpec::LossScaling { fmt, factor_exp } => {
                format!("loss_scaling/{fmt}^{factor_exp}")
            }
            StrategySpec::Aps { fmt } => format!("aps/{fmt}"),
            StrategySpec::Ternary { .. } => "ternary".to_string(),
            StrategySpec::TopK { frac } => format!("topk@{frac}"),
            StrategySpec::Qsgd { bits, bucket, .. } => format!("qsgd b{bits}/{bucket}"),
            StrategySpec::ErrorFeedback { inner } => format!("ef:{}", inner.label()),
        }
    }
}

impl From<SyncMethod> for StrategySpec {
    fn from(m: SyncMethod) -> Self {
        match m {
            SyncMethod::Fp32 => StrategySpec::Fp32,
            SyncMethod::Naive { fmt } => StrategySpec::Naive { fmt },
            SyncMethod::LossScaling { fmt, factor_exp } => {
                StrategySpec::LossScaling { fmt, factor_exp }
            }
            SyncMethod::Aps { fmt } => StrategySpec::Aps { fmt },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrips_legacy_methods() {
        for m in [
            SyncMethod::Fp32,
            SyncMethod::Naive { fmt: FpFormat::E5M2 },
            SyncMethod::LossScaling { fmt: FpFormat::E4M3, factor_exp: 7 },
            SyncMethod::Aps { fmt: FpFormat::E3M0 },
        ] {
            let spec = StrategySpec::from(m);
            assert_eq!(spec.as_sync_method(), Some(m));
        }
        assert_eq!(StrategySpec::Ternary { seed: 1 }.as_sync_method(), None);
        assert_eq!(StrategySpec::TopK { frac: 0.25 }.as_sync_method(), None);
        assert_eq!(
            StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 1 }.as_sync_method(),
            None
        );
        assert_eq!(
            StrategySpec::ErrorFeedback { inner: Box::new(StrategySpec::Fp32) }.as_sync_method(),
            None
        );
    }

    #[test]
    fn spec_labels_and_builds() {
        let ef = StrategySpec::ErrorFeedback {
            inner: Box::new(StrategySpec::Ternary { seed: 3 }),
        };
        assert_eq!(ef.label(), "ef:ternary");
        assert_eq!(ef.build().name(), "ef:ternary");
        let q = StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 9 };
        assert_eq!(q.label(), "qsgd b4/256");
        assert_eq!(q.build().name(), "qsgd");
        assert_eq!(StrategySpec::Fp32.label(), "fp32");
    }

    #[test]
    fn wire_cost_arithmetic() {
        let dense = WireCost::dense(100, FpFormat::E5M2);
        assert_eq!(dense.value_bits, 800);
        assert_eq!(dense.total_bytes(), 100);
        let mut c = WireCost { value_bits: 7, index_bits: 2, metadata_bytes: 3 };
        // 9 bits → 2 bytes, plus 3 metadata
        assert_eq!(c.total_bytes(), 5);
        c += WireCost::dense(2, FpFormat::FP32);
        assert_eq!(c.value_bits, 71);
        assert_eq!(c.index_bits, 2);
        let half = WireCost { value_bits: 10, index_bits: 4, metadata_bytes: 8 }.per_worker(2);
        assert_eq!(half, WireCost { value_bits: 5, index_bits: 2, metadata_bytes: 4 });
    }

    #[test]
    fn grad_view_shape() {
        let grads = vec![vec![vec![1.0f32; 4], vec![2.0; 2]]; 3];
        let v = GradView::new(&grads);
        assert_eq!(v.world(), 3);
        assert_eq!(v.num_layers(), 2);
        assert_eq!(v.layer_len(1), 2);
        assert_eq!(v.layer_of(2, 0), &[1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "ragged layer counts")]
    fn grad_view_rejects_ragged() {
        let grads = vec![vec![vec![1.0f32; 4]], vec![]];
        let _ = GradView::new(&grads);
    }

    #[test]
    fn unscale_matches_legacy_formula() {
        let mut xs = vec![8.0f32, -2.0, 0.5];
        unscale_in_place(&mut xs, 2, 4, true);
        // 2^-2 / 4 = 1/16
        assert_eq!(xs, vec![0.5, -0.125, 0.03125]);
        let mut ys = vec![3.0f32];
        unscale_in_place(&mut ys, 0, 8, false);
        assert_eq!(ys, vec![3.0]);
    }
}

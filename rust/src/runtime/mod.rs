//! PJRT runtime — loads and executes the JAX-lowered HLO artifacts.
//!
//! The compile path (`make artifacts`) runs Python **once**: each model's
//! `loss_and_grads` (and an eval function) is lowered by
//! `python/compile/aot.py` to HLO *text* plus a JSON [`ModelSpec`]. This
//! module is the only place that touches the `xla` crate: it compiles the
//! text with the PJRT CPU client and exposes typed `train_step` /
//! `eval_*` calls to the coordinator. Python never runs on this path.
//!
//! HLO text (not serialized protos) is the interchange format — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, bail, ensure, Context};
use std::path::{Path, PathBuf};

#[doc(hidden)]
pub mod xla_stub;
// The PJRT seam: this module is written against the real `xla` crate's
// API; offline builds alias it to the in-tree stub (see xla_stub docs).
use self::xla_stub as xla;

/// What a model's eval artifact returns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalOutput {
    /// Per-example (or per-pixel) logits — classifier / segmenter.
    Logits,
    /// A scalar mean loss — language model.
    Loss,
}

/// Element type of the model's `x` input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XDtype {
    F32,
    I32,
}

/// One parameter tensor's metadata.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Metadata emitted by `aot.py` alongside each pair of HLO artifacts.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Parameter tensors in artifact argument order.
    pub params: Vec<ParamSpec>,
    /// Per-exec batch the artifacts were lowered at.
    pub batch: usize,
    /// Per-example `x` shape (e.g. `[32, 32, 3]`, or `[seq_len]` for LM).
    pub x_shape: Vec<usize>,
    pub x_dtype: XDtype,
    /// Per-example `y` shape (`[]` scalar label, `[h, w]` mask, `[s]` LM).
    pub y_shape: Vec<usize>,
    pub num_classes: usize,
    pub eval_output: EvalOutput,
    pub train_artifact: String,
    pub eval_artifact: String,
    /// Seed used for the reference init emitted in `<name>.init.json`.
    pub init_seed: u64,
    /// Vmapped one-dispatch training artifacts keyed by worker count
    /// (`<name>.train_w{W}.hlo.txt`, see aot.py MULTI_WORLDS).
    pub multi_train: std::collections::BTreeMap<usize, String>,
}

impl ModelSpec {
    /// Parse the JSON document `aot.py` writes (snake_case keys).
    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let x_dtype = match j.get("x_dtype")?.as_str()? {
            "f32" => XDtype::F32,
            "i32" => XDtype::I32,
            other => bail!("unknown x_dtype {other:?}"),
        };
        let eval_output = match j.get("eval_output")?.as_str()? {
            "logits" => EvalOutput::Logits,
            "loss" => EvalOutput::Loss,
            other => bail!("unknown eval_output {other:?}"),
        };
        Ok(ModelSpec {
            name: j.get("name")?.as_str()?.to_string(),
            params,
            batch: j.get("batch")?.as_usize()?,
            x_shape: j.get("x_shape")?.as_usize_vec()?,
            x_dtype,
            y_shape: j.get("y_shape")?.as_usize_vec()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            eval_output,
            train_artifact: j.get("train_artifact")?.as_str()?.to_string(),
            eval_artifact: j.get("eval_artifact")?.as_str()?.to_string(),
            init_seed: j.get("init_seed")?.as_u64()?,
            multi_train: match j.opt("multi_train") {
                Some(m) => m
                    .as_obj()?
                    .iter()
                    .map(|(k, v)| {
                        Ok((
                            k.parse::<usize>()
                                .map_err(|e| anyhow!("multi_train key {k:?}: {e}"))?,
                            v.as_str()?.to_string(),
                        ))
                    })
                    .collect::<Result<_>>()?,
                None => Default::default(),
            },
        })
    }

    pub fn param_lens(&self) -> Vec<usize> {
        self.params.iter().map(|p| p.len()).collect()
    }
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
    pub fn x_elems_per_example(&self) -> usize {
        self.x_shape.iter().product()
    }
    pub fn y_elems_per_example(&self) -> usize {
        self.y_shape.iter().product()
    }
}

/// The PJRT client (one per process).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile a model's artifacts from `dir` (e.g. `artifacts/`).
    pub fn load_model(&self, dir: impl AsRef<Path>, name: &str) -> Result<Model> {
        let dir = dir.as_ref();
        let spec_path = dir.join(format!("{name}.json"));
        let text = std::fs::read_to_string(&spec_path)
            .with_context(|| format!("reading {spec_path:?} — run `make artifacts`?"))?;
        let spec = ModelSpec::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parsing {spec_path:?}"))?;
        let train = self.compile_hlo(&dir.join(&spec.train_artifact))?;
        let eval = self.compile_hlo(&dir.join(&spec.eval_artifact))?;
        let mut multi_train = std::collections::BTreeMap::new();
        for (&world, fname) in &spec.multi_train {
            multi_train.insert(world, self.compile_hlo(&dir.join(fname))?);
        }
        Ok(Model { spec, train, eval, multi_train, dir: dir.to_path_buf() })
    }

    /// Compile one HLO text file.
    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
    }

    /// Load the standalone Pallas quantize kernel artifact
    /// (`quantize.hlo.txt`): `(x[f32;N], factor_exp, exp_bits, man_bits)
    /// → f32[N]`. Used to cross-check the Rust cast path.
    pub fn load_quantizer(&self, dir: impl AsRef<Path>) -> Result<QuantizeKernel> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("quantize.json"))
            .context("reading quantize.json — run `make artifacts`?")?;
        let j = Json::parse(&text)?;
        let artifact = j.get("artifact")?.as_str()?.to_string();
        let n = j.get("n")?.as_usize()?;
        let exe = self.compile_hlo(&dir.join(&artifact))?;
        Ok(QuantizeKernel { exe, n })
    }
}

/// The AOT-compiled Pallas quantize kernel.
pub struct QuantizeKernel {
    exe: xla::PjRtLoadedExecutable,
    /// Fixed element count the kernel was lowered at.
    pub n: usize,
}

impl QuantizeKernel {
    /// Quantize `xs` (padded/chunked to the kernel's fixed size) with the
    /// given shift and format.
    pub fn run(&self, xs: &[f32], factor_exp: i32, exp_bits: u8, man_bits: u8) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.n) {
            let mut buf = chunk.to_vec();
            buf.resize(self.n, 0.0);
            let x = xla::Literal::vec1(&buf);
            let fe = xla::Literal::scalar(factor_exp);
            let eb = xla::Literal::scalar(exp_bits as i32);
            let mb = xla::Literal::scalar(man_bits as i32);
            let res = self
                .exe
                .execute::<xla::Literal>(&[x, fe, eb, mb])
                .map_err(|e| anyhow!("quantize exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("quantize sync: {e:?}"))?;
            let lit = res.to_tuple1().map_err(|e| anyhow!("quantize tuple: {e:?}"))?;
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("quantize vec: {e:?}"))?;
            out.extend_from_slice(&v[..chunk.len()]);
        }
        Ok(out)
    }
}

/// Parameter tensors pre-converted to PJRT literals (see
/// [`Model::prepare_params`]).
pub struct PreparedParams {
    literals: Vec<xla::Literal>,
}

/// A compiled model: train + eval executables and the spec.
pub struct Model {
    pub spec: ModelSpec,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    /// Vmapped training executables keyed by worker count.
    multi_train: std::collections::BTreeMap<usize, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Model {
    /// Load the reference initial parameters emitted by `aot.py`
    /// (`<name>.init.json`) so Rust and Python start from identical
    /// weights.
    pub fn initial_params(&self) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(format!("{}.init.json", self.spec.name));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`?"))?;
        let j = Json::parse(&text)?;
        let flat: Vec<Vec<f32>> = j
            .as_arr()?
            .iter()
            .map(|a| a.as_f32_vec())
            .collect::<Result<_>>()?;
        ensure!(
            flat.len() == self.spec.params.len(),
            "init param count {} != spec {}",
            flat.len(),
            self.spec.params.len()
        );
        for (f, p) in flat.iter().zip(&self.spec.params) {
            ensure!(f.len() == p.len(), "param {} length mismatch", p.name);
        }
        Ok(flat)
    }

    /// Build the parameter literals once; reuse across many executions in
    /// the same step (all simulated workers share parameters, so this
    /// saves `world_size − 1` conversions per training step).
    pub fn prepare_params(&self, params: &[Vec<f32>]) -> Result<PreparedParams> {
        Ok(PreparedParams { literals: self.param_literals(params)? })
    }

    fn param_literals(&self, params: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
        ensure!(params.len() == self.spec.params.len(), "param count mismatch");
        params
            .iter()
            .zip(&self.spec.params)
            .map(|(p, s)| {
                let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(p)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {}: {e:?}", s.name))
            })
            .collect()
    }

    fn x_literal(&self, x_f32: Option<&[f32]>, x_i32: Option<&[i32]>) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![self.spec.batch as i64];
        dims.extend(self.spec.x_shape.iter().map(|&d| d as i64));
        let lit = match self.spec.x_dtype {
            XDtype::F32 => {
                let x = x_f32.ok_or_else(|| anyhow!("model expects f32 x"))?;
                xla::Literal::vec1(x)
            }
            XDtype::I32 => {
                let x = x_i32.ok_or_else(|| anyhow!("model expects i32 x"))?;
                xla::Literal::vec1(x)
            }
        };
        lit.reshape(&dims).map_err(|e| anyhow!("reshape x: {e:?}"))
    }

    fn y_literal(&self, y: &[i32]) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![self.spec.batch as i64];
        dims.extend(self.spec.y_shape.iter().map(|&d| d as i64));
        xla::Literal::vec1(y)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape y: {e:?}"))
    }

    /// One forward+backward: returns `(loss, per-layer gradients)`.
    ///
    /// `x` length must be `batch * x_elems_per_example`; labels length
    /// `batch * y_elems_per_example`.
    pub fn train_step(
        &self,
        params: &[Vec<f32>],
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let prepared = self.prepare_params(params)?;
        self.train_step_prepared(&prepared, x_f32, x_i32, y)
    }

    /// `train_step` against pre-converted parameter literals (the
    /// coordinator's per-step fast path).
    pub fn train_step_prepared(
        &self,
        prepared: &PreparedParams,
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let x = self.x_literal(x_f32, x_i32)?;
        let yl = self.y_literal(y)?;
        let mut args: Vec<&xla::Literal> = prepared.literals.iter().collect();
        args.push(&x);
        args.push(&yl);
        let res = self
            .train
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("train exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train sync: {e:?}"))?;
        let mut parts = res.to_tuple().map_err(|e| anyhow!("train tuple: {e:?}"))?;
        ensure!(
            parts.len() == 1 + self.spec.params.len(),
            "expected loss + {} grads, got {} outputs",
            self.spec.params.len(),
            parts.len()
        );
        let grads: Vec<Vec<f32>> = parts
            .drain(1..)
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("grad vec: {e:?}")))
            .collect::<Result<_>>()?;
        let loss = parts[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss scalar: {e:?}"))?;
        Ok((loss, grads))
    }

    /// True when a vmapped artifact exists for `world` workers.
    pub fn has_multi_train(&self, world: usize) -> bool {
        self.multi_train.contains_key(&world)
    }

    /// All workers' forward+backward in ONE dispatch via the vmapped
    /// artifact: `x_all`/`y_all` hold every worker's shard concatenated
    /// along a leading worker axis. Returns `(mean_loss, grads[w][layer])`.
    pub fn train_step_multi(
        &self,
        prepared: &PreparedParams,
        world: usize,
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y: &[i32],
    ) -> Result<(f32, Vec<Vec<Vec<f32>>>)> {
        let exe = self
            .multi_train
            .get(&world)
            .ok_or_else(|| anyhow!("no vmapped artifact for world={world}"))?;
        let mut x_dims: Vec<i64> = vec![world as i64, self.spec.batch as i64];
        x_dims.extend(self.spec.x_shape.iter().map(|&d| d as i64));
        let x = match self.spec.x_dtype {
            XDtype::F32 => xla::Literal::vec1(
                x_f32.ok_or_else(|| anyhow!("model expects f32 x"))?,
            ),
            XDtype::I32 => xla::Literal::vec1(
                x_i32.ok_or_else(|| anyhow!("model expects i32 x"))?,
            ),
        }
        .reshape(&x_dims)
        .map_err(|e| anyhow!("reshape multi x: {e:?}"))?;
        let mut y_dims: Vec<i64> = vec![world as i64, self.spec.batch as i64];
        y_dims.extend(self.spec.y_shape.iter().map(|&d| d as i64));
        let yl = xla::Literal::vec1(y)
            .reshape(&y_dims)
            .map_err(|e| anyhow!("reshape multi y: {e:?}"))?;

        let mut args: Vec<&xla::Literal> = prepared.literals.iter().collect();
        args.push(&x);
        args.push(&yl);
        let res = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("multi train exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("multi train sync: {e:?}"))?;
        let mut parts = res.to_tuple().map_err(|e| anyhow!("multi tuple: {e:?}"))?;
        ensure!(
            parts.len() == 1 + self.spec.params.len(),
            "expected loss + {} stacked grads, got {}",
            self.spec.params.len(),
            parts.len()
        );
        let loss = parts[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("multi loss: {e:?}"))?;
        // grads[layer] is [world, …]; split into per-worker tensors.
        let mut per_worker: Vec<Vec<Vec<f32>>> =
            vec![Vec::with_capacity(self.spec.params.len()); world];
        for (l, lit) in parts.drain(1..).enumerate() {
            let flat = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("multi grad vec: {e:?}"))?;
            let len = self.spec.params[l].len();
            ensure!(flat.len() == world * len, "stacked grad {l} size mismatch");
            for w in 0..world {
                per_worker[w].push(flat[w * len..(w + 1) * len].to_vec());
            }
        }
        Ok((loss, per_worker))
    }

    /// Eval forward pass: logits (or scalar loss for LM) for one batch.
    pub fn eval_step(
        &self,
        params: &[Vec<f32>],
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
        y: Option<&[i32]>,
    ) -> Result<Vec<f32>> {
        let mut args = self.param_literals(params)?;
        args.push(self.x_literal(x_f32, x_i32)?);
        if self.spec.eval_output == EvalOutput::Loss {
            let y = y.ok_or_else(|| anyhow!("LM eval needs targets"))?;
            args.push(self.y_literal(y)?);
        }
        let res = self
            .eval
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("eval exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("eval sync: {e:?}"))?;
        let lit = res.to_tuple1().map_err(|e| anyhow!("eval tuple: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("eval vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC_JSON: &str = r#"{
        "name": "mlp",
        "params": [
            {"name": "w1", "shape": [192, 64]},
            {"name": "b1", "shape": [64]}
        ],
        "batch": 32, "x_shape": [8, 8, 3], "x_dtype": "f32", "y_shape": [],
        "num_classes": 10, "eval_output": "logits",
        "train_artifact": "mlp.train.hlo.txt",
        "eval_artifact": "mlp.eval.hlo.txt", "init_seed": 7
    }"#;

    #[test]
    fn spec_parses_from_python_json() {
        let spec = ModelSpec::from_json(&Json::parse(SPEC_JSON).unwrap()).unwrap();
        assert_eq!(spec.total_params(), 192 * 64 + 64);
        assert_eq!(spec.param_lens(), vec![192 * 64, 64]);
        assert_eq!(spec.x_elems_per_example(), 192);
        assert_eq!(spec.y_elems_per_example(), 1);
        assert_eq!(spec.x_dtype, XDtype::F32);
        assert_eq!(spec.eval_output, EvalOutput::Logits);
        assert_eq!(spec.init_seed, 7);
    }

    #[test]
    fn spec_rejects_bad_enums() {
        let bad = SPEC_JSON.replace("\"f32\"", "\"f64\"");
        assert!(ModelSpec::from_json(&Json::parse(&bad).unwrap()).is_err());
        let bad = SPEC_JSON.replace("\"logits\"", "\"probs\"");
        assert!(ModelSpec::from_json(&Json::parse(&bad).unwrap()).is_err());
    }
}

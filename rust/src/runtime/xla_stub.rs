//! Build-time stub for the `xla` PJRT bindings.
//!
//! The real `xla` crate wraps the native `xla_extension` shared library,
//! which is not vendorable and not present in offline build environments.
//! This module mirrors the exact API surface [`super`] uses so the crate
//! (and every simulation-only test, bench, and example) compiles and runs
//! without it. Every entry point that would touch PJRT fails fast at
//! [`PjRtClient::cpu`] with an instructive error; nothing downstream is
//! reachable without a client.
//!
//! To run the real HLO artifacts, add the `xla` bindings as a dependency
//! and replace the `use xla_stub as xla;` seam in `runtime/mod.rs` — the
//! rest of the runtime module is written against the real crate's API.

/// Error type standing in for `xla::Error` (only `Debug` is needed by the
/// call sites, which wrap it into `anyhow!` messages).
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT backend unavailable: this build uses the in-tree xla stub \
         (see rust/src/runtime/xla_stub.rs); simulation paths work, but \
         executing HLO artifacts requires the real `xla` bindings"
            .to_string(),
    ))
}

/// Marker for element types PJRT literals can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable()
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Self {
        Literal
    }
    pub fn scalar<T: NativeType>(_value: T) -> Self {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        unavailable()
    }
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        unavailable()
    }
}

//! Bench-trajectory regression comparison for `BENCH_packed.json`.
//!
//! CI reruns the hotpath smoke bench on every push and diffs the fresh
//! record against the committed baseline
//! (`benches/BENCH_baseline.json`). Two very different kinds of columns,
//! two very different tolerances:
//!
//! * **`bytes_moved` is exact.** Wire traffic is deterministic honest
//!   accounting — a single byte of drift means a codec, transport, or
//!   the accounting itself changed, and that is a correctness event, not
//!   noise.
//! * **`elems_per_sec` is gated at −20 %, machine-normalized.** Raw
//!   rates are incomparable across machines, so each strategy's rate is
//!   first divided by the *same file's* `dense_fp32@sim` rate (every
//!   record carries that dense baseline row). The normalized ratio must
//!   stay ≥ 0.8 × the baseline's ratio; speedups and noise upward pass
//!   freely.
//!
//! Keys present only in the current record are reported as additions
//! (new codecs/transports appear legitimately); keys that *vanish* are
//! regressions — losing a row silently is how coverage rots. A baseline
//! with an empty `strategies` map (the bootstrap state, stamped with a
//! `note`) accepts any current record; `--refresh` then commits the
//! measured record as the new baseline.

use super::json::Json;

/// The dense baseline row every record must carry for rate normalization.
pub const NORM_KEY: &str = "dense_fp32@sim";

/// Allowed fractional loss in machine-normalized throughput.
pub const RATE_FLOOR: f64 = 0.8;

/// One comparison's outcome. `regressions` non-empty ⇒ the gate fails.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DiffReport {
    /// Keys compared in both records.
    pub compared: usize,
    /// Keys only in the current record (new coverage; informational).
    pub added: Vec<String>,
    /// Human-readable regression lines (empty ⇒ pass).
    pub regressions: Vec<String>,
    /// Non-fatal observations (e.g. empty bootstrap baseline).
    pub notes: Vec<String>,
}

impl DiffReport {
    pub fn ok(&self) -> bool {
        self.regressions.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "benchdiff: {} keys compared, {} added, {} regressions\n",
            self.compared,
            self.added.len(),
            self.regressions.len()
        ));
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        for a in &self.added {
            out.push_str(&format!("  added: {a}\n"));
        }
        for r in &self.regressions {
            out.push_str(&format!("  REGRESSION: {r}\n"));
        }
        out
    }
}

fn strategies(doc: &Json) -> Result<&std::collections::BTreeMap<String, Json>, String> {
    doc.get("strategies")
        .and_then(|s| s.as_obj())
        .map_err(|e| format!("record has no strategies map: {e}"))
}

fn row_f64(rows: &std::collections::BTreeMap<String, Json>, key: &str, field: &str) -> Option<f64> {
    rows.get(key)?.opt(field)?.as_f64().ok()
}

/// The machine-normalizing denominator: the record's own dense fp32 rate.
fn norm_rate(rows: &std::collections::BTreeMap<String, Json>) -> Option<f64> {
    row_f64(rows, NORM_KEY, "elems_per_sec").filter(|r| *r > 0.0)
}

/// Compare a freshly measured record against the committed baseline.
/// Returns `Err` only for malformed documents; measured regressions come
/// back inside the report.
pub fn compare(baseline: &Json, current: &Json) -> Result<DiffReport, String> {
    let base = strategies(baseline)?;
    let cur = strategies(current)?;
    let mut report = DiffReport::default();

    if base.is_empty() {
        report.notes.push(
            "baseline has no strategy rows (bootstrap); any current record passes — \
             refresh the baseline to arm the gate"
                .to_string(),
        );
        if let Ok(note) = baseline.get("note").and_then(|n| n.as_str()) {
            report.notes.push(format!("baseline note: {note}"));
        }
    }

    let base_norm = norm_rate(base);
    let cur_norm = norm_rate(cur);
    if !base.is_empty() && base_norm.is_none() {
        report
            .notes
            .push(format!("baseline lacks a positive {NORM_KEY} rate; rate gate skipped"));
    }
    if !cur.is_empty() && cur_norm.is_none() {
        report
            .regressions
            .push(format!("current record lacks the {NORM_KEY} normalization row"));
    }

    for (key, base_row) in base {
        let Some(cur_row) = cur.get(key) else {
            report.regressions.push(format!(
                "{key}: present in baseline but missing from the current record \
                 (bench coverage lost)"
            ));
            continue;
        };
        report.compared += 1;

        match (
            base_row.opt("bytes_moved").and_then(|v| v.as_f64().ok()),
            cur_row.opt("bytes_moved").and_then(|v| v.as_f64().ok()),
        ) {
            (Some(b), Some(c)) => {
                if b != c {
                    report.regressions.push(format!(
                        "{key}: bytes_moved changed {b} -> {c} (wire traffic is exact; \
                         any drift is a codec/accounting change)"
                    ));
                }
            }
            _ => report
                .regressions
                .push(format!("{key}: bytes_moved column missing")),
        }

        if key == NORM_KEY {
            continue; // The denominator is not gated against itself.
        }
        if let (Some(bn), Some(cn)) = (base_norm, cur_norm) {
            match (
                base_row.opt("elems_per_sec").and_then(|v| v.as_f64().ok()),
                cur_row.opt("elems_per_sec").and_then(|v| v.as_f64().ok()),
            ) {
                (Some(b), Some(c)) => {
                    if b <= 0.0 {
                        // A non-positive baseline rate makes the floor
                        // check vacuous (anything ≥ 0.8 × 0): say so
                        // instead of silently passing forever.
                        report.notes.push(format!(
                            "{key}: baseline elems_per_sec is {b} (non-positive); \
                             rate floor cannot gate this row — refresh the baseline"
                        ));
                        continue;
                    }
                    let base_ratio = b / bn;
                    let cur_ratio = c / cn;
                    if cur_ratio < RATE_FLOOR * base_ratio {
                        report.regressions.push(format!(
                            "{key}: normalized throughput fell below the {RATE_FLOOR}x floor \
                             (baseline {base_ratio:.3}x of dense, current {cur_ratio:.3}x)"
                        ));
                    }
                }
                _ => report
                    .regressions
                    .push(format!("{key}: elems_per_sec column missing")),
            }
        }
    }

    for key in cur.keys() {
        if !base.contains_key(key) {
            report.added.push(key.clone());
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(rows: &[(&str, f64, f64)]) -> Json {
        let mut s = std::collections::BTreeMap::new();
        for (k, bytes, rate) in rows {
            let mut row = std::collections::BTreeMap::new();
            row.insert("bytes_moved".to_string(), Json::Num(*bytes));
            row.insert("elems_per_sec".to_string(), Json::Num(*rate));
            s.insert(k.to_string(), Json::Obj(row));
        }
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("hotpath-packed".to_string()));
        doc.insert("strategies".to_string(), Json::Obj(s));
        Json::Obj(doc)
    }

    #[test]
    fn identical_records_pass() {
        let rows =
            [(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7), ("fp32@ring", 4096.0, 8e7)];
        let r = compare(&record(&rows), &record(&rows)).expect("well-formed");
        assert!(r.ok(), "{}", r.render());
        assert_eq!(r.compared, 3);
        assert!(r.added.is_empty());
    }

    #[test]
    fn bytes_drift_is_a_regression() {
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 257.0, 9e7)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(!r.ok());
        assert!(r.regressions[0].contains("bytes_moved"), "{}", r.render());
    }

    #[test]
    fn machine_speed_changes_cancel_out() {
        // Current machine is 10x slower across the board: every raw rate
        // drops, but the dense-normalized ratios are unchanged — pass.
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e7), ("ternary@ring", 256.0, 9e6)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(r.ok(), "{}", r.render());
    }

    #[test]
    fn normalized_slowdown_past_floor_fails() {
        // Dense rate unchanged, ternary alone fell to 0.5x its baseline
        // ratio — a genuine per-codec regression.
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 4.5e7)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(!r.ok());
        assert!(r.regressions[0].contains("floor"), "{}", r.render());
    }

    #[test]
    fn small_noise_within_floor_passes() {
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 7.5e7)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(r.ok(), "0.83x of baseline ratio is within the 0.8 floor: {}", r.render());
    }

    #[test]
    fn vanished_key_is_a_regression_and_new_key_is_an_addition() {
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[
            (NORM_KEY, 4096.0, 1e8),
            ("overlap_ternary@tcp", 256.0, 8e7),
        ]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(!r.ok());
        assert!(r.regressions[0].contains("coverage lost"));
        assert_eq!(r.added, ["overlap_ternary@tcp"]);
    }

    #[test]
    fn empty_bootstrap_baseline_accepts_anything() {
        let base = record(&[]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(r.ok(), "{}", r.render());
        assert_eq!(r.compared, 0);
        assert_eq!(r.added.len(), 2);
        assert!(r.notes.iter().any(|n| n.contains("bootstrap")), "{}", r.render());
    }

    #[test]
    fn zero_rate_baseline_row_notes_instead_of_vacuous_pass() {
        // A 0.0 baseline rate makes `cur < 0.8 * 0` vacuously false —
        // the row must surface as a note, not silently pass the gate.
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 0.0)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 1.0)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(r.ok(), "a broken baseline row is diagnosed, not failed: {}", r.render());
        assert!(
            r.notes.iter().any(|n| n.contains("ternary@ring") && n.contains("non-positive")),
            "{}",
            r.render()
        );
    }

    #[test]
    fn zero_norm_rate_in_baseline_skips_rate_gate_with_note() {
        // The denominator itself is 0: every ratio would be inf/NaN.
        // Bytes stay gated; the rate gate is skipped with a diagnostic.
        let base = record(&[(NORM_KEY, 4096.0, 0.0), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 1.0)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(r.ok(), "{}", r.render());
        assert!(r.notes.iter().any(|n| n.contains("rate gate skipped")), "{}", r.render());
        assert_eq!(r.compared, 2, "bytes comparison still covers every row");
    }

    #[test]
    fn zero_norm_rate_in_current_is_a_regression() {
        let base = record(&[(NORM_KEY, 4096.0, 1e8), ("ternary@ring", 256.0, 9e7)]);
        let cur = record(&[(NORM_KEY, 4096.0, 0.0), ("ternary@ring", 256.0, 9e7)]);
        let r = compare(&base, &cur).expect("well-formed");
        assert!(!r.ok(), "{}", r.render());
        assert!(
            r.regressions.iter().any(|x| x.contains("normalization row")),
            "{}",
            r.render()
        );
    }

    #[test]
    fn parses_real_record_shape() {
        let text = r#"{
            "bench": "hotpath-packed",
            "world": 8,
            "elements": 16384,
            "strategies": {
                "dense_fp32@sim": {"bytes_moved": 65536, "elems_per_sec": 1.0e8},
                "ternary@ring": {"bytes_moved": 4112, "elems_per_sec": 1.1e8}
            }
        }"#;
        let doc = Json::parse(text).expect("parse");
        let r = compare(&doc, &doc).expect("well-formed");
        assert!(r.ok(), "{}", r.render());
        assert_eq!(r.compared, 2);
    }

    #[test]
    fn missing_strategies_map_is_an_error() {
        let doc = Json::parse(r#"{"bench": "x"}"#).expect("parse");
        assert!(compare(&doc, &doc).is_err());
    }
}

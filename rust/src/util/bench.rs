//! Micro-benchmark harness (criterion stand-in).
//!
//! `benches/*.rs` are `harness = false` binaries; they use [`Bench`] to
//! time closures with warmup + repeated samples and report median /
//! mean ± spread, plus optional throughput. Timings go to stdout in a
//! fixed-width format that EXPERIMENTS.md quotes directly.

use std::time::Instant;

/// One benchmark's timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Iterations per sample (amortizes timer overhead for fast bodies).
    pub iters_per_sample: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, samples: 15, iters_per_sample: 1 }
    }
}

/// Result of one measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration seconds, one entry per sample (already divided by
    /// `iters_per_sample`).
    pub seconds: Vec<f64>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        let mut s = self.seconds.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }
    pub fn mean(&self) -> f64 {
        self.seconds.iter().sum::<f64>() / self.seconds.len().max(1) as f64
    }
    pub fn min(&self) -> f64 {
        self.seconds.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.seconds.iter().cloned().fold(0.0, f64::max)
    }

    /// `"  name                    median 12.3 µs  (min 11.9, max 13.0)"`
    pub fn report(&self) -> String {
        format!(
            "  {:<44} median {:>10}  (min {}, max {})",
            self.name,
            fmt_secs(self.median()),
            fmt_secs(self.min()),
            fmt_secs(self.max())
        )
    }

    /// Report with throughput derived from `bytes` processed per iter.
    pub fn report_throughput(&self, bytes: u64) -> String {
        // apslint: allow(lossy_cast) -- bench byte counts stay far below 2^53; (1u64 << 30) is a power of two, exact in f64
        let gibs = bytes as f64 / self.median() / (1u64 << 30) as f64;
        format!("{}  [{:.2} GiB/s]", self.report(), gibs)
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, samples: 5, iters_per_sample: 1 }
    }

    /// Time `f`, preventing the compiler from eliding it via its returned
    /// value (the closure should return something data-dependent).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut seconds = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                std::hint::black_box(f());
            }
            seconds.push(t0.elapsed().as_secs_f64() / self.iters_per_sample as f64);
        }
        Measurement { name: name.to_string(), seconds }
    }
}

/// Human-format a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "n/a".into();
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench { warmup_iters: 1, samples: 5, iters_per_sample: 10 };
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(m.seconds.len(), 5);
        assert!(m.median() > 0.0);
        assert!(m.min() <= m.median() && m.median() <= m.max());
        assert!(m.report().contains("spin"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }

    #[test]
    fn median_even_odd() {
        let m = Measurement { name: "x".into(), seconds: vec![1.0, 3.0, 2.0] };
        assert_eq!(m.median(), 2.0);
        let m = Measurement { name: "x".into(), seconds: vec![1.0, 2.0, 3.0, 4.0] };
        assert_eq!(m.median(), 2.5);
    }
}

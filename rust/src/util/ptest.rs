//! Miniature property-testing harness (proptest stand-in).
//!
//! [`check`] runs a property over `cases` randomly generated inputs from
//! a deterministic seed; on failure it panics with the failing case's
//! index and debug representation so the case can be replayed by seed.
//! Generators are plain closures over [`crate::data::Rng`].

use crate::data::Rng;
use std::fmt::Debug;

/// Default number of random cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` on `cases` inputs drawn from `gen`. Panics on the first
/// failing input, reporting the case index, seed and input.
pub fn check<T: Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for a
/// custom failure message.
pub fn check_msg<T: Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed {seed}): {msg}\n  input = {input:?}"
            );
        }
    }
}

/// Generator helpers.
pub mod generators {
    use crate::data::Rng;

    /// A "nasty" f32: mixes normals across many scales, subnormals,
    /// exact powers of two, zeros and boundary values.
    pub fn nasty_f32(rng: &mut Rng) -> f32 {
        match rng.below(10) {
            0 => 0.0,
            1 => -0.0,
            2 => {
                // exact power of two in a wide range
                let e = rng.below(60) as i32 - 30;
                let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                s * (e as f32).exp2()
            }
            3 => f32::from_bits(rng.next_u64() as u32 & 0x007f_ffff), // subnormal
            4 => {
                let m = f32::MAX;
                m * (rng.uniform() * 2.0 - 1.0)
            }
            _ => {
                // log-uniform magnitude in [2^-30, 2^30]
                let e = rng.range(-30.0, 30.0);
                let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                s * e.exp2() * (1.0 + rng.uniform())
            }
        }
    }

    /// Vector of nasty floats with random length in [1, max_len].
    pub fn nasty_vec(rng: &mut Rng, max_len: usize) -> Vec<f32> {
        let n = 1 + rng.below(max_len);
        (0..n).map(|_| nasty_f32(rng)).collect()
    }

    /// A small random format (exp 2..=8, man 0..=23).
    pub fn format(rng: &mut Rng) -> crate::cpd::FpFormat {
        crate::cpd::FpFormat::new(2 + rng.below(7) as u8, rng.below(24) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always true", 1, 50, |r| r.below(100), |_| {
            true
        });
        check("counted", 2, 50, |r| r.below(100), |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_input() {
        check("fails", 3, 100, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn nasty_generator_hits_special_values() {
        let mut rng = crate::data::Rng::new(7);
        let vals: Vec<f32> = (0..2000).map(|_| generators::nasty_f32(&mut rng)).collect();
        assert!(vals.iter().any(|&v| v == 0.0));
        assert!(vals.iter().any(|&v| v != 0.0 && v.abs() < 1e-38), "subnormals");
        assert!(vals.iter().any(|&v| v.abs() > 1e20), "huge");
        assert!(vals.iter().any(|&v| v < 0.0));
    }
}

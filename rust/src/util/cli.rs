//! Minimal `--flag value` command-line parsing for the `aps` binary and
//! the examples (no external dependencies).

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: a subcommand, positionals, and `--key value` /
/// `--switch` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]). The first token that
    /// does not start with `--` becomes the subcommand; `--key value`
    /// pairs and bare `--switch`es may appear anywhere after it.
    pub fn from_env() -> Args {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter(iter: impl IntoIterator<Item = String>) -> Args {
        let tokens: Vec<String> = iter.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                // `--key=value` or `--key value` or bare switch
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.push((k.to_string(), Some(v.to_string())));
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.flags.push((name.to_string(), Some(tokens[i + 1].clone())));
                    i += 1;
                } else {
                    args.flags.push((name.to_string(), None));
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// String flag with a default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.clone())
            .unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.clone())
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Integer flag with a default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.iter().rev().find(|(k, _)| k == key) {
            None => Ok(default),
            Some((_, None)) => bail!("flag --{key} needs a value"),
            Some((_, Some(v))) => {
                v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}"))
            }
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        // apslint: allow(lossy_cast) -- CLI defaults are small hand-written constants; flag parsing itself goes through usize
        Ok(self.get_usize(key, default as usize)? as u64)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.iter().rev().find(|(k, _)| k == key) {
            None => Ok(default),
            Some((_, None)) => bail!("flag --{key} needs a value"),
            Some((_, Some(v))) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Boolean switch (`--foo` present, or `--foo true/false`).
    pub fn has(&self, key: &str) -> bool {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_deref() != Some("false"))
            .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_flags() {
        // NB: a bare token after `--switch` is consumed as its value
        // (`--switch extra` is ambiguous), so positionals go before
        // switches or between `--key value` pairs.
        let a = parse("train extra --config c.toml --log-every 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config", "x"), "c.toml");
        assert_eq!(a.get_usize("log-every", 0).unwrap(), 5);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("run --seed=7");
        assert_eq!(a.get_u64("seed", 42).unwrap(), 7);
        assert_eq!(a.get_u64("other", 42).unwrap(), 42);
        assert!(!a.has("missing"));
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn switch_false() {
        let a = parse("x --flag false");
        assert!(!a.has("flag"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn last_flag_wins() {
        let a = parse("x --n 1 --n 2");
        assert_eq!(a.get_usize("n", 0).unwrap(), 2);
    }
}

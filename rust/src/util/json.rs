//! A complete, dependency-free JSON implementation (RFC 8259).
//!
//! Used for the artifact metadata (`artifacts/*.json`, written by
//! `python/compile/aot.py`), golden test vectors, and experiment records.
//! The parser is a straightforward recursive-descent over bytes with
//! proper string escapes, exponents and nesting; the writer emits
//! deterministic output (object keys in insertion order).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object; `BTreeMap` for deterministic ordering.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }
    /// Object field access with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field {key:?}"))
    }
    /// Optional field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }
    /// Array of f32 (common case for tensors).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }
    /// Array of usize (shapes).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------- serialization ----------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        // shortest f64 round-trip formatting
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no INF/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------- parsing ----------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.i += 1;
        Ok(c)
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i - 1, got as char);
        }
        Ok(())
    }
    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.bump()?;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let mut cp = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                cp = cp * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            // surrogate pairs
                            if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let mut lo = 0u32;
                                for _ in 0..4 {
                                    let h = self.bump()?;
                                    lo = lo * 16
                                        + (h as char)
                                            .to_digit(16)
                                            .ok_or_else(|| anyhow!("bad \\u escape"))?;
                                }
                                cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            }
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        self.i = start + len;
                        if self.i > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(
                            std::str::from_utf8(&self.b[start..self.i])
                                .map_err(|_| anyhow!("invalid UTF-8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(a)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(o)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
        assert!(matches!(v.get("c").unwrap(), Json::Null));
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cAé");
        // surrogate pair (😀 = U+1F600)
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // writer escapes control chars and round-trips unicode
        let s = Json::Str("tab\tnew\nline\u{1}é😀".into()).to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.as_str().unwrap(), "tab\tnew\nline\u{1}é😀");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-2.5e3").unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e-12, 1e30, f32::MIN_POSITIVE];
        let j = Json::from_f32s(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "nul", "\"abc", "{\"a\" 1}", "[1 2]", "{} x"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn deterministic_output() {
        let v = Json::obj(vec![("b", Json::Num(1.0)), ("a", Json::Bool(true))]);
        assert_eq!(v.to_string(), r#"{"a":true,"b":1}"#);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
    }
}

//! Fixed-width ASCII table rendering for bench reports.
//!
//! Every bench prints its reproduction of the paper's table through
//! [`Table`], so EXPERIMENTS.md can quote output verbatim.

/// A simple left/right-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // first column left-aligned, others right-aligned
                let pad = widths[i].saturating_sub(cell.chars().count());
                if i == 0 {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                } else {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row_str(&["a", "1.0"]);
        t.row_str(&["longer-name", "22.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].starts_with("---"));
        // right-aligned second column
        assert!(lines[2].ends_with("1.0"));
        assert!(lines[3].ends_with("22.5"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.4183), "41.83%");
    }
}

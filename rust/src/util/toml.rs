//! The TOML subset used by `configs/*.toml`.
//!
//! Supports: `[section]` headers, `key = value` with string / integer /
//! float / boolean / array-of-scalar / inline-table-of-scalar values,
//! `#` comments, and blank lines. (No nested tables, dotted keys, or
//! multi-line strings — the experiment configs don't need them, and
//! unknown syntax errors out loudly rather than being silently misread.)

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
    /// Inline table of scalars, e.g. `threads = { fold = 4, encode = 2 }`.
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("expected non-negative integer, got {i}");
        }
        Ok(i as usize)
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }
    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_table(&self) -> Result<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(t) => Ok(t),
            _ => bail!("expected inline table, got {self:?}"),
        }
    }
}

/// A parsed document: section name → key → value. Top-level keys live in
/// the `""` section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    bail!("line {}: bad section name {name:?}", lineno + 1);
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
            } else {
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
                let key = k.trim();
                if key.is_empty() {
                    bail!("line {}: empty key", lineno + 1);
                }
                let value = parse_value(v.trim())
                    .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
                doc.sections
                    .get_mut(&current)
                    .unwrap()
                    .insert(key.to_string(), value);
            }
        }
        Ok(doc)
    }

    pub fn section(&self, name: &str) -> Result<&BTreeMap<String, TomlValue>> {
        self.sections
            .get(name)
            .ok_or_else(|| anyhow!("missing [{}] section", name))
    }

    pub fn get(&self, section: &str, key: &str) -> Result<&TomlValue> {
        self.section(section)?
            .get(key)
            .ok_or_else(|| anyhow!("missing {section}.{key}"))
    }

    pub fn opt(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string {s:?}"))?;
        if inner.contains('"') {
            bail!("unsupported embedded quote in {s:?}");
        }
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    if let Some(rest) = s.strip_prefix('{') {
        let inner = rest
            .strip_suffix('}')
            .ok_or_else(|| anyhow!("unterminated inline table {s:?}"))?
            .trim();
        let mut table = BTreeMap::new();
        if !inner.is_empty() {
            for pair in inner.split(',') {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow!("expected key = value in inline table {s:?}"))?;
                let key = k.trim();
                if key.is_empty() {
                    bail!("empty key in inline table {s:?}");
                }
                table.insert(key.to_string(), parse_value(v.trim())?);
            }
        }
        return Ok(TomlValue::Table(table));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
top = 1

[experiment]
name = "table4"   # trailing comment
seed = 42

[sync]
method = "aps"
kahan = true
scale = -2.5
decay_at = [40.0, 80.0]
big = 1_000_000
"#;

    #[test]
    fn parse_sample() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.get("", "top").unwrap().as_i64().unwrap(), 1);
        assert_eq!(d.get("experiment", "name").unwrap().as_str().unwrap(), "table4");
        assert_eq!(d.get("experiment", "seed").unwrap().as_usize().unwrap(), 42);
        assert!(d.get("sync", "kahan").unwrap().as_bool().unwrap());
        assert_eq!(d.get("sync", "scale").unwrap().as_f64().unwrap(), -2.5);
        assert_eq!(d.get("sync", "big").unwrap().as_i64().unwrap(), 1_000_000);
        let arr = match d.get("sync", "decay_at").unwrap() {
            TomlValue::Arr(a) => a.clone(),
            _ => panic!(),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_f64().unwrap(), 40.0);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let d = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(d.get("", "k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_are_loud() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = zzz").is_err());
        let d = TomlDoc::parse("[a]\nx = 1").unwrap();
        assert!(d.get("b", "x").is_err());
        assert!(d.get("a", "y").is_err());
    }

    #[test]
    fn negative_usize_rejected() {
        let d = TomlDoc::parse("k = -3").unwrap();
        assert!(d.get("", "k").unwrap().as_usize().is_err());
    }

    #[test]
    fn inline_table_of_scalars() {
        let d = TomlDoc::parse("threads = { fold = 4, encode = 2 }").unwrap();
        let t = d.get("", "threads").unwrap().as_table().unwrap();
        assert_eq!(t.get("fold").unwrap().as_usize().unwrap(), 4);
        assert_eq!(t.get("encode").unwrap().as_usize().unwrap(), 2);
        let d = TomlDoc::parse("empty = {}").unwrap();
        assert!(d.get("", "empty").unwrap().as_table().unwrap().is_empty());
        // Scalars reject as_table and vice versa.
        assert!(TomlDoc::parse("k = 1").unwrap().get("", "k").unwrap().as_table().is_err());
        assert!(d.get("", "empty").unwrap().as_usize().is_err());
        // Malformed tables error loudly.
        assert!(TomlDoc::parse("k = { fold = 4").is_err());
        assert!(TomlDoc::parse("k = { fold }").is_err());
        assert!(TomlDoc::parse("k = { = 4 }").is_err());
    }
}

//! Scoped-thread data parallelism for the element-wise hot loops.
//!
//! A tiny stand-in for rayon: split a mutable slice (or an index range)
//! into per-core chunks and run a closure on each under `std::thread::scope`.
//! Used by the quantize and all-reduce fold paths, which are embarrassingly
//! parallel over elements.

/// Number of worker threads to use (once-computed).
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Minimum elements per thread before parallelism is worth spawning.
pub const PAR_THRESHOLD: usize = 16 * 1024;

/// Minimum elements before an intra-layer *reduction scan* (max-abs,
/// bucket norms) is worth spawning threads for. Deliberately higher than
/// [`PAR_THRESHOLD`]: a scan does one read per element (the fold kernels
/// do several), and the prepare phase runs once per worker × layer per
/// step, so spawn bookkeeping would dominate on mid-sized layers.
pub const REDUCE_PAR_THRESHOLD: usize = 64 * 1024;

/// Thread budget for a reduction scan over `n` elements: the host's
/// [`num_threads`] once `n` clears [`REDUCE_PAR_THRESHOLD`], else 1.
/// Only *where* blocks run depends on this — [`par_block_reduce`]'s
/// combine tree is fixed by the block size alone, so the result never
/// does.
pub fn reduce_threads(n: usize) -> usize {
    if n >= REDUCE_PAR_THRESHOLD {
        num_threads()
    } else {
        1
    }
}

/// Run `f(chunk_start_index, chunk)` over disjoint chunks of `data` in
/// parallel. Falls back to a single call when the slice is small.
///
/// Callers must be schedule-oblivious: `f` receives the chunk's absolute
/// start index, and chunk boundaries only partition the iteration space —
/// they must never change what is computed per element. Under that
/// contract results are bit-identical for any thread count, which
/// `tests/apslint_rules.rs` pins by permuting `max_threads` explicitly.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(data, min_chunk, num_threads(), f)
}

/// [`par_chunks_mut`] with an explicit thread-count cap instead of the
/// host's [`num_threads`]. This is the determinism test hook: running the
/// same input at `max_threads` = 1, 2, and N exercises every chunking
/// schedule a host could pick, so a test can assert the outputs are
/// bit-identical without depending on the machine it runs on.
pub fn par_chunks_mut_with<T: Send, F>(data: &mut [T], min_chunk: usize, max_threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = max_threads.min(n.div_ceil(min_chunk.max(1))).max(1);
    if threads == 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut start = 0usize;
        for _ in 0..threads {
            if rest.is_empty() {
                break;
            }
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let fref = &f;
            s.spawn(move || fref(start, head));
            start += take;
            rest = tail;
        }
    });
}

/// [`par_chunks_mut_with`] plus a per-thread scratch slot: the k-th
/// spawned chunk runs with exclusive access to `scratch[k]`. The data
/// split (thread count, chunk size, chunk order) is computed with
/// exactly the same arithmetic as [`par_chunks_mut_with`], so the two
/// share one schedule and the same schedule-obliviousness contract:
/// `f` may use its scratch slot as workspace, but what it writes into
/// `data` must depend only on the chunk's contents and absolute start
/// index. `scratch` must hold at least `max_threads.max(1)` slots
/// (callers size it once and reuse it; this function never allocates).
pub fn par_chunks_mut_with_scratch<T: Send, S: Send, F>(
    data: &mut [T],
    scratch: &mut [S],
    min_chunk: usize,
    max_threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = max_threads.min(n.div_ceil(min_chunk.max(1))).max(1);
    assert!(
        scratch.len() >= threads,
        "par_chunks_mut_with_scratch: {} scratch slots for {} threads",
        scratch.len(),
        threads
    );
    if threads == 1 {
        f(0, data, &mut scratch[0]);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut slots = scratch;
        let mut start = 0usize;
        for _ in 0..threads {
            if rest.is_empty() {
                break;
            }
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let (slot, slot_tail) = slots.split_at_mut(1);
            let slot = &mut slot[0];
            let fref = &f;
            s.spawn(move || fref(start, head, slot));
            start += take;
            rest = tail;
            slots = slot_tail;
        }
    });
}

/// Split two equal-length slices with one schedule and run
/// `f(start, a_chunk, b_chunk)` over the paired chunks in parallel. The
/// split arithmetic is exactly [`par_chunks_mut_with`]'s, so the
/// schedule-obliviousness contract is the same; the pairing exists for
/// lane-style fan-outs where each index owns state in two parallel
/// arrays (e.g. a per-worker encode twin and that worker's wire buffer).
pub fn par_chunks_mut_pair<A: Send, B: Send, F>(
    a: &mut [A],
    b: &mut [B],
    min_chunk: usize,
    max_threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "par_chunks_mut_pair: slice lengths differ");
    let n = a.len();
    if n == 0 {
        return;
    }
    let threads = max_threads.min(n.div_ceil(min_chunk.max(1))).max(1);
    if threads == 1 {
        f(0, a, b);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut start = 0usize;
        for _ in 0..threads {
            if rest_a.is_empty() {
                break;
            }
            let take = chunk.min(rest_a.len());
            let (head_a, tail_a) = rest_a.split_at_mut(take);
            let (head_b, tail_b) = rest_b.split_at_mut(take);
            let fref = &f;
            s.spawn(move || fref(start, head_a, head_b));
            start += take;
            rest_a = tail_a;
            rest_b = tail_b;
        }
    });
}

/// Upper bound on threads a block reduction will spawn. Bounds the
/// stack-allocated partials array so the reduction never heap-allocates.
const MAX_REDUCE_FANOUT: usize = 32;

/// Fixed-block tree reduction over a shared slice: `leaf` maps each
/// `block`-sized block (the last may be short) to a partial, and
/// `combine` folds the partials in ascending block order. Threads take
/// contiguous runs of *whole* blocks, so block boundaries — and hence
/// every `leaf` call — are a function of `block` alone, never of the
/// thread count. For an associative `combine` the result is therefore
/// identical at every `max_threads`, including 1; callers must pass an
/// associative combine (exact max/min/bit-or — not float addition).
/// Returns `None` only for an empty slice. Never allocates.
pub fn par_block_reduce<T, R, L, C>(
    xs: &[T],
    block: usize,
    max_threads: usize,
    leaf: L,
    combine: C,
) -> Option<R>
where
    T: Sync,
    R: Send,
    L: Fn(&[T]) -> R + Sync,
    C: Fn(R, R) -> R + Sync,
{
    assert!(block > 0, "par_block_reduce: block size must be positive");
    let nblocks = xs.len().div_ceil(block);
    if nblocks == 0 {
        return None;
    }
    let threads = max_threads.min(MAX_REDUCE_FANOUT).min(nblocks).max(1);
    if threads == 1 {
        let mut it = xs.chunks(block).map(&leaf);
        let first = it.next()?;
        return Some(it.fold(first, &combine));
    }
    let run_len = nblocks.div_ceil(threads) * block;
    let mut partials: [Option<R>; MAX_REDUCE_FANOUT] = core::array::from_fn(|_| None);
    std::thread::scope(|s| {
        let mut rest = xs;
        for slot in partials.iter_mut().take(threads) {
            if rest.is_empty() {
                break;
            }
            let take = run_len.min(rest.len());
            let (head, tail) = rest.split_at(take);
            let leaf = &leaf;
            let combine = &combine;
            s.spawn(move || {
                let mut it = head.chunks(block).map(leaf);
                let first = it.next().expect("runs hold at least one block");
                *slot = Some(it.fold(first, combine));
            });
            rest = tail;
        }
    });
    let mut acc: Option<R> = None;
    for slot in partials.into_iter().take(threads) {
        acc = match (acc, slot) {
            (Some(a), Some(b)) => Some(combine(a, b)),
            (a, None) => a,
            (None, b) => b,
        };
    }
    acc
}

/// Parallel map over an index range, collecting results in order.
pub fn par_map<T: Send, F>(count: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(count).max(1);
    if threads <= 1 || count <= 1 {
        return (0..count).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..count).map(|_| None).collect();
    par_chunks_mut(&mut out, 1, |start, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(start + i));
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0usize; 100_000];
        par_chunks_mut(&mut v, 1024, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn small_input_single_thread() {
        let mut v = vec![1i32; 10];
        par_chunks_mut(&mut v, 1024, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn scratch_variant_matches_plain_split() {
        // Same input, same (min_chunk, max_threads) → the scratch
        // variant must see exactly the chunks the plain variant sees.
        for &(n, min_chunk, max_threads) in
            &[(100_000usize, 1024usize, 8usize), (10, 1024, 8), (7, 1, 3), (8, 1, 3), (0, 4, 4)]
        {
            let mut plain: Vec<(usize, usize)> = Vec::new();
            let mut v = vec![0u8; n];
            {
                let log = std::sync::Mutex::new(&mut plain);
                par_chunks_mut_with(&mut v, min_chunk, max_threads, |start, c| {
                    log.lock().unwrap().push((start, c.len()));
                });
            }
            let mut with_scratch: Vec<(usize, usize, usize)> = Vec::new();
            let mut scratch: Vec<usize> = (0..max_threads).collect();
            {
                let log = std::sync::Mutex::new(&mut with_scratch);
                par_chunks_mut_with_scratch(
                    &mut v,
                    &mut scratch,
                    min_chunk,
                    max_threads,
                    |start, c, slot| {
                        log.lock().unwrap().push((start, c.len(), *slot));
                    },
                );
            }
            plain.sort_unstable();
            with_scratch.sort_unstable();
            assert_eq!(plain.len(), with_scratch.len(), "n={n}");
            let mut slots_seen = Vec::new();
            for (p, w) in plain.iter().zip(&with_scratch) {
                assert_eq!((p.0, p.1), (w.0, w.1), "n={n}");
                slots_seen.push(w.2);
            }
            // Each spawned chunk got a distinct scratch slot.
            slots_seen.sort_unstable();
            slots_seen.dedup();
            assert_eq!(slots_seen.len(), with_scratch.len(), "n={n}: scratch slot reused");
        }
    }

    #[test]
    fn pair_variant_matches_plain_split() {
        // Same (n, min_chunk, max_threads) → the paired variant sees
        // exactly the chunks the plain variant sees, on both slices.
        for &(n, min_chunk, max_threads) in
            &[(100_000usize, 1024usize, 8usize), (10, 1024, 8), (7, 1, 3), (8, 1, 3), (0, 4, 4)]
        {
            let mut plain: Vec<(usize, usize)> = Vec::new();
            let mut v = vec![0u8; n];
            {
                let log = std::sync::Mutex::new(&mut plain);
                par_chunks_mut_with(&mut v, min_chunk, max_threads, |start, c| {
                    log.lock().unwrap().push((start, c.len()));
                });
            }
            let mut paired: Vec<(usize, usize, usize)> = Vec::new();
            let mut a = vec![0u8; n];
            let mut b = vec![0u16; n];
            {
                let log = std::sync::Mutex::new(&mut paired);
                par_chunks_mut_pair(&mut a, &mut b, min_chunk, max_threads, |start, ca, cb| {
                    log.lock().unwrap().push((start, ca.len(), cb.len()));
                });
            }
            plain.sort_unstable();
            paired.sort_unstable();
            assert_eq!(plain.len(), paired.len(), "n={n}");
            for (p, q) in plain.iter().zip(&paired) {
                assert_eq!((p.0, p.1), (q.0, q.1), "n={n}");
                assert_eq!(q.1, q.2, "n={n}: paired chunks misaligned");
            }
        }
    }

    #[test]
    fn block_reduce_matches_serial_fold_for_every_thread_count() {
        // Exact max is associative, so every thread count must reproduce
        // the single-threaded fold bit-for-bit — including short tails
        // and blocks that don't divide the length.
        let xs: Vec<f32> = (0..200_001)
            .map(|i| {
                let v = ((i * 2_654_435_761u64 as usize) % 10_007) as f32 - 5_003.0;
                v * 1e-3
            })
            .collect();
        let leaf = |blk: &[f32]| {
            let mut m = 0.0f32;
            for &x in blk {
                let a = x.abs();
                if a > m {
                    m = a;
                }
            }
            m
        };
        let combine = |a: f32, b: f32| if b > a { b } else { a };
        for &block in &[1usize, 7, 4096, 1 << 20] {
            let serial = par_block_reduce(&xs, block, 1, leaf, combine).unwrap();
            for &threads in &[2usize, 3, 8, 64] {
                let par = par_block_reduce(&xs, block, threads, leaf, combine).unwrap();
                assert_eq!(
                    par.to_bits(),
                    serial.to_bits(),
                    "block={block} threads={threads}: tree result diverged"
                );
            }
        }
        assert!(par_block_reduce(&[] as &[f32], 4096, 8, leaf, combine).is_none());
    }

    #[test]
    fn par_map_ordered() {
        let out = par_map(1000, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
        assert!(par_map(0, |i| i).is_empty());
    }
}

//! Self-contained utility substrates.
//!
//! This repository builds fully offline with no registry dependencies at
//! all (`anyhow` is vendored at `rust/vendor/anyhow`, the `xla` PJRT
//! bindings are stubbed at `runtime::xla_stub`), so the small
//! infrastructure crates a project would normally pull in are
//! implemented here:
//!
//! * [`json`] — a complete JSON parser + serializer (artifact specs,
//!   golden vectors, experiment records).
//! * [`toml`] — the TOML subset used by `configs/*.toml` (sections,
//!   scalar keys, arrays of scalars).
//! * [`cli`] — declarative-ish `--flag value` argument parsing.
//! * [`bench`] — a micro-benchmark harness (median-of-runs timing) used
//!   by `benches/*` in place of criterion.
//! * [`benchdiff`] — the bench-trajectory regression gate (diffs
//!   `BENCH_packed.json` against the committed baseline; exact on
//!   bytes-moved, −20 % floor on machine-normalized throughput).
//! * [`par`] — scoped-thread parallel helpers for the element-wise hot
//!   loops (quantize, reduction folds).
//! * [`ptest`] — a miniature property-testing harness (random cases +
//!   input logging) used by the invariants suites.
//! * [`table`] — fixed-width ASCII table rendering for bench reports.

pub mod bench;
pub mod benchdiff;
pub mod cli;
pub mod json;
pub mod par;
pub mod ptest;
pub mod table;
pub mod toml;

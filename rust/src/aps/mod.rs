//! APS — Auto-Precision Scaling (paper §3, Algorithm 1).
//!
//! The paper-level vocabulary of gradient synchronization. The four
//! methods of Table 2 are described by the closed [`SyncMethod`] enum:
//!
//! * [`SyncMethod::Fp32`] — the FP32 baseline (wire = 32 bits).
//! * [`SyncMethod::Naive`] — cast to the low-precision wire format with no
//!   scaling (the paper's "no APS" rows: underflow/overflow-prone).
//! * [`SyncMethod::LossScaling`] — one *global, hand-chosen* power-of-two
//!   factor for all layers (Micikevicius et al. [21]).
//! * [`SyncMethod::Aps`] — Algorithm 1: each layer is shifted by the
//!   largest power-of-two factor that provably cannot overflow the wire
//!   format even after summation across all `N` workers (Eq. 1–4), using a
//!   1-byte-per-layer exponent all-reduce to agree on the factor.
//!
//! Since the [`crate::sync`] redesign, the *execution* of these methods
//! lives in [`crate::sync::strategies`] (one [`crate::sync::SyncStrategy`]
//! impl per method, plus net-new codecs the closed enum cannot name), and
//! the hot path is a buffer-reusing [`crate::sync::SyncSession`]. The
//! deprecated `aps::synchronize` one-shot shim has been removed after its
//! one-release grace period — build a session via
//! [`crate::sync::SyncSessionBuilder`] (see the migration notes in
//! lib.rs); [`legacy::synchronize`] preserves the pre-trait
//! implementation so the equivalence suite can pin the session path
//! bit-for-bit against the old one.
//!
//! All reductions run through [`crate::collectives`] so the wire
//! precision and summation order are emulated faithfully.

pub mod policy;

use crate::collectives::Topology;
use crate::cpd::{FpFormat, Rounding};

pub use policy::{HybridSchedule, LayerPolicy};

/// Gradient-synchronization method (paper Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncMethod {
    /// Full-precision all-reduce.
    Fp32,
    /// Low-precision wire format, no scaling.
    Naive { fmt: FpFormat },
    /// Global constant power-of-two loss scaling (factor is `2^factor_exp`).
    LossScaling { fmt: FpFormat, factor_exp: i32 },
    /// Auto-Precision Scaling (Algorithm 1).
    Aps { fmt: FpFormat },
}

impl SyncMethod {
    /// The wire format gradients travel in.
    pub fn wire_format(&self) -> FpFormat {
        match *self {
            SyncMethod::Fp32 => FpFormat::FP32,
            SyncMethod::Naive { fmt }
            | SyncMethod::LossScaling { fmt, .. }
            | SyncMethod::Aps { fmt } => fmt,
        }
    }
}

/// Options for one synchronization call.
#[derive(Clone, Copy, Debug)]
pub struct SyncOptions {
    pub method: SyncMethod,
    pub topo: Topology,
    /// Rounding used for all casts (paper uses round-to-nearest-even).
    pub rounding: Rounding,
    /// Kahan-compensated reduction (CPD feature, §5.1.1).
    pub kahan: bool,
    /// Divide the reduced sum by `world_size` (data-parallel averaging).
    pub average: bool,
    /// Keep the last layer's wire format at FP32 (paper Table 7; the
    /// recommendation of Wang et al. [27] adopted in §4.2).
    pub fp32_last_layer: bool,
    /// Lazy all-reduce: communicate all layers as one fused message
    /// (paper §4.3 / Fig 11 rightmost bar). Affects message accounting
    /// only — per-layer scaling factors are still independent.
    pub fused: bool,
}

impl SyncOptions {
    pub fn new(method: SyncMethod) -> Self {
        SyncOptions {
            method,
            topo: Topology::Ring,
            rounding: Rounding::NearestEven,
            kahan: false,
            average: true,
            fp32_last_layer: false,
            fused: false,
        }
    }
    pub fn with_topology(mut self, topo: Topology) -> Self {
        self.topo = topo;
        self
    }
    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }
    pub fn with_kahan(mut self, kahan: bool) -> Self {
        self.kahan = kahan;
        self
    }
    pub fn with_fp32_last_layer(mut self, yes: bool) -> Self {
        self.fp32_last_layer = yes;
        self
    }
    pub fn with_average(mut self, yes: bool) -> Self {
        self.average = yes;
        self
    }
    pub fn with_fused(mut self, yes: bool) -> Self {
        self.fused = yes;
        self
    }
}

impl Default for SyncOptions {
    /// FP32 sync over a ring with averaging — the baseline configuration.
    fn default() -> Self {
        SyncOptions::new(SyncMethod::Fp32)
    }
}

/// Per-layer diagnostics from one synchronization.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerReport {
    /// The power-of-two exponent APS (or loss scaling) applied.
    pub factor_exp: i32,
    /// Fraction of non-zero elements flushed to zero by the wire cast.
    pub underflow_frac: f64,
    /// Fraction of elements that overflowed to INF on the wire.
    pub overflow_frac: f64,
    /// Elements in this layer.
    pub elements: usize,
}

/// Per-bucket timing and traffic from one overlapped synchronization
/// (`SyncSession::step_overlapped`). Timing fields are wall-clock
/// observability only — report equality deliberately ignores them (see
/// the manual [`PartialEq`] on [`SyncReport`]); the reduced gradients
/// stay bit-identical to the synchronous path regardless of schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BucketStats {
    /// Bucket index in launch (ready) order.
    pub bucket: usize,
    /// Number of layers fused into this bucket.
    pub layers: usize,
    /// Total elements across the bucket's layers.
    pub elements: usize,
    /// Honest octets this bucket ships per worker pair-exchange
    /// (`moved_cost().total_bytes()` summed over workers and layers).
    pub bytes: u64,
    /// Main-thread encode→pack time for the bucket.
    pub encode_ns: u64,
    /// Transport exchange time on the pool thread.
    pub transit_ns: u64,
    /// Packed fold (reduce) time on the pool thread.
    pub fold_ns: u64,
    /// Queue wait between launch and the pool thread picking it up.
    pub wait_ns: u64,
}

/// Aggregate result of one synchronization call.
#[derive(Clone, Debug, Default)]
pub struct SyncReport {
    pub layers: Vec<LayerReport>,
    /// Wire bytes per worker for the gradient payload phase, as the
    /// *simulated* collective moved them (dense buffers in the wire
    /// format, ring/hierarchical schedule accounting).
    pub payload_bytes: u64,
    /// Wire bytes per worker for the exponent (max) phase — APS only.
    pub exponent_bytes: u64,
    /// The codec's honest per-worker cost of shipping one full gradient
    /// set (packed value bits, sparse index bits, metadata bytes) — what
    /// a real deployment of the codec would put on the network. For
    /// sparse codecs (top-k, QSGD) this is where index and scale traffic
    /// is accounted; `payload_bytes` keeps the dense simulation figure.
    pub wire: crate::sync::WireCost,
    /// Latency-bound steps across all messages.
    pub steps: usize,
    /// Number of distinct messages (layers, or 1 when fused).
    pub messages: usize,
    /// Per-bucket timing from the overlapped path (empty for
    /// [`crate::sync::SyncSession::step`]). Excluded from equality.
    pub buckets: Vec<BucketStats>,
    /// Wall-clock nanoseconds of the per-worker encode→pack phase for
    /// the whole step (the overlapped path reports the sum of its
    /// buckets' [`BucketStats::encode_ns`]). Observability only —
    /// excluded from equality like the bucket timings.
    pub encode_ns: u64,
}

/// Timing-free equality: every accounting field must match, but
/// `buckets` and `encode_ns` carry wall-clock measurements that
/// legitimately differ between the synchronous and overlapped paths (and
/// between runs), so the packed/simulated/overlapped bit-identity suites
/// can compare whole reports with `assert_eq!`.
impl PartialEq for SyncReport {
    fn eq(&self, other: &Self) -> bool {
        self.layers == other.layers
            && self.payload_bytes == other.payload_bytes
            && self.exponent_bytes == other.exponent_bytes
            && self.wire == other.wire
            && self.steps == other.steps
            && self.messages == other.messages
    }
}

impl SyncReport {
    /// Total *simulated* wire bytes per worker (payload + exponent
    /// phases, dense accounting).
    pub fn total_bytes(&self) -> u64 {
        self.payload_bytes + self.exponent_bytes
    }

    /// Total *honest* wire bytes per worker: the codec's packed payload
    /// (values + indices + metadata) plus the exponent agreement phase.
    pub fn honest_bytes(&self) -> u64 {
        self.wire.total_bytes() + self.exponent_bytes
    }
    /// Mean underflow fraction across layers (weighted by elements).
    pub fn underflow_frac(&self) -> f64 {
        let (num, den) = self.layers.iter().fold((0.0, 0usize), |(s, n), l| {
            (s + l.underflow_frac * l.elements as f64, n + l.elements)
        });
        if den == 0 {
            0.0
        } else {
            num / den as f64
        }
    }
    /// True if any element overflowed to INF anywhere.
    pub fn any_overflow(&self) -> bool {
        self.layers.iter().any(|l| l.overflow_frac > 0.0)
    }
}

/// Multiply by a power of two without intermediate overflow (ldexp).
#[inline]
pub fn ldexp_f32(x: f32, e: i32) -> f32 {
    (x as f64 * (e as f64).exp2()) as f32
}

/// Fixed tree block for the max-magnitude prepare scan: per-block maxima
/// combined in ascending block order. Compile-time so the combine tree
/// is a function of the layer length alone — never of the thread count.
const MAX_ABS_BLOCK: usize = 4096;

/// Leaf of the max-magnitude tree: `max |g|` over one block. `>` skips
/// NaN; ±INF propagates, so divergent layers still map to `None` below.
fn abs_block_max(blk: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &g in blk {
        let a = g.abs();
        if a > m {
            m = a;
        }
    }
    m
}

/// Algorithm 1 lines 3–4: a worker's local `max_exp` for one layer,
/// already inflated by `world_size` (the `grad * world_size` term that
/// makes the Eq. 2 bound hold for the *summed* gradient).
///
/// The scan is a fixed-block tree reduction: threads engage on huge
/// layers (intra-layer parallel prepare), and because exact max is
/// associative and the block boundaries are compile-time, the result is
/// the serial scan's bit-for-bit at every thread count
/// (`rust/tests/encode_parallel.rs` pins the equivalence).
///
/// Returns `None` when the layer's gradient is all zero (nothing to scale).
pub fn local_max_exp(grad: &[f32], world_size: usize) -> Option<i32> {
    let max_abs = crate::util::par::par_block_reduce(
        grad,
        MAX_ABS_BLOCK,
        crate::util::par::reduce_threads(grad.len()),
        abs_block_max,
        |a, b| if b > a { b } else { a },
    )
    .unwrap_or(0.0);
    if max_abs == 0.0 || !max_abs.is_finite() {
        return None;
    }
    // ceil(log2(N * ĝ)) = via f64 to avoid f32 overflow for huge N·ĝ.
    let v = max_abs as f64 * world_size as f64;
    let l = v.log2();
    let c = l.ceil();
    // Exact powers of two: ceil(log2) == log2 (paper's FindMaxExp).
    Some(c as i32)
}

/// The pre-trait implementation of the removed `synchronize` shim, kept verbatim so the
/// equivalence suite (`rust/tests/strategy_layer.rs`) can assert the
/// strategy/session path is bit-identical to it. Not part of the public
/// API surface; do not call from new code.
#[doc(hidden)]
pub mod legacy {
    use super::{local_max_exp, LayerReport, SyncMethod, SyncOptions, SyncReport};
    use crate::collectives::{ReduceOptions, ReduceStats, SimCluster};
    use crate::cpd::{quantize_shifted_slice, FpFormat};
    use crate::sync::WireCost;

    /// See the module docs: the original closed-enum synchronize.
    pub fn synchronize(
        cluster: &SimCluster,
        grads: &[Vec<Vec<f32>>],
        opts: &SyncOptions,
    ) -> (Vec<Vec<f32>>, SyncReport) {
        let world = cluster.world_size;
        assert_eq!(grads.len(), world, "one gradient set per worker");
        let num_layers = grads[0].len();
        assert!(grads.iter().all(|g| g.len() == num_layers), "ragged layer counts");

        let mut report = SyncReport {
            layers: vec![LayerReport::default(); num_layers],
            messages: if opts.fused { 1 } else { num_layers },
            ..Default::default()
        };

        // ---- Phase 1 (APS only): agree on per-layer scaling factors. ---
        let factor_exps: Vec<i32> = match opts.method {
            SyncMethod::Aps { fmt } => {
                // Each worker contributes one i8 exponent per layer; one
                // max-all-reduce over the vector E (Algorithm 1 line 4).
                let contribs: Vec<Vec<i8>> = grads
                    .iter()
                    .map(|wg| {
                        wg.iter()
                            .map(|g| {
                                local_max_exp(g, world)
                                    .map(|e| e.clamp(-128, 127) as i8)
                                    .unwrap_or(i8::MIN)
                            })
                            .collect()
                    })
                    .collect();
                let (max_exps, stats) = cluster.all_reduce_max_i8(&contribs);
                report.exponent_bytes = stats.bytes_per_worker;
                report.steps += stats.steps;
                max_exps
                    .iter()
                    .map(|&me| {
                        if me == i8::MIN {
                            0 // all-zero layer: no scaling needed
                        } else {
                            fmt.max_exponent() - me as i32
                        }
                    })
                    .collect()
            }
            SyncMethod::LossScaling { factor_exp, .. } => vec![factor_exp; num_layers],
            _ => vec![0; num_layers],
        };

        // ---- Phase 2: scale, cast, all-reduce, cast back, unscale. -----
        let mut reduced: Vec<Vec<f32>> = Vec::with_capacity(num_layers);
        let wire_fmt = opts.method.wire_format();

        for l in 0..num_layers {
            let n = grads[0][l].len();
            let layer_fmt = if opts.fp32_last_layer && l == num_layers - 1 {
                FpFormat::FP32
            } else {
                wire_fmt
            };
            let fe = if layer_fmt.is_fp32() { 0 } else { factor_exps[l] };

            // Per-worker: shift by 2^fe and cast into the wire format (one
            // rounding — the shift is exponent arithmetic, §3.3.1).
            let mut nonzero_in = 0usize;
            let mut zero_out = 0usize;
            let mut inf_out = 0usize;
            let contribs: Vec<Vec<f32>> = grads
                .iter()
                .map(|wg| {
                    let src = &wg[l];
                    let q = quantize_shifted_slice(src, fe, layer_fmt, opts.rounding);
                    for (&x, &qq) in src.iter().zip(&q) {
                        if x != 0.0 {
                            nonzero_in += 1;
                            if qq == 0.0 {
                                zero_out += 1;
                            }
                        }
                        if qq.is_infinite() {
                            inf_out += 1;
                        }
                    }
                    q
                })
                .collect();

            let ropts =
                ReduceOptions { fmt: layer_fmt, mode: opts.rounding, kahan: opts.kahan };
            let (mut sum, stats): (Vec<f32>, ReduceStats) =
                cluster.all_reduce_sum(&contribs, opts.topo, ropts);

            // Cast back up (already f32 storage) and undo the shift; average.
            // apslint: allow(lossy_cast) -- fe is a small FP exponent (|fe| < 2^15), so its negation is exact in i32
            let unscale = -(fe as i64) as i32;
            let div = if opts.average { world as f64 } else { 1.0 };
            let m = (unscale as f64).exp2() / div;
            for v in sum.iter_mut() {
                *v = (*v as f64 * m) as f32;
            }

            report.layers[l] = LayerReport {
                factor_exp: fe,
                underflow_frac: if nonzero_in == 0 {
                    0.0
                } else {
                    zero_out as f64 / nonzero_in as f64
                },
                overflow_frac: inf_out as f64 / (n * world).max(1) as f64,
                elements: n,
            };
            report.payload_bytes += stats.bytes_per_worker;
            // The paper methods are dense codecs: their honest per-worker
            // wire cost is one full tensor in the layer's wire format —
            // the same figure the session derives via `wire_cost`.
            report.wire += WireCost::dense(n, layer_fmt);
            if !opts.fused {
                report.steps += stats.steps;
            }
            reduced.push(sum);
        }
        if opts.fused {
            // One fused message: pay the per-message step count once.
            report.steps += opts.topo.steps(world);
        }

        (reduced, report)
    }
}

/// The exact (f64-accumulated, FP32-wire) reduction used as the reference
/// when measuring round-off error (Eq. 5 inputs).
pub fn reduce_exact(grads: &[Vec<Vec<f32>>], average: bool) -> Vec<Vec<f32>> {
    let world = grads.len();
    let num_layers = grads[0].len();
    (0..num_layers)
        .map(|l| {
            let n = grads[0][l].len();
            let mut out = vec![0.0f32; n];
            for (i, o) in out.iter_mut().enumerate() {
                let mut s = 0.0f64;
                for wg in grads {
                    s += wg[l][i] as f64;
                }
                if average {
                    s /= world as f64;
                }
                *o = s as f32;
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::SimCluster;
    use crate::cpd::avg_roundoff_error;

    fn cluster8() -> SimCluster {
        SimCluster::new(8)
    }

    /// One-shot sync through the modern session path (what the removed
    /// `aps::synchronize` shim used to do) — these tests pin *method*
    /// semantics, not the entry point.
    fn synchronize(
        cluster: &SimCluster,
        grads: &[Vec<Vec<f32>>],
        opts: &SyncOptions,
    ) -> (Vec<Vec<f32>>, SyncReport) {
        let mut session =
            crate::sync::SyncSessionBuilder::from_sync_options(cluster.world_size, opts).build();
        let (reduced, report) = session.step(grads);
        (reduced.to_vec(), report.clone())
    }

    /// Synthetic per-worker gradients with wildly different layer scales —
    /// the Fig-2 situation APS is built for.
    fn scaled_grads(world: usize, layers: &[(usize, f32)]) -> Vec<Vec<Vec<f32>>> {
        (0..world)
            .map(|w| {
                layers
                    .iter()
                    .enumerate()
                    .map(|(l, &(n, scale))| {
                        (0..n)
                            .map(|i| {
                                let h = (w * 2654435761 + l * 97 + i * 131) % 2003;
                                (h as f32 / 2003.0 - 0.5) * scale
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fp32_sync_matches_exact() {
        let grads = scaled_grads(8, &[(32, 1.0), (16, 1e-4)]);
        let opts = SyncOptions::new(SyncMethod::Fp32);
        let (out, report) = synchronize(&cluster8(), &grads, &opts);
        let exact = reduce_exact(&grads, true);
        for l in 0..2 {
            let e = avg_roundoff_error(&exact[l], &out[l]);
            assert!(e < 1e-6, "layer {l}: {e}");
        }
        assert_eq!(report.exponent_bytes, 0);
        assert!(!report.any_overflow());
    }

    #[test]
    fn naive_low_precision_underflows_small_layers() {
        // Layer 1 values ~1e-6 are far below E5M2's 2^-16 ≈ 1.5e-5.
        let grads = scaled_grads(8, &[(64, 1.0), (64, 1e-6)]);
        let opts = SyncOptions::new(SyncMethod::Naive { fmt: FpFormat::E5M2 });
        let (out, report) = synchronize(&cluster8(), &grads, &opts);
        assert!(report.layers[1].underflow_frac > 0.9, "{:?}", report.layers[1]);
        assert!(out[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn aps_rescues_small_layers() {
        let grads = scaled_grads(8, &[(64, 1.0), (64, 1e-6)]);
        let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
        let (out, report) = synchronize(&cluster8(), &grads, &opts);
        let exact = reduce_exact(&grads, true);
        // Underflow nearly eliminated; values within format epsilon-ish.
        assert!(report.layers[1].underflow_frac < 0.05, "{:?}", report.layers[1]);
        let e = avg_roundoff_error(&exact[1], &out[1]);
        assert!(e < 0.35, "roundoff {e}"); // 2-bit mantissa: ≤ ~1/8 per op
        assert!(!report.any_overflow());
        assert!(report.exponent_bytes > 0, "APS must pay the exponent phase");
    }

    #[test]
    fn aps_never_overflows_by_construction() {
        // Eq. 2 bound: even when every worker holds the max value with the
        // same sign, the scaled sum stays within the format.
        let world = 16;
        let grads: Vec<Vec<Vec<f32>>> =
            (0..world).map(|_| vec![vec![3.7e8f32; 16]]).collect();
        let cluster = SimCluster::new(world);
        let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 }).with_average(false);
        let (out, report) = synchronize(&cluster, &grads, &opts);
        assert!(!report.any_overflow());
        assert!(out[0].iter().all(|x| x.is_finite()));
        // and the sum is right to within the format's (2-bit-mantissa)
        // sequential-fold accumulation error — large but finite and
        // bounded (this is exactly the §4.2 round-off the paper studies).
        let exact = 3.7e8f64 * world as f64;
        let got = out[0][0] as f64;
        assert!((got - exact).abs() / exact < 0.35, "got {got} exact {exact}");
    }

    #[test]
    fn loss_scaling_overflow_when_factor_too_big() {
        let grads = scaled_grads(8, &[(64, 100.0)]);
        // 2^12 scale pushes values ~100·4096 ≈ 4e5 > E5M2 max 57344 → INF.
        let opts = SyncOptions::new(SyncMethod::LossScaling {
            fmt: FpFormat::E5M2,
            factor_exp: 12,
        });
        let (_, report) = synchronize(&cluster8(), &grads, &opts);
        assert!(report.any_overflow());
    }

    #[test]
    fn aps_factor_is_power_of_two_shift_exactness() {
        // A single worker, values already representable in E5M2: APS must
        // return them exactly (shift by 2^k is lossless — Fig 4).
        let vals: Vec<f32> = FpFormat::E5M2
            .enumerate_magnitudes()
            .into_iter()
            .filter(|&v| v > 0.0)
            .take(40)
            .collect();
        let grads = vec![vec![vals.clone()]];
        let cluster = SimCluster::new(1);
        let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
        let (out, _) = synchronize(&cluster, &grads, &opts);
        // world=1 → factor chosen so max ≤ 2^15; shifting representable
        // values by powers of two keeps them representable (until the
        // subnormal floor). Values here are normals scaled up, so exact.
        for (a, b) in vals.iter().zip(&out[0]) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn fp32_last_layer_policy() {
        let grads = scaled_grads(8, &[(32, 1e-6), (32, 1e-6)]);
        let opts = SyncOptions::new(SyncMethod::Naive { fmt: FpFormat::E5M2 })
            .with_fp32_last_layer(true);
        let (out, report) = synchronize(&cluster8(), &grads, &opts);
        // first layer dies, last layer survives at full precision
        assert!(report.layers[0].underflow_frac > 0.9);
        assert_eq!(report.layers[1].underflow_frac, 0.0);
        assert!(out[1].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn all_zero_layer_is_safe() {
        let world = 4;
        let grads: Vec<Vec<Vec<f32>>> = (0..world).map(|_| vec![vec![0.0f32; 8]]).collect();
        let cluster = SimCluster::new(world);
        let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E4M3 });
        let (out, report) = synchronize(&cluster, &grads, &opts);
        assert!(out[0].iter().all(|&x| x == 0.0));
        assert_eq!(report.layers[0].factor_exp, 0);
    }

    #[test]
    fn local_max_exp_matches_paper_findmaxexp() {
        // ceil(log2(8 * 3.0)) = ceil(log2 24) = 5
        assert_eq!(local_max_exp(&[1.0, -3.0, 0.5], 8), Some(5));
        // exact power of two: ceil(log2(4 * 4)) = 4
        assert_eq!(local_max_exp(&[4.0], 4), Some(4));
        assert_eq!(local_max_exp(&[0.0, 0.0], 8), None);
    }

    #[test]
    fn fused_reduces_message_count() {
        let grads = scaled_grads(8, &[(16, 1.0), (16, 1.0), (16, 1.0)]);
        let mut opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
        opts.fused = true;
        let (_, fused) = synchronize(&cluster8(), &grads, &opts);
        opts.fused = false;
        let (_, unfused) = synchronize(&cluster8(), &grads, &opts);
        assert_eq!(fused.messages, 1);
        assert_eq!(unfused.messages, 3);
        assert!(fused.steps < unfused.steps);
        // payload bytes identical — fusion saves latency, not bandwidth
        assert_eq!(fused.payload_bytes, unfused.payload_bytes);
    }

    #[test]
    fn exponent_phase_is_one_byte_per_layer() {
        // APS communicates ceil(log2(N·ĝ)) as a single byte per layer
        // (paper §3.3.3) — check the accounting.
        let grads = scaled_grads(8, &[(1000, 1.0); 5]);
        let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
        let (_, report) = synchronize(&cluster8(), &grads, &opts);
        // ring max all-reduce of 5 bytes across 8 workers
        assert!(report.exponent_bytes <= 2 * 5 * 8);
        assert!(report.exponent_bytes < report.payload_bytes / 100);
    }
}

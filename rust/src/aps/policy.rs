//! Precision *policies*: which sync method applies when (paper §4.2).
//!
//! * [`HybridSchedule`] — the paper's hybrid precision (Fig 10, Table 6):
//!   FP32 communication for the first `fp32_epochs` epochs, the low
//!   precision format afterwards. "Using FP32 for the first 30 epochs and
//!   8 bits for the last 60" recovers the FP32 baseline accuracy.
//! * [`LayerPolicy`] — per-layer wire formats (Table 7): the last
//!   (classification) layer kept at FP32 while all others run low.

use super::SyncMethod;
use crate::cpd::FpFormat;

/// Epoch-indexed hybrid precision schedule (paper Fig 10).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HybridSchedule {
    /// Epochs trained with FP32 communication before switching down.
    pub fp32_epochs: usize,
    /// The low-precision method used afterwards.
    pub low: SyncMethod,
}

impl HybridSchedule {
    /// Paper's ResNet-50 recipe: 30 FP32 epochs then (4,3) APS.
    pub fn paper_resnet50() -> Self {
        HybridSchedule {
            fp32_epochs: 30,
            low: SyncMethod::Aps { fmt: FpFormat::E4M3 },
        }
    }

    /// The method in effect at `epoch` (0-based).
    pub fn method_at(&self, epoch: usize) -> SyncMethod {
        if epoch < self.fp32_epochs {
            SyncMethod::Fp32
        } else {
            self.low
        }
    }
}

/// Per-layer wire-format policy (paper Table 7).
#[derive(Clone, Debug, PartialEq)]
pub enum LayerPolicy {
    /// Every layer uses the method's wire format.
    Uniform,
    /// All layers low-precision except the final classification layer,
    /// which stays FP32 (Wang et al. [27]'s recommendation, Table 7 row 2/4).
    Fp32LastLayer,
}

impl LayerPolicy {
    /// Wire format for layer `l` of `num_layers` given the base format.
    pub fn format_for(&self, base: FpFormat, l: usize, num_layers: usize) -> FpFormat {
        match self {
            LayerPolicy::Uniform => base,
            LayerPolicy::Fp32LastLayer => {
                if l + 1 == num_layers {
                    FpFormat::FP32
                } else {
                    base
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_switches_at_boundary() {
        let h = HybridSchedule::paper_resnet50();
        assert_eq!(h.method_at(0), SyncMethod::Fp32);
        assert_eq!(h.method_at(29), SyncMethod::Fp32);
        assert_eq!(h.method_at(30), SyncMethod::Aps { fmt: FpFormat::E4M3 });
        assert_eq!(h.method_at(89), SyncMethod::Aps { fmt: FpFormat::E4M3 });
    }

    #[test]
    fn layer_policy_formats() {
        let base = FpFormat::E5M2;
        assert_eq!(LayerPolicy::Uniform.format_for(base, 9, 10), base);
        assert_eq!(
            LayerPolicy::Fp32LastLayer.format_for(base, 9, 10),
            FpFormat::FP32
        );
        assert_eq!(LayerPolicy::Fp32LastLayer.format_for(base, 8, 10), base);
    }
}

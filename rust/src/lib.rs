//! # aps-cpd — Auto-Precision Scaling for Distributed Deep Learning
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"Auto-Precision Scaling for Distributed Deep Learning"* (Han, Demmel,
//! Si, You). The crate contains:
//!
//! * [`cpd`] — the **C**ustomized-**P**recision **D**eep-learning numeric
//!   substrate: arbitrary `(exp_bits, man_bits)` floating-point formats,
//!   bit-exact round-to-nearest-even casts, low-precision accumulation,
//!   Kahan summation, and low-precision GEMM (paper §5).
//! * [`collectives`] — a simulated N-worker cluster whose reduction
//!   *order* and operand precision are faithfully emulated (paper §4.2,
//!   Tables 8–9). Topologies are pluggable behind the
//!   [`collectives::Collective`] trait (ring and hierarchical in-tree).
//! * [`sync`] — the gradient-synchronization layer: a pluggable
//!   [`sync::SyncStrategy`] codec trait (prepare → encode → reduce →
//!   decode, with structured [`sync::WireCost`] traffic accounting) and a
//!   buffer-reusing [`sync::SyncSession`] that owns one strategy, one
//!   collective, and all hot-path scratch. The paper's four methods are
//!   strategy impls; TernGrad-style ternarization, top-k sparsification
//!   and QSGD bucketed quantization ship as net-new codecs, and
//!   [`sync::ErrorFeedback`] layers residual memory over any of them.
//!   Under the default packed wire ([`sync::WireMode::Packed`]) encoded
//!   tensors move as bit-packed [`sync::PackedWire`] buffers — 2-bit
//!   ternary symbols, `bits`-wide QSGD codes, format-width bit-codes —
//!   so simulated traffic is payload-proportional while staying
//!   bit-identical to the dense-f32 simulation.
//! * [`aps`] — the paper-level method vocabulary ([`aps::SyncMethod`],
//!   Algorithm 1 helpers, [`aps::SyncReport`]).
//! * [`optim`] — momentum SGD, Nesterov, LARS, LR schedules (paper §4.1).
//! * [`data`] — deterministic synthetic datasets standing in for CIFAR-10,
//!   cityscapes and a token corpus (see DESIGN.md §3 substitutions).
//! * [`runtime`] — PJRT loader/executor for the JAX-lowered HLO artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs on the training path.
//! * [`coordinator`] — the distributed-training driver tying it together.
//! * [`perfmodel`] — the α–β communication cost model (paper Fig 11).
//! * [`metrics`] — accuracy / mIoU / histograms / round-off error (Eq. 5).
//! * [`lint`] — `apslint`, the repo-native static-analysis pass that
//!   enforces the wire-honesty / no-alloc / determinism invariants at
//!   the source level (`cargo run --bin apslint`).
//!
//! ## Migrating from `aps::synchronize`
//!
//! `aps::synchronize(&cluster, &grads, &opts)` has been **removed** after
//! its one-release deprecation window (`aps::legacy::synchronize` remains,
//! hidden, purely to pin the bit-identity equivalence suite). It allocated
//! every wire buffer, the output tensors and the report on each call; the
//! replacement owns them across steps:
//!
//! ```
//! use aps_cpd::aps::{SyncMethod, SyncOptions};
//! use aps_cpd::sync::SyncSessionBuilder;
//!
//! let opts = SyncOptions::new(SyncMethod::Fp32);
//! // once, at trainer construction:
//! let mut session = SyncSessionBuilder::from_sync_options(4, &opts).build();
//! // every training step:
//! let grads = vec![vec![vec![0.5f32; 16]]; 4];
//! let (reduced, report) = session.step(&grads);
//! assert_eq!(reduced.len(), 1);
//! assert!(!report.any_overflow());
//! ```
//!
//! New codecs implement [`sync::SyncStrategy`] and plug in via
//! [`sync::SyncSessionBuilder::strategy`]; new topologies implement
//! [`collectives::Collective`] and plug in via
//! [`sync::SyncSessionBuilder::collective`]. Configs name built-in
//! strategies (`fp32 | naive | loss_scaling | aps | ternary | topk |
//! qsgd`) through [`sync::StrategySpec`]; prefixing a name with `ef:`
//! (e.g. `ef:topk`) wraps it in [`sync::ErrorFeedback`] residual memory,
//! `sync.qsgd_bits` / `sync.qsgd_bucket` tune the QSGD codec, and
//! `sync.ternary_seed` seeds both stochastic codecs (default: the
//! experiment seed). Every codec must pass the shared contract in
//! `rust/tests/codec_conformance.rs`.

pub mod aps;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod cpd;
pub mod data;
pub mod lint;
pub mod metrics;
pub mod optim;
pub mod perfmodel;
pub mod runtime;
pub mod sync;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

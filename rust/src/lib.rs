//! # aps-cpd — Auto-Precision Scaling for Distributed Deep Learning
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"Auto-Precision Scaling for Distributed Deep Learning"* (Han, Demmel,
//! Si, You). The crate contains:
//!
//! * [`cpd`] — the **C**ustomized-**P**recision **D**eep-learning numeric
//!   substrate: arbitrary `(exp_bits, man_bits)` floating-point formats,
//!   bit-exact round-to-nearest-even casts, low-precision accumulation,
//!   Kahan summation, and low-precision GEMM (paper §5).
//! * [`collectives`] — a simulated N-worker cluster with ring and
//!   hierarchical all-reduce whose reduction *order* and operand precision
//!   are faithfully emulated (paper §4.2, Tables 8–9).
//! * [`aps`] — Algorithm 1: layer-wise automatic power-of-two scaling for
//!   low-precision gradient communication, plus the loss-scaling and
//!   no-scaling baselines (paper §3).
//! * [`optim`] — momentum SGD, Nesterov, LARS, LR schedules (paper §4.1).
//! * [`data`] — deterministic synthetic datasets standing in for CIFAR-10,
//!   cityscapes and a token corpus (see DESIGN.md §3 substitutions).
//! * [`runtime`] — PJRT loader/executor for the JAX-lowered HLO artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs on the training path.
//! * [`coordinator`] — the distributed-training driver tying it together.
//! * [`perfmodel`] — the α–β communication cost model (paper Fig 11).
//! * [`metrics`] — accuracy / mIoU / histograms / round-off error (Eq. 5).

pub mod aps;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod cpd;
pub mod data;
pub mod metrics;
pub mod optim;
pub mod perfmodel;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! The `apslint` rule implementations.
//!
//! Each rule is a free function `fn(&FileCtx, &mut Vec<Diagnostic>)`
//! that pattern-matches the file's code-token stream. See the module
//! docs in [`super`] for the rule table, rationale, and waiver syntax.

use super::lexer::{Tok, TokKind};
use super::{Diagnostic, FileCtx, Severity};
use std::collections::BTreeMap;

fn id<'a>(code: &'a [Tok], i: usize) -> Option<&'a str> {
    code.get(i).and_then(|t| t.ident())
}
fn p(code: &[Tok], i: usize, c: char) -> bool {
    code.get(i).is_some_and(|t| t.is_punct(c))
}
fn lit<'a>(code: &'a [Tok], i: usize) -> Option<&'a str> {
    code.get(i).and_then(|t| t.literal())
}

fn diag(
    diags: &mut Vec<Diagnostic>,
    rule: &'static str,
    ctx: &FileCtx,
    line: u32,
    message: String,
) {
    diags.push(Diagnostic {
        rule,
        severity: Severity::Error,
        file: ctx.path.to_string(),
        line,
        message,
        waived: None,
    });
}

// ---------------------------------------------------------------------
// Rule: alloc_in_hot_path
// ---------------------------------------------------------------------

/// No `Vec::new` / `Vec::with_capacity` / `vec!` / `.to_vec()` /
/// `.collect()` / `Box::new` inside the configured hot-path functions.
/// Capacity-*reusing* calls (`clear`, `resize`, `push`,
/// `extend_from_slice` on long-lived scratch) are deliberately allowed:
/// after warmup they do not allocate, which is exactly the property the
/// counting-allocator test pins at runtime.
pub fn alloc_in_hot_path(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    for i in 0..code.len() {
        if !ctx.in_hot_path(i) {
            continue;
        }
        let line = code[i].line;
        if p(code, i + 1, ':') && p(code, i + 2, ':') {
            let callee = id(code, i + 3);
            if id(code, i) == Some("Vec")
                && matches!(callee, Some("new") | Some("with_capacity"))
            {
                diag(
                    diags,
                    "alloc_in_hot_path",
                    ctx,
                    line,
                    format!(
                        "`Vec::{}` allocates on the hot path; reuse session-owned scratch",
                        callee.unwrap_or_default()
                    ),
                );
            }
            if id(code, i) == Some("Box") && callee == Some("new") {
                diag(
                    diags,
                    "alloc_in_hot_path",
                    ctx,
                    line,
                    "`Box::new` allocates on the hot path".to_string(),
                );
            }
        }
        if id(code, i) == Some("vec") && p(code, i + 1, '!') {
            diag(
                diags,
                "alloc_in_hot_path",
                ctx,
                line,
                "`vec![…]` allocates on the hot path; reuse session-owned scratch".to_string(),
            );
        }
        if p(code, i, '.') {
            if id(code, i + 1) == Some("to_vec") {
                diag(
                    diags,
                    "alloc_in_hot_path",
                    ctx,
                    line,
                    "`.to_vec()` copies into a fresh allocation on the hot path".to_string(),
                );
            }
            if id(code, i + 1) == Some("collect") {
                diag(
                    diags,
                    "alloc_in_hot_path",
                    ctx,
                    line,
                    "`.collect()` allocates on the hot path; write into reused scratch"
                        .to_string(),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rule: wire_honesty
// ---------------------------------------------------------------------

/// Any `impl SyncStrategy for T` that overrides `wire_cost` must also
/// override both `encode_packed` and `decode_packed`: a codec that
/// claims a non-default wire cost but rides the default f32 packing
/// would move bytes its own accounting never admits to.
pub fn wire_honesty(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    let mut i = 0usize;
    while i < code.len() {
        if id(code, i) != Some("impl") || ctx.in_test(i) {
            i += 1;
            continue;
        }
        // Header runs from `impl` to the opening brace.
        let mut open = i + 1;
        while open < code.len() && !p(code, open, '{') && !p(code, open, ';') {
            open += 1;
        }
        if !p(code, open, '{') {
            i = open + 1;
            continue;
        }
        // Trait position: the path segment directly before a `for` that
        // is not a higher-ranked `for<'a>`.
        let mut is_sync_strategy = false;
        let mut type_name = String::new();
        for j in i + 1..open {
            if id(code, j) == Some("for")
                && !p(code, j + 1, '<')
                && id(code, j - 1) == Some("SyncStrategy")
            {
                is_sync_strategy = true;
                for k in j + 1..open {
                    if let Some(name) = id(code, k) {
                        if name != "dyn" {
                            type_name = name.to_string();
                            break;
                        }
                    }
                }
                break;
            }
        }
        if !is_sync_strategy {
            i = open + 1;
            continue;
        }
        // Collect method names defined at the impl's top level.
        let mut depth = 1i64;
        let mut methods: Vec<String> = Vec::new();
        let mut j = open + 1;
        while j < code.len() && depth > 0 {
            match &code[j].kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => depth -= 1,
                TokKind::Ident(s) if s == "fn" && depth == 1 => {
                    if let Some(name) = id(code, j + 1) {
                        methods.push(name.to_string());
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let has = |m: &str| methods.iter().any(|n| n == m);
        if has("wire_cost") && !(has("encode_packed") && has("decode_packed")) {
            diag(
                diags,
                "wire_honesty",
                ctx,
                code[i].line,
                format!(
                    "`impl SyncStrategy for {type_name}` overrides `wire_cost` but not both \
                     `encode_packed` and `decode_packed` — it would claim packed bits the \
                     default f32 packing never moves"
                ),
            );
        }
        i = j;
    }
}

// ---------------------------------------------------------------------
// Rule: lossy_cast
// ---------------------------------------------------------------------

/// `as` casts that can truncate or lose precision, where the source
/// type is resolvable from local, explicit evidence (see module docs
/// for the resolution rules — unresolvable sources are never flagged).
pub fn lossy_cast(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    let fields = struct_fields(code);
    for i in 0..code.len() {
        if id(code, i) != Some("as") || ctx.in_test(i) {
            continue;
        }
        let Some(dst) = id(code, i + 1).filter(|t| is_prim(t)) else {
            continue; // `use x as y`, `as &dyn T`, …
        };
        let Some(src) = resolve_source(ctx, &fields, i) else {
            continue;
        };
        if let Some(why) = lossiness(&src, dst) {
            diag(
                diags,
                "lossy_cast",
                ctx,
                code[i].line,
                format!("`{src} as {dst}` {why}"),
            );
        }
    }
}

const PRIMS: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
    "f32", "f64",
];

fn is_prim(t: &str) -> bool {
    PRIMS.contains(&t)
}

/// Integer width for truncation checks. `usize`/`isize` are treated as
/// 64-bit as a *source* and 32-bit as a *target* — conservative in both
/// directions, which is the point: `u64 as usize` truncates on 32-bit
/// hosts, `usize as u32` truncates on 64-bit hosts, and `u32 as usize`
/// is safe everywhere.
fn int_width(t: &str, as_target: bool) -> Option<u32> {
    Some(match t {
        "u8" | "i8" => 8,
        "u16" | "i16" => 16,
        "u32" | "i32" => 32,
        "u64" | "i64" => 64,
        "u128" | "i128" => 128,
        "usize" | "isize" => {
            if as_target {
                32
            } else {
                64
            }
        }
        _ => return None,
    })
}

/// Why `src as dst` is lossy, or `None` when it is not a concern.
/// Float→int casts are never flagged: rounding is the quantization
/// kernels' entire job and always intentional here.
fn lossiness(src: &str, dst: &str) -> Option<&'static str> {
    if src == dst {
        return None;
    }
    if src == "f64" && dst == "f32" {
        return Some("loses precision (f64 → f32)");
    }
    if src == "f32" || src == "f64" {
        return None;
    }
    // Integer source from here on.
    let sw = int_width(src, false)?;
    if dst == "f64" {
        // usize is excluded: `.len() as f64` in stats code is ubiquitous
        // and lengths here are nowhere near 2^53.
        return if matches!(src, "u64" | "i64" | "u128" | "i128") {
            Some("loses precision above 2^53 (f64 mantissa)")
        } else {
            None
        };
    }
    if dst == "f32" {
        // usize is excluded for the same reason as the f64 arm: small
        // index/length casts into f32 tensors are the dominant use.
        return if sw > 24 && !matches!(src, "usize" | "isize") {
            Some("loses precision above 2^24 (f32 mantissa)")
        } else {
            None
        };
    }
    let dw = int_width(dst, true)?;
    if sw > dw {
        return if dst == "usize" || dst == "isize" {
            Some("truncates on 32-bit targets")
        } else if src == "usize" || src == "isize" {
            Some("truncates on 64-bit hosts")
        } else {
            Some("truncates")
        };
    }
    None
}

/// Struct fields declared in this file with primitive types:
/// `field -> type`. A field name declared twice with conflicting types
/// is dropped (ambiguous).
fn struct_fields(code: &[Tok]) -> BTreeMap<String, String> {
    let mut out: BTreeMap<String, String> = BTreeMap::new();
    let mut ambiguous: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if id(code, i) != Some("struct") {
            i += 1;
            continue;
        }
        // Find the body brace; tuple structs `struct X(…);` and unit
        // structs have none and are skipped.
        let mut open = i + 1;
        while open < code.len()
            && !p(code, open, '{')
            && !p(code, open, ';')
            && !p(code, open, '(')
        {
            open += 1;
        }
        if !p(code, open, '{') {
            i = open + 1;
            continue;
        }
        let mut depth = 1i64;
        let mut j = open + 1;
        while j < code.len() && depth > 0 {
            match &code[j].kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => depth -= 1,
                TokKind::Ident(name) if depth == 1 => {
                    if p(code, j + 1, ':')
                        && !p(code, j + 2, ':')
                        && id(code, j + 2).is_some_and(is_prim)
                        && (p(code, j + 3, ',') || p(code, j + 3, '}'))
                    {
                        let ty = id(code, j + 2).unwrap_or_default().to_string();
                        match out.get(name) {
                            Some(prev) if prev != &ty => ambiguous.push(name.clone()),
                            _ => {
                                out.insert(name.clone(), ty);
                            }
                        }
                    }
                }
                _ => {}
            }
            j += 1;
        }
        i = j;
    }
    for name in ambiguous {
        out.remove(&name);
    }
    out
}

/// Resolve the primitive type of the expression ending just before the
/// `as` at token index `i`, using only local, explicit evidence.
fn resolve_source(
    ctx: &FileCtx,
    fields: &BTreeMap<String, String>,
    i: usize,
) -> Option<String> {
    let code = &ctx.code;
    if i == 0 {
        return None;
    }
    match &code[i - 1].kind {
        // literal suffix: `0u64 as u32`, `1e-3f64 as f32`
        TokKind::Literal(text) => literal_suffix(text),
        TokKind::Ident(name) => {
            // cast chain: `x as u64 as u32`
            if is_prim(name) && id(code, i.wrapping_sub(2)) == Some("as") {
                return Some(name.clone());
            }
            // field access: `self.acc as u8`, `w.nbits as usize`
            if i >= 2 && p(code, i - 2, '.') {
                return fields.get(name.as_str()).cloned();
            }
            lookup_binding(ctx, fields, name, i)
        }
        // parenthesized expression: `(bit_offset / 8) as usize`,
        // `x.len() as f64`, `(man as f64 * p) as f32`
        TokKind::Punct(')') => {
            let open = matching_open(code, i - 1)?;
            resolve_paren_group(ctx, fields, open, i - 1)
        }
        _ => None,
    }
}

/// Type suffix of a numeric literal, if it has one. (Known lexer
/// limitation: a suffix-less hex literal whose digits end in e.g.
/// `f32` would be read as suffixed; no such literal exists here.)
fn literal_suffix(text: &str) -> Option<String> {
    if text.starts_with('"') || text.starts_with('\'') || text.starts_with('r')
        || text.starts_with('b')
    {
        return None;
    }
    PRIMS.iter().find(|s| text.ends_with(*s)).map(|s| s.to_string())
}

/// Index of the `(` matching the `)` at `close`.
fn matching_open(code: &[Tok], close: usize) -> Option<usize> {
    let mut depth = 0i64;
    for j in (0..=close).rev() {
        if p(code, j, ')') {
            depth += 1;
        } else if p(code, j, '(') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// Resolve a `( … )` group in `open+1..close`. Handles, in order:
/// known method-call results (`.len()` → `usize`,
/// `.leading_zeros()` → `u32`), a trailing inner cast (`(x as u64)`),
/// and otherwise a flat integer expression over resolved variables,
/// field accesses and unsuffixed literals — every identifier must
/// resolve and all resolved types must agree, or the group is treated
/// as unresolvable.
fn resolve_paren_group(
    ctx: &FileCtx,
    fields: &BTreeMap<String, String>,
    open: usize,
    close: usize,
) -> Option<String> {
    let code = &ctx.code;
    // Method call: `recv.len() as …` — the `(` is the argument list.
    if open >= 2 && p(code, open - 2, '.') {
        return match id(code, open - 1) {
            Some("len") => Some("usize".to_string()),
            Some("leading_zeros") | Some("trailing_zeros") | Some("count_ones")
            | Some("count_zeros") => Some("u32".to_string()),
            _ => None,
        };
    }
    // Any other call `f(…) as …` is unresolvable.
    if open >= 1 && id(code, open - 1).is_some() {
        return None;
    }
    // Trailing inner cast: `(… as u64)`.
    if close >= 2 && id(code, close - 2) == Some("as") {
        let t = id(code, close - 1)?;
        return is_prim(t).then(|| t.to_string());
    }
    // Flat expression walk.
    let mut ty: Option<String> = None;
    let mut j = open + 1;
    while j < close {
        match &code[j].kind {
            TokKind::Ident(name) => {
                if name == "as" {
                    return None; // inner cast not in trailing position
                }
                let t = if p(code, j + 1, '.') {
                    // only plain field access `a.b` (no call) resolves
                    let f = id(code, j + 2)?;
                    if p(code, j + 3, '(') {
                        return None;
                    }
                    let t = fields.get(f).cloned()?;
                    j += 2;
                    t
                } else if name == "self" {
                    return None;
                } else {
                    lookup_binding(ctx, fields, name, j)?
                };
                match &ty {
                    Some(prev) if prev != &t => return None,
                    _ => ty = Some(t),
                }
            }
            TokKind::Literal(text) => {
                if let Some(t) = literal_suffix(text) {
                    match &ty {
                        Some(prev) if prev != &t => return None,
                        _ => ty = Some(t),
                    }
                } else if text.contains('.') {
                    return None; // unsuffixed float literal
                }
                // unsuffixed integer literals adopt the expression type
            }
            TokKind::Punct(c)
                if matches!(c, '+' | '-' | '*' | '/' | '%' | '&' | '|' | '^' | '<' | '>') => {}
            _ => return None,
        }
        j += 1;
    }
    ty
}

/// Find the latest explicit binding of `name` before token `at` inside
/// the innermost enclosing function: a `let name: T`, a typed closure
/// or fn parameter `name: T`, or a `const NAME: T` in the signature's
/// generics. `self.field` is handled by the caller via the field table.
fn lookup_binding(
    ctx: &FileCtx,
    _fields: &BTreeMap<String, String>,
    name: &str,
    at: usize,
) -> Option<String> {
    let code = &ctx.code;
    let f = ctx.enclosing_fn(at)?;
    let mut found: Option<String> = None;
    // Parameters (and signature const generics): `name : prim` between
    // the `fn` token and the body, not part of a `::` path.
    for j in f.sig..f.body.start {
        if id(code, j) == Some(name)
            && p(code, j + 1, ':')
            && !p(code, j + 2, ':')
            && !p(code, j.wrapping_sub(1), ':')
            && id(code, j + 2).is_some_and(is_prim)
        {
            found = Some(id(code, j + 2).unwrap_or_default().to_string());
        }
    }
    // `let [mut] name : prim` and typed closure params inside the body,
    // latest before `at` wins (shadowing).
    for j in f.body.start..at.min(f.body.end) {
        let is_let_binding = id(code, j) == Some("let")
            && {
                let mut k = j + 1;
                if id(code, k) == Some("mut") {
                    k += 1;
                }
                id(code, k) == Some(name) && p(code, k + 1, ':') && !p(code, k + 2, ':')
                    && id(code, k + 2).is_some_and(is_prim)
            };
        if is_let_binding {
            let mut k = j + 1;
            if id(code, k) == Some("mut") {
                k += 1;
            }
            found = Some(id(code, k + 2).unwrap_or_default().to_string());
        }
    }
    found
}

// ---------------------------------------------------------------------
// Rule: unsafe_code
// ---------------------------------------------------------------------

/// The crate is `unsafe`-free; keep it that way. Test code is exempt
/// (the counting global allocator in `rust/tests` is unsafe by the
/// nature of `GlobalAlloc`, and tests are outside the scan roots
/// anyway).
pub fn unsafe_code(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    for i in 0..ctx.code.len() {
        if id(&ctx.code, i) == Some("unsafe") && !ctx.in_test(i) {
            diag(
                diags,
                "unsafe_code",
                ctx,
                ctx.code[i].line,
                "`unsafe` is banned: the crate is unsafe-free and pinned so".to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Rule: panic_in_hot_path
// ---------------------------------------------------------------------

/// No hidden panics on the hot path: `.unwrap()`, `.expect(…)` and
/// literal indexing (`xs[0]`). Explicit `assert!`s remain allowed —
/// ragged-input panics are the documented conformance contract.
pub fn panic_in_hot_path(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    for i in 0..code.len() {
        if !ctx.in_hot_path(i) {
            continue;
        }
        let line = code[i].line;
        if p(code, i, '.') && p(code, i + 2, '(') {
            match id(code, i + 1) {
                Some("unwrap") => diag(
                    diags,
                    "panic_in_hot_path",
                    ctx,
                    line,
                    "`.unwrap()` hides a panic on the hot path".to_string(),
                ),
                Some("expect") => diag(
                    diags,
                    "panic_in_hot_path",
                    ctx,
                    line,
                    "`.expect(…)` hides a panic on the hot path".to_string(),
                ),
                _ => {}
            }
        }
        // Literal indexing `recv[0]`: previous token must make this an
        // index (identifier, `)`, or `]`), not an array literal `[0]`.
        if p(code, i, '[')
            && lit(code, i + 1).is_some_and(is_plain_int)
            && p(code, i + 2, ']')
            && i >= 1
            && (id(code, i - 1).is_some() || p(code, i - 1, ')') || p(code, i - 1, ']'))
        {
            diag(
                diags,
                "panic_in_hot_path",
                ctx,
                line,
                format!(
                    "literal index `[{}]` can panic on the hot path; assert the shape once \
                     and use checked access",
                    lit(code, i + 1).unwrap_or_default()
                ),
            );
        }
    }
}

fn is_plain_int(text: &str) -> bool {
    !text.is_empty() && text.chars().all(|c| c.is_ascii_digit() || c == '_')
}

// ---------------------------------------------------------------------
// Rule: nondeterminism
// ---------------------------------------------------------------------

/// Encode/decode/fold paths must be reproducible: wire bytes and fold
/// results may not depend on hash iteration order, the wall clock, or
/// the host's thread count. `num_threads`/`available_parallelism`
/// *calls* are flagged so each use carries a waiver explaining why it
/// only affects scheduling, never values.
pub fn nondeterminism(ctx: &FileCtx, diags: &mut Vec<Diagnostic>) {
    let code = &ctx.code;
    for i in 0..code.len() {
        if !ctx.in_nd_scope(i) {
            continue;
        }
        let line = code[i].line;
        match id(code, i) {
            Some(name @ ("HashMap" | "HashSet")) => diag(
                diags,
                "nondeterminism",
                ctx,
                line,
                format!(
                    "`{name}` iteration order is nondeterministic; use `BTreeMap`/`BTreeSet` \
                     or index-ordered vectors in encode/decode/fold paths"
                ),
            ),
            Some(name @ ("Instant" | "SystemTime"))
                if p(code, i + 1, ':')
                    && p(code, i + 2, ':')
                    && id(code, i + 3) == Some("now") =>
            {
                diag(
                    diags,
                    "nondeterminism",
                    ctx,
                    line,
                    format!("`{name}::now()` makes encode/decode/fold results time-dependent"),
                )
            }
            Some(name @ ("num_threads" | "available_parallelism")) if p(code, i + 1, '(') => {
                diag(
                    diags,
                    "nondeterminism",
                    ctx,
                    line,
                    format!(
                        "`{name}()` in an encode/decode/fold path: results must be \
                         bit-identical for any thread count — waive with the reason why \
                         this only affects scheduling"
                    ),
                )
            }
            _ => {}
        }
    }
}

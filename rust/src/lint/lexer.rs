//! A small, self-contained Rust lexer for the `apslint` pass.
//!
//! This is *not* a full Rust lexer — it is exactly enough to turn a
//! source file into a flat token stream with line numbers, which is what
//! the rule matchers in [`super::rules`] pattern-match over. It handles
//! the parts that would otherwise produce false matches:
//!
//! * line comments and (nested) block comments — retained as
//!   [`TokKind::Comment`] tokens so the waiver scanner can read them;
//! * string literals, raw strings (`r"…"`, `r#"…"#`), byte strings, and
//!   char literals — retained as opaque [`TokKind::Literal`]s so that,
//!   e.g., the string `"Vec::new"` never matches the alloc rule;
//! * char literals vs. lifetimes (`'a'` vs. `'a`);
//! * numeric literals including type suffixes (`0usize`, `1e-3f32`),
//!   kept as a single token so the lossy-cast rule can read the suffix.
//!
//! Known simplifications (fine for linting, documented here on purpose):
//! multi-char operators are emitted as individual [`TokKind::Punct`]
//! chars (`::` is `:`, `:`), and a hex literal whose digits happen to end
//! in `f32`/`u32`-like text (e.g. `0x1f32`) is read as suffixed.

/// Token payload. Lines are 1-based.
#[derive(Clone, Debug, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `Vec`, …).
    Ident(String),
    /// Lifetime, without the leading quote (`'a` → `a`).
    Lifetime(String),
    /// Any literal: string, raw string, char, byte, or number.
    /// The full source text is kept (including numeric type suffixes).
    Literal(String),
    /// A single punctuation character.
    Punct(char),
    /// A comment, full text including the `//` or `/* … */` markers.
    /// For block comments the line is the line the comment *starts* on.
    Comment(String),
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub line: u32,
}

impl Tok {
    /// The identifier text, if this token is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }
    /// True when this token is the given punctuation char.
    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.kind, TokKind::Punct(p) if *p == c)
    }
    /// The literal text, if this token is a literal.
    pub fn literal(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Literal(s) => Some(s.as_str()),
            _ => None,
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}
fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unterminated constructs run to EOF and
/// unknown bytes become [`TokKind::Punct`] tokens — a linter must keep
/// going on odd input rather than refuse the file.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = b[i];

        // -- whitespace -------------------------------------------------
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // -- comments ---------------------------------------------------
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.push(Tok { kind: TokKind::Comment(b[start..i].iter().collect()), line });
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Tok {
                kind: TokKind::Comment(b[start..i].iter().collect()),
                line: start_line,
            });
            continue;
        }

        // -- raw strings / raw identifiers / byte strings ---------------
        if c == 'r' || c == 'b' {
            // r"…", r#"…"#, br"…", b"…", b'…', r#ident
            let mut j = i + 1;
            let mut is_raw = c == 'r';
            if c == 'b' && j < n && b[j] == 'r' {
                is_raw = true;
                j += 1;
            }
            if is_raw {
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    // raw string: scan for `"` followed by `hashes` hashes
                    let start = i;
                    let start_line = line;
                    j += 1;
                    'raw: while j < n {
                        if b[j] == '\n' {
                            line += 1;
                            j += 1;
                            continue;
                        }
                        if b[j] == '"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break 'raw;
                            }
                        }
                        j += 1;
                    }
                    out.push(Tok {
                        kind: TokKind::Literal(b[start..j].iter().collect()),
                        line: start_line,
                    });
                    i = j;
                    continue;
                }
                if c == 'r' && hashes == 1 && j < n && is_ident_start(b[j]) {
                    // raw identifier r#ident
                    let start = j;
                    while j < n && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    out.push(Tok { kind: TokKind::Ident(b[start..j].iter().collect()), line });
                    i = j;
                    continue;
                }
                // not actually raw — fall through to plain ident below
            }
            if c == 'b' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '\'') {
                // byte string / byte char: skip the `b`, reuse the string
                // and char paths below by treating the quote directly.
                let quote = b[i + 1];
                let start = i;
                let start_line = line;
                let mut j = i + 2;
                while j < n {
                    if b[j] == '\\' {
                        j += 2;
                        continue;
                    }
                    if b[j] == '\n' {
                        line += 1;
                    }
                    if b[j] == quote {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                out.push(Tok {
                    kind: TokKind::Literal(b[start..j].iter().collect()),
                    line: start_line,
                });
                i = j;
                continue;
            }
        }

        // -- identifiers / keywords -------------------------------------
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            out.push(Tok { kind: TokKind::Ident(b[start..i].iter().collect()), line });
            continue;
        }

        // -- numbers (with suffix, exponent, hex/oct/bin) ----------------
        if c.is_ascii_digit() {
            let start = i;
            let is_prefixed = c == '0'
                && i + 1 < n
                && matches!(b[i + 1], 'x' | 'X' | 'o' | 'O' | 'b' | 'B');
            i += 1;
            while i < n {
                let d = b[i];
                if d.is_ascii_alphanumeric() || d == '_' {
                    // `1e-3`: the sign after e/E belongs to the exponent
                    // (decimal literals only — `0x1E-2` is subtraction).
                    if matches!(d, 'e' | 'E')
                        && !is_prefixed
                        && i + 1 < n
                        && matches!(b[i + 1], '+' | '-')
                    {
                        i += 2;
                        continue;
                    }
                    i += 1;
                    continue;
                }
                if d == '.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                    i += 2;
                    continue;
                }
                break;
            }
            out.push(Tok { kind: TokKind::Literal(b[start..i].iter().collect()), line });
            continue;
        }

        // -- strings -----------------------------------------------------
        if c == '"' {
            let start = i;
            let start_line = line;
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                    continue;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            out.push(Tok {
                kind: TokKind::Literal(b[start..i].iter().collect()),
                line: start_line,
            });
            continue;
        }

        // -- char literal vs. lifetime ----------------------------------
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char: '\n', '\'', '\u{…}'
                let start = i;
                let mut j = i + 3; // skip quote, backslash, escaped char
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                j = (j + 1).min(n);
                out.push(Tok { kind: TokKind::Literal(b[start..j].iter().collect()), line });
                i = j;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // plain char: 'a', '0', ' '
                out.push(Tok {
                    kind: TokKind::Literal(b[i..i + 3].iter().collect()),
                    line,
                });
                i += 3;
                continue;
            }
            if i + 1 < n && is_ident_start(b[i + 1]) {
                // lifetime: 'a, 'static, '_
                let start = i + 1;
                let mut j = start;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                out.push(Tok { kind: TokKind::Lifetime(b[start..j].iter().collect()), line });
                i = j;
                continue;
            }
            // stray quote — emit as punct and keep going
            out.push(Tok { kind: TokKind::Punct('\''), line });
            i += 1;
            continue;
        }

        // -- everything else is single-char punctuation -----------------
        out.push(Tok { kind: TokKind::Punct(c), line });
        i += 1;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let t = kinds("fn f(x: u32) -> u32 { x }");
        assert_eq!(t[0], TokKind::Ident("fn".into()));
        assert_eq!(t[1], TokKind::Ident("f".into()));
        assert!(t.contains(&TokKind::Punct('{')));
    }

    #[test]
    fn strings_are_opaque() {
        let t = kinds(r#"let s = "Vec::new() // not a comment";"#);
        assert!(!t.iter().any(|k| matches!(k, TokKind::Comment(_))));
        assert!(t.iter().any(
            |k| matches!(k, TokKind::Literal(s) if s.contains("Vec::new"))
        ));
        assert!(!t.iter().any(|k| matches!(k, TokKind::Ident(s) if s == "Vec")));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let t = kinds(r##"let s = r#"a "quoted" b"#; let r#fn = 1;"##);
        assert!(t.iter().any(
            |k| matches!(k, TokKind::Literal(s) if s.contains("quoted"))
        ));
        assert!(t.iter().any(|k| matches!(k, TokKind::Ident(s) if s == "fn")));
    }

    #[test]
    fn char_vs_lifetime() {
        let t = kinds("let c = 'a'; fn f<'a>(x: &'a str) {}");
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "'a'")));
        assert_eq!(
            t.iter().filter(|k| matches!(k, TokKind::Lifetime(s) if s == "a")).count(),
            2
        );
        let t = kinds(r"let q = '\''; let nl = '\n';");
        assert_eq!(
            t.iter().filter(|k| matches!(k, TokKind::Literal(_))).count(),
            2
        );
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let toks = lex("a\n/* x /* y */ z\n */ b");
        assert_eq!(toks[0].line, 1);
        assert!(matches!(&toks[1].kind, TokKind::Comment(_)));
        assert_eq!(toks[2].ident(), Some("b"));
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn numeric_suffixes_kept() {
        let t = kinds("let a = 0usize; let b = 1e-3f32; let c = 0x1E - 2;");
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "0usize")));
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "1e-3f32")));
        // 0x1E - 2 must stay three tokens (hex literal, minus, 2)
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "0x1E")));
        assert!(t.iter().any(|k| matches!(k, TokKind::Punct('-'))));
    }

    #[test]
    fn float_method_call_not_merged() {
        let t = kinds("let x = 1.max(2); let r = 0..4;");
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "1")));
        assert!(t.iter().any(|k| matches!(k, TokKind::Ident(s) if s == "max")));
        assert!(t.iter().any(|k| matches!(k, TokKind::Literal(s) if s == "0")));
    }
}

//! # `apslint` — repo-native static analysis for the APS invariants
//!
//! The crate's core guarantees — honest [`crate::sync::WireCost`]
//! accounting, zero per-step allocation in `SyncSession::step`, and
//! bit-identity between the packed and simulated wires — are enforced at
//! runtime by `session_alloc.rs`, `packed_wire.rs` and the codec
//! conformance suite. This module is their *static* complement: a small
//! lexer ([`lexer`]) plus a rule engine ([`rules`]) that pattern-matches
//! the token stream of every file under `rust/src`, `benches` and
//! `examples`, and fails CI on any unwaived diagnostic. Run it with
//! `cargo run --bin apslint`.
//!
//! ## Rules
//!
//! | rule | severity | what it catches | why |
//! |---|---|---|---|
//! | `alloc_in_hot_path` | error | `Vec::new` / `Vec::with_capacity` / `vec!` / `.to_vec()` / `.collect()` / `Box::new` inside the configured hot-path set | static complement to the counting-allocator pin in `rust/tests/session_alloc.rs`: the steady-state step path must not allocate |
//! | `wire_honesty` | error | a `SyncStrategy` impl that overrides `wire_cost` without overriding **both** `encode_packed` and `decode_packed` | a codec must never claim packed bits it does not actually pack — measured == claimed traffic is the paper's headline invariant |
//! | `lossy_cast` | error | truncating `as` casts (`u64 as u32`, `usize as u32`, `u64 as usize`, `f64 as f32`, `u64 as f64`, wide-int `as f32`) where the source type is locally resolvable | bit-kernel index math must survive 32-bit targets; value casts must be exact or carry a written reason |
//! | `unsafe_code` | error | any `unsafe` token outside `#[cfg(test)]` code | the crate is unsafe-free today; pin it |
//! | `panic_in_hot_path` | error | `.unwrap()` / `.expect()` / literal indexing (`x[0]`) inside the hot-path set | hidden panics on the step path; `assert!`s stay allowed — ragged-input panics are the documented conformance contract |
//! | `nondeterminism` | error | `HashMap` / `HashSet`, `Instant::now` / `SystemTime::now`, `num_threads` / `available_parallelism` inside encode / decode / fold paths | guard rail for the parallel packed fold (ROADMAP open item 1): wire bytes and fold results must not depend on host thread count or wall clock |
//!
//! `lossy_cast` only fires when the source type is *resolvable* from
//! local, explicit evidence: a `let x: T` annotation, an `fn` parameter,
//! a struct field declared in the same file, a literal suffix
//! (`0u64 as u32`), a cast chain (`x as u64 as u32`), a parenthesized
//! expression over a single resolved variable and integer literals
//! (`(bit_offset / 8) as usize`), or a known method (`.len()` → `usize`,
//! `.leading_zeros()` → `u32`). Anything else is conservatively left
//! unflagged — the rule is a tripwire for the bit kernels, not a type
//! checker.
//!
//! ## Waivers
//!
//! A diagnostic is waived — reported, but not fatal — by a comment on the
//! same line as the flagged token or on the line directly above it:
//!
//! ```text
//! // apslint: allow(lossy_cast) -- low-byte extraction; masked to 8 bits above
//! let byte = self.acc as u8;
//! ```
//!
//! The `-- reason` text is mandatory: a waiver without a written reason
//! is itself an error (`waiver_syntax`). Multiple rules may be listed:
//! `allow(alloc_in_hot_path, lossy_cast) -- …`. Naming a rule that does
//! not exist is a warning, so typos cannot silently disable anything.
//!
//! ## Report
//!
//! [`Report::to_json`] serializes every diagnostic (waived ones
//! included, with their reasons) plus summary counts; the `apslint`
//! binary writes it to `apslint_report.json` and CI uploads it as an
//! artifact. See EXPERIMENTS.md ("Static analysis") for how to read it.

pub mod lexer;
pub mod rules;

use crate::util::json::Json;
use lexer::{Tok, TokKind};
use std::collections::BTreeMap;
use std::ops::Range;
use std::path::{Path, PathBuf};

/// The shipped rule set. `waiver_syntax` is the engine's own meta-rule
/// (malformed waivers) and cannot itself be waived.
pub const RULES: &[&str] = &[
    "alloc_in_hot_path",
    "wire_honesty",
    "lossy_cast",
    "unsafe_code",
    "panic_in_hot_path",
    "nondeterminism",
];

/// Diagnostic severity. Unwaived `Error`s fail the run; `Warning`s are
/// reported (and counted in the JSON) but never change the exit code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding, tied to a file and 1-based line.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// `Some(reason)` when an `apslint: allow(...)` waiver covers this
    /// diagnostic; the written reason is carried into the report.
    pub waived: Option<String>,
}

impl Diagnostic {
    /// True when this diagnostic should fail the run.
    pub fn is_fatal(&self) -> bool {
        self.severity == Severity::Error && self.waived.is_none()
    }
    /// `file:line: severity[rule]: message` (the clickable format).
    pub fn render(&self) -> String {
        let waiver = match &self.waived {
            Some(r) => format!(" (waived: {r})"),
            None => String::new(),
        };
        format!(
            "{}:{}: {}[{}]: {}{}",
            self.file,
            self.line,
            self.severity.as_str(),
            self.rule,
            self.message,
            waiver
        )
    }
}

/// A file (matched by path suffix) whose listed functions are hot-path:
/// no allocation, no hidden panics. An empty `functions` list marks the
/// whole file hot.
#[derive(Clone, Debug)]
pub struct HotSpec {
    pub file_suffix: String,
    pub functions: Vec<String>,
}

/// Engine configuration: the hot-path set plus the nondeterminism scope.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub hot: Vec<HotSpec>,
    /// Path fragments (e.g. `"sync/"`) in which functions whose names
    /// start with one of [`Config::nd_fn_prefixes`] are encode/decode/
    /// fold paths for the `nondeterminism` rule (in addition to the hot
    /// set, which is always in scope for it).
    pub nd_path_fragments: Vec<String>,
    pub nd_fn_prefixes: Vec<String>,
}

impl Config {
    /// The repository's real hot-path set. Kept here, in code, so the
    /// lint config is reviewed like any other source change.
    pub fn repo_default() -> Config {
        let hot = |suffix: &str, fns: &[&str]| HotSpec {
            file_suffix: suffix.to_string(),
            functions: fns.iter().map(|s| s.to_string()).collect(),
        };
        Config {
            hot: vec![
                // The per-step session path (static complement to the
                // counting-allocator test), including the overlapped
                // bucket pipeline's per-bucket encode/fold entry points
                // and the parallel encode fan-out's per-layer twin-lane
                // entry points.
                hot(
                    "sync/session.rs",
                    &[
                        "step",
                        "step_overlapped",
                        "encode_bucket_layers",
                        "overlap_worker",
                        "encode_layer_packed",
                        "encode_layer_dense",
                    ],
                ),
                // Transport frame path: runs once per layer per worker
                // per step on the serializing transports.
                hot(
                    "sync/transport.rs",
                    &["exchange", "serialize_frame_into", "deserialize_frame"],
                ),
                // Parameter-server push/pull/fold path: one round per
                // reduce call, so these run once per layer per step.
                hot(
                    "sync/ps.rs",
                    &[
                        "all_reduce_sum_into",
                        "all_reduce_packed_sum_into",
                        "all_reduce_max_i8_into",
                        "fold_due",
                    ],
                ),
                // Bit-packing kernels: every BitWriter/BitReader method
                // and every pack_*/unpack_* transcoder.
                hot(
                    "sync/wire.rs",
                    &[
                        "put",
                        "put_many",
                        "finish",
                        "at",
                        "read",
                        "read_many",
                        "read_bits_at",
                        "read_bits_at_many",
                        "unpack_bits_into",
                        "low_byte",
                        "low_word",
                        "byte_index",
                        "bit_rem",
                        "pack_format_bits",
                        "unpack_format_bits",
                        "pack_raw_f32",
                        "unpack_raw_f32",
                        "pack_cast_layer",
                        "unpack_cast_range",
                        "meta_f32",
                        "push_meta_f32",
                        "meta_bytes",
                        "assign_parts",
                    ],
                ),
                // Collective fold kernels (single-threaded and parallel
                // packed entry points alike).
                hot(
                    "collectives/ring.rs",
                    &[
                        "all_reduce_into",
                        "all_reduce_packed_into",
                        "all_reduce_packed_into_par",
                    ],
                ),
                hot(
                    "collectives/hierarchical.rs",
                    &[
                        "all_reduce_with_scratch",
                        "all_reduce_packed_with_scratch",
                        "all_reduce_packed_with_scratch_par",
                    ],
                ),
                hot(
                    "collectives/mod.rs",
                    &[
                        "fold_step",
                        "all_reduce_sum_into",
                        "all_reduce_packed_sum_into",
                        "all_reduce_max_i8_into",
                        "max_i8_into",
                    ],
                ),
                // Quantize slice kernels.
                hot(
                    "cpd/cast.rs",
                    &[
                        "quantize_shifted_slice_into",
                        "quantize_slice_into",
                        "decode_bits",
                        "encode_bits",
                    ],
                ),
            ],
            nd_path_fragments: vec!["sync/".into(), "collectives/".into(), "cpd/".into()],
            nd_fn_prefixes: vec![
                "encode".into(),
                "decode".into(),
                "fold".into(),
                "all_reduce".into(),
                "pack".into(),
                "unpack".into(),
                "quantize".into(),
            ],
        }
    }

    /// No hot paths, no nondeterminism scope — only the whole-file rules
    /// (`unsafe_code`, `lossy_cast`, `wire_honesty`) fire. Useful for
    /// fixture tests.
    pub fn empty() -> Config {
        Config::default()
    }

    fn hot_spec_for(&self, path: &str) -> Option<&HotSpec> {
        self.hot.iter().find(|h| path.ends_with(&h.file_suffix))
    }
}

/// A function span over code-token indices. `sig` is the index of the
/// `fn` token (so parameter lists are in `sig..body.start`); `body`
/// excludes the braces themselves.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    pub line: u32,
    pub sig: usize,
    pub body: Range<usize>,
}

/// A parsed waiver comment.
#[derive(Clone, Debug)]
pub struct Waiver {
    pub rules: Vec<String>,
    pub reason: String,
    pub line: u32,
}

/// Everything the rules need to know about one file.
pub struct FileCtx<'a> {
    pub path: &'a str,
    /// Code tokens only (comments stripped).
    pub code: Vec<Tok>,
    pub fn_spans: Vec<FnSpan>,
    /// Code-token index ranges that are `#[cfg(test)]` / `#[test]` /
    /// `mod tests` bodies — excluded from every rule.
    pub test_ranges: Vec<Range<usize>>,
    pub waivers: Vec<Waiver>,
    pub cfg: &'a Config,
}

impl<'a> FileCtx<'a> {
    /// True when code-token index `i` lies inside test-only code.
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|r| r.contains(&i))
    }

    /// The innermost function span containing code-token index `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<&FnSpan> {
        self.fn_spans
            .iter()
            .filter(|f| f.body.contains(&i))
            .min_by_key(|f| f.body.end - f.body.start)
    }

    /// True when index `i` is inside a configured hot-path function.
    pub fn in_hot_path(&self, i: usize) -> bool {
        if self.in_test(i) {
            return false;
        }
        let Some(spec) = self.cfg.hot_spec_for(self.path) else {
            return false;
        };
        if spec.functions.is_empty() {
            return true;
        }
        // A nested hot fn keeps its enclosing names in scope too: check
        // every span containing `i`, not just the innermost.
        self.fn_spans
            .iter()
            .any(|f| f.body.contains(&i) && spec.functions.contains(&f.name))
    }

    /// True when index `i` is in scope for the `nondeterminism` rule:
    /// the hot set, plus encode/decode/fold-named functions under the
    /// configured path fragments.
    pub fn in_nd_scope(&self, i: usize) -> bool {
        if self.in_test(i) {
            return false;
        }
        if self.in_hot_path(i) {
            return true;
        }
        if !self.cfg.nd_path_fragments.iter().any(|p| self.path.contains(p.as_str())) {
            return false;
        }
        self.fn_spans.iter().any(|f| {
            f.body.contains(&i)
                && self.cfg.nd_fn_prefixes.iter().any(|p| f.name.starts_with(p.as_str()))
        })
    }
}

// ---------------------------------------------------------------------
// Waiver parsing
// ---------------------------------------------------------------------

/// Parse `apslint: allow(rule, …) -- reason` out of a comment. Returns
/// `Ok(None)` for comments that don't mention apslint, `Err(message)`
/// for ones that do but are malformed.
fn parse_waiver(text: &str, line: u32) -> Result<Option<Waiver>, String> {
    let Some(pos) = text.find("apslint:") else {
        return Ok(None);
    };
    let rest = text[pos + "apslint:".len()..].trim_start();
    let Some(body) = rest.strip_prefix("allow") else {
        return Err("expected `allow(<rule, …>)` after `apslint:`".to_string());
    };
    let body = body.trim_start();
    let Some(open) = body.strip_prefix('(') else {
        return Err("expected `(` after `apslint: allow`".to_string());
    };
    let Some(close) = open.find(')') else {
        return Err("unclosed `(` in `apslint: allow(...)`".to_string());
    };
    let rules: Vec<String> = open[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Err("`apslint: allow()` lists no rules".to_string());
    }
    let after = open[close + 1..].trim_start();
    let reason = after
        .strip_prefix("--")
        .map(|r| r.trim_end_matches("*/").trim().to_string())
        .unwrap_or_default();
    if reason.is_empty() {
        return Err(
            "waiver has no reason: write `apslint: allow(<rule>) -- <why this is sound>`"
                .to_string(),
        );
    }
    Ok(Some(Waiver { rules, reason, line }))
}

// ---------------------------------------------------------------------
// File analysis
// ---------------------------------------------------------------------

/// Build the per-file context: strip comments into waivers, compute
/// function spans and test ranges. Malformed waivers are pushed onto
/// `diags` directly.
fn build_ctx<'a>(
    path: &'a str,
    toks: Vec<Tok>,
    cfg: &'a Config,
    diags: &mut Vec<Diagnostic>,
) -> FileCtx<'a> {
    let mut code: Vec<Tok> = Vec::with_capacity(toks.len());
    let mut waivers: Vec<Waiver> = Vec::new();
    for t in toks {
        match &t.kind {
            // Doc comments (`///`, `//!`, `/**`, `/*!`) are prose about
            // the code, not directives — only plain comments can carry
            // waivers. This also keeps documentation that *shows* the
            // waiver syntax (like this module's) from being parsed.
            TokKind::Comment(text)
                if text.starts_with("///")
                    || text.starts_with("//!")
                    || text.starts_with("/**")
                    || text.starts_with("/*!") => {}
            TokKind::Comment(text) => match parse_waiver(text, t.line) {
                Ok(Some(w)) => {
                    for r in &w.rules {
                        if !RULES.contains(&r.as_str()) {
                            diags.push(Diagnostic {
                                rule: "waiver_syntax",
                                severity: Severity::Warning,
                                file: path.to_string(),
                                line: t.line,
                                message: format!(
                                    "waiver names unknown rule `{r}` (known: {})",
                                    RULES.join(", ")
                                ),
                                waived: None,
                            });
                        }
                    }
                    waivers.push(w);
                }
                Ok(None) => {}
                Err(msg) => diags.push(Diagnostic {
                    rule: "waiver_syntax",
                    severity: Severity::Error,
                    file: path.to_string(),
                    line: t.line,
                    message: msg,
                    waived: None,
                }),
            },
            _ => code.push(t),
        }
    }

    // Single pass for fn spans and test ranges.
    let mut fn_spans: Vec<FnSpan> = Vec::new();
    let mut test_ranges: Vec<Range<usize>> = Vec::new();
    let mut depth = 0i64;
    let mut bracket_depth = 0i64; // ( ) and [ ] — guards `;` in types
    let mut pending_fn: Option<(String, u32, usize)> = None;
    let mut pending_test = false;
    let mut fn_stack: Vec<(String, u32, usize, i64, usize)> = Vec::new();
    let mut test_stack: Vec<(i64, usize)> = Vec::new();

    for i in 0..code.len() {
        match &code[i].kind {
            TokKind::Ident(s) if s == "fn" => {
                if let Some(name) = code.get(i + 1).and_then(|t| t.ident()) {
                    pending_fn = Some((name.to_string(), code[i].line, i));
                }
            }
            TokKind::Ident(s) if s == "mod" => {
                if code.get(i + 1).and_then(|t| t.ident()) == Some("tests") {
                    pending_test = true;
                }
            }
            TokKind::Punct('#') => {
                // `#[test]`, `#[cfg(test)]`
                let id = |k: usize| code.get(i + k).and_then(|t| t.ident());
                let p = |k: usize, c: char| code.get(i + k).is_some_and(|t| t.is_punct(c));
                if p(1, '[')
                    && ((id(2) == Some("test") && p(3, ']'))
                        || (id(2) == Some("cfg")
                            && p(3, '(')
                            && id(4) == Some("test")
                            && p(5, ')')
                            && p(6, ']')))
                {
                    pending_test = true;
                }
            }
            TokKind::Punct('(') | TokKind::Punct('[') => bracket_depth += 1,
            TokKind::Punct(')') | TokKind::Punct(']') => bracket_depth -= 1,
            TokKind::Punct('{') => {
                if let Some((name, line, sig)) = pending_fn.take() {
                    fn_stack.push((name, line, sig, depth, i));
                }
                if pending_test {
                    test_stack.push((depth, i));
                    pending_test = false;
                }
                depth += 1;
            }
            TokKind::Punct('}') => {
                depth -= 1;
                if fn_stack.last().is_some_and(|f| f.3 == depth) {
                    let (name, line, sig, _, open) =
                        fn_stack.pop().expect("checked non-empty");
                    fn_spans.push(FnSpan { name, line, sig, body: open + 1..i });
                }
                if test_stack.last().is_some_and(|t| t.0 == depth) {
                    let (_, open) = test_stack.pop().expect("checked non-empty");
                    test_ranges.push(open..i + 1);
                }
            }
            TokKind::Punct(';') if bracket_depth == 0 => {
                // trait method declarations / attributed items end here
                pending_fn = None;
                pending_test = false;
            }
            _ => {}
        }
    }
    // Unclosed spans at EOF (unbalanced file): close them at the end so
    // the rules still see the tokens.
    while let Some((name, line, sig, _, open)) = fn_stack.pop() {
        fn_spans.push(FnSpan { name, line, sig, body: open + 1..code.len() });
    }
    while let Some((_, open)) = test_stack.pop() {
        test_ranges.push(open..code.len());
    }

    FileCtx { path, code, fn_spans, test_ranges, waivers, cfg }
}

/// Lint one source string. `path` is used for hot-path matching and in
/// diagnostics; it should be the repo-relative path with `/` separators.
pub fn check_source(path: &str, src: &str, cfg: &Config) -> Vec<Diagnostic> {
    let mut diags: Vec<Diagnostic> = Vec::new();
    let toks = lexer::lex(src);
    let ctx = build_ctx(path, toks, cfg, &mut diags);

    rules::alloc_in_hot_path(&ctx, &mut diags);
    rules::wire_honesty(&ctx, &mut diags);
    rules::lossy_cast(&ctx, &mut diags);
    rules::unsafe_code(&ctx, &mut diags);
    rules::panic_in_hot_path(&ctx, &mut diags);
    rules::nondeterminism(&ctx, &mut diags);

    // Apply waivers: a waiver covers its own line and the next line, so
    // both trailing comments and own-line comments directly above work.
    for d in &mut diags {
        if d.rule == "waiver_syntax" {
            continue; // the meta-rule cannot be waived
        }
        for w in &ctx.waivers {
            if (d.line == w.line || d.line == w.line + 1)
                && w.rules.iter().any(|r| r == d.rule)
            {
                d.waived = Some(w.reason.clone());
                break;
            }
        }
    }

    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

// ---------------------------------------------------------------------
// Repo walk + report
// ---------------------------------------------------------------------

/// Aggregated result of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    /// Unwaived errors — the count that fails the run.
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.is_fatal()).count()
    }
    pub fn warnings(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warning && d.waived.is_none())
            .count()
    }
    pub fn waived(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.waived.is_some()).count()
    }
    pub fn ok(&self) -> bool {
        self.errors() == 0
    }

    /// Machine-readable report (see EXPERIMENTS.md "Static analysis").
    pub fn to_json(&self) -> Json {
        let mut per_rule: BTreeMap<String, Json> = BTreeMap::new();
        for rule in RULES.iter().chain(std::iter::once(&"waiver_syntax")) {
            let fired =
                self.diagnostics.iter().filter(|d| d.rule == *rule).count();
            if fired > 0 {
                per_rule.insert(rule.to_string(), Json::Num(fired as f64));
            }
        }
        Json::obj(vec![
            ("tool", Json::Str("apslint".to_string())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            (
                "summary",
                Json::obj(vec![
                    ("errors", Json::Num(self.errors() as f64)),
                    ("warnings", Json::Num(self.warnings() as f64)),
                    ("waived", Json::Num(self.waived() as f64)),
                ]),
            ),
            ("per_rule", Json::Obj(per_rule)),
            (
                "diagnostics",
                Json::Arr(
                    self.diagnostics
                        .iter()
                        .map(|d| {
                            let mut fields = vec![
                                ("rule", Json::Str(d.rule.to_string())),
                                ("severity", Json::Str(d.severity.as_str().to_string())),
                                ("file", Json::Str(d.file.clone())),
                                ("line", Json::Num(d.line as f64)),
                                ("message", Json::Str(d.message.clone())),
                                ("waived", Json::Bool(d.waived.is_some())),
                            ];
                            if let Some(r) = &d.waived {
                                fields.push(("waiver_reason", Json::Str(r.clone())));
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Recursively collect `.rs` files under `dir`, skipping `vendor` and
/// `target` trees. Paths come back sorted for deterministic reports.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "vendor" || name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The scan roots, relative to the repo root.
pub const SCAN_ROOTS: &[&str] = &["rust/src", "benches", "examples"];

/// Lint the repository at `root` with `cfg`.
pub fn run(root: &Path, cfg: &Config) -> anyhow::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    let mut report = Report::default();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        report.diagnostics.extend(check_source(&rel, &src, cfg));
        report.files_scanned += 1;
    }
    report
        .diagnostics
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

//! Ring all-reduce with faithful reduction order (Patarasuk & Yuan [22]).
//!
//! The tensor is split into `p` chunks. During reduce-scatter, chunk `c`
//! is accumulated sequentially around the ring starting at worker
//! `(c+1) % p`: worker `(c+1)` sends its chunk to `(c+2)`, which adds its
//! own and forwards, …, until the fully reduced chunk lands on worker `c`.
//! Every addition happens in the wire precision, so an element's final
//! value is the left fold
//!
//! `Q(…Q(Q(g_{c+1} + g_{c+2}) + g_{c+3})… + g_c)`
//!
//! — the last addition combines one local gradient with a partial sum of
//! `p-1` others, the paper's §4.2 round-off hazard. The all-gather phase
//! moves finished chunks without further arithmetic.

use super::{fold_step, ReduceOptions, ReduceStats};
use crate::sync::wire::{PackScratch, PackedWire};
use crate::sync::{LayerCtx, SyncStrategy};
use crate::util::par;

/// Run ring all-reduce over per-worker contributions, allocating the
/// output (wrapper over [`all_reduce_into`]).
pub fn all_reduce(contribs: &[Vec<f32>], opts: ReduceOptions) -> (Vec<f32>, ReduceStats) {
    let mut out = vec![0.0f32; contribs[0].len()];
    let stats = all_reduce_into(contribs, &mut out, opts);
    (out, stats)
}

/// Ring all-reduce into a caller-provided buffer — the allocation-free
/// variant behind [`crate::collectives::Collective`]. Only O(p) pointer
/// bookkeeping is allocated per call; Kahan compensation lives in a
/// stack-resident `FOLD_BLOCK`-element block inside the cache-blocked
/// fold, so `opts.kahan` allocates nothing either (the ROADMAP-tracked
/// per-call compensation vectors are gone).
pub fn all_reduce_into(
    contribs: &[Vec<f32>],
    out: &mut [f32],
    opts: ReduceOptions,
) -> ReduceStats {
    let p = contribs.len();
    // apslint: allow(panic_in_hot_path) -- the first contribution defines the layer shape; ragged input panics are the documented collective contract
    let n = contribs[0].len();
    assert_eq!(out.len(), n);

    // Chunk boundaries: chunk c covers [bounds[c], bounds[c+1]).
    // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping, not element storage; within the steady-state budget pinned by rust/tests/session_alloc.rs
    let bounds: Vec<usize> = (0..=p).map(|c| c * n / p).collect();

    // Each chunk's fold is independent → parallelize over chunks.
    // Manual split (chunks are uneven when p ∤ n).
    // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping, not element storage; within the steady-state budget pinned by rust/tests/session_alloc.rs
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(p);
    let mut rest = out;
    for c in 0..p {
        let len = bounds[c + 1] - bounds[c];
        let (head, tail) = rest.split_at_mut(len);
        slices.push(head);
        rest = tail;
    }

    let process = |c: usize, chunk: &mut [f32]| {
        let lo = bounds[c];
        if chunk.is_empty() {
            return;
        }
        // Fold order: start at worker (c+1) % p, wrap around the ring.
        let start = (c + 1) % p;
        // Cache-blocked fold: per-element arithmetic (and hence results)
        // is unchanged, but the Kahan compensation lane shrinks to one
        // stack block instead of a heap vector per call.
        let mut comp = [0.0f32; super::FOLD_BLOCK];
        let mut b0 = 0usize;
        while b0 < chunk.len() {
            let b1 = (b0 + super::FOLD_BLOCK).min(chunk.len());
            let blk = &mut chunk[b0..b1];
            blk.copy_from_slice(&contribs[start][lo + b0..lo + b1]);
            if opts.kahan {
                let comp = &mut comp[..blk.len()];
                comp.fill(0.0);
                for s in 1..p {
                    let w = (start + s) % p;
                    let src = &contribs[w][lo + b0..lo + b1];
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut comp[i], src[i], opts.fmt, opts.mode, true);
                    }
                }
            } else {
                let mut dummy = 0.0f32;
                for s in 1..p {
                    let w = (start + s) % p;
                    let src = &contribs[w][lo + b0..lo + b1];
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut dummy, src[i], opts.fmt, opts.mode, false);
                    }
                }
            }
            b0 = b1;
        }
    };

    // Bounded thread pool: round-robin chunks over available cores; run
    // sequentially when the tensor is small (thread spawn not worth it).
    // apslint: allow(nondeterminism) -- thread count only selects chunk scheduling; each chunk's fold order is fixed by the ring, so results are bit-identical for any thread count
    let nthreads = par::num_threads().min(p).max(1);
    if n * p < par::PAR_THRESHOLD || nthreads == 1 {
        for (c, chunk) in slices.into_iter().enumerate() {
            process(c, chunk);
        }
    } else {
        let mut buckets: Vec<Vec<(usize, &mut [f32])>> =
            // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping (empty Vec::new never allocates); within the session_alloc.rs budget
            (0..nthreads).map(|_| Vec::new()).collect();
        for (c, sl) in slices.into_iter().enumerate() {
            buckets[c % nthreads].push((c, sl));
        }
        std::thread::scope(|s| {
            for bucket in buckets {
                let process = &process;
                s.spawn(move || {
                    for (c, chunk) in bucket {
                        process(c, chunk);
                    }
                });
            }
        });
    }

    // Traffic: reduce-scatter + all-gather each move (p-1)/p of the tensor
    // per worker; 2 bytes/elt is not assumed — stats are in *elements*
    // scaled by the wire width in bytes.
    let elt_bytes = wire_bytes(opts);
    let moved = 2 * (p as u64 - 1) * (n as u64) / p as u64;
    ReduceStats {
        bytes_per_worker: moved * elt_bytes as u64,
        steps: 2 * (p - 1),
    }
}

/// Ring all-reduce over **packed** worker contributions: the reduction
/// consumes each worker's [`PackedWire`] bytes in cache-blocked chunks
/// (unpack-block → fold), never materializing a dense f32 copy of any
/// contribution. Fold order and operand precision are exactly those of
/// [`all_reduce_into`], so with an exact `decode_packed` the result is
/// bit-identical to the simulated-f32 path — including `opts.kahan`,
/// whose compensation block lives on the stack here too.
///
/// `unpack` is caller-owned block scratch (the session's
/// [`crate::sync::PackScratch::chunk`]); it grows to `FOLD_BLOCK`
/// elements once and stays.
///
/// Runs single-threaded; codecs whose `decode_packed` is `Sync`-safe
/// opt into [`all_reduce_packed_into_par`] via
/// [`SyncStrategy::parallel_decoder`], which splits the same fold over
/// chunk boundaries (bit-identical results — each chunk's fold chain is
/// untouched).
pub fn all_reduce_packed_into(
    packed: &[PackedWire],
    strategy: &dyn SyncStrategy,
    ctx: &LayerCtx,
    out: &mut [f32],
    opts: ReduceOptions,
    unpack: &mut Vec<f32>,
) -> ReduceStats {
    let p = packed.len();
    let n = out.len();
    debug_assert!(p >= 2, "single-worker reduces are handled by the caller");
    // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping, not element storage; within the steady-state budget pinned by rust/tests/session_alloc.rs
    let bounds: Vec<usize> = (0..=p).map(|c| c * n / p).collect();
    unpack.clear();
    unpack.resize(super::FOLD_BLOCK, 0.0);
    let mut comp = [0.0f32; super::FOLD_BLOCK];
    for c in 0..p {
        let (lo, hi) = (bounds[c], bounds[c + 1]);
        if lo == hi {
            continue;
        }
        let start = (c + 1) % p;
        let mut b0 = lo;
        while b0 < hi {
            let b1 = (b0 + super::FOLD_BLOCK).min(hi);
            let blk = &mut out[b0..b1];
            strategy.decode_packed(&packed[start], ctx, b0..b1, blk);
            let seg = &mut unpack[..b1 - b0];
            if opts.kahan {
                let comp = &mut comp[..blk.len()];
                comp.fill(0.0);
                for s in 1..p {
                    let w = (start + s) % p;
                    strategy.decode_packed(&packed[w], ctx, b0..b1, seg);
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut comp[i], seg[i], opts.fmt, opts.mode, true);
                    }
                }
            } else {
                let mut dummy = 0.0f32;
                for s in 1..p {
                    let w = (start + s) % p;
                    strategy.decode_packed(&packed[w], ctx, b0..b1, seg);
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut dummy, seg[i], opts.fmt, opts.mode, false);
                    }
                }
            }
            b0 = b1;
        }
    }
    // Identical traffic accounting to the dense path: `SyncReport`s must
    // stay bit-identical across wire modes (payload_bytes deliberately
    // keeps the dense simulation figure; the packed figure is
    // `SyncReport::wire` / `SyncSession::wire_moved`).
    let elt_bytes = wire_bytes(opts);
    let moved = 2 * (p as u64 - 1) * (n as u64) / p as u64;
    ReduceStats { bytes_per_worker: moved * elt_bytes as u64, steps: 2 * (p - 1) }
}

/// Parallel twin of [`all_reduce_packed_into`] for `Sync`-safe decoders
/// (obtained through [`SyncStrategy::parallel_decoder`]): the `p` ring
/// chunks are distributed over worker threads as contiguous index runs
/// by the fixed-split schedule of
/// [`par::par_chunks_mut_with_scratch`], each thread folding its chunks
/// with a private unpack block ([`PackScratch::chunks`], session-owned,
/// so the zero-steady-state-allocation pin holds). Chunk boundaries only
/// partition the iteration space — every element's fold chain (start
/// worker, order, operand precision, Kahan compensation) is exactly that
/// of the single-threaded fold, so results are bit-identical for any
/// thread count; `rust/tests/packed_parallel.rs` pins this at 1/2/4/8
/// threads for every shipped codec.
///
/// Thread count: `scratch.max_threads` (`0` = auto by tensor size and
/// host parallelism; explicit values are honored exactly — the test
/// hook). One thread delegates to the single-threaded fold.
pub fn all_reduce_packed_into_par(
    packed: &[PackedWire],
    strategy: &(dyn SyncStrategy + Sync),
    ctx: &LayerCtx,
    out: &mut [f32],
    opts: ReduceOptions,
    scratch: &mut PackScratch,
) -> ReduceStats {
    let p = packed.len();
    let n = out.len();
    debug_assert!(p >= 2, "single-worker reduces are handled by the caller");
    let threads = match scratch.max_threads {
        0 if n * p < par::PAR_THRESHOLD => 1,
        // apslint: allow(nondeterminism) -- thread count only selects how ring chunks are grouped onto threads; each chunk's fold chain is fixed, so results are bit-identical for any count (pinned by the rust/tests/packed_parallel.rs schedule-permutation suite)
        0 => par::num_threads().min(p).max(1),
        k => k.min(p),
    };
    if threads == 1 {
        return all_reduce_packed_into(packed, strategy, ctx, out, opts, &mut scratch.chunk);
    }

    // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping, not element storage; within the steady-state budget pinned by rust/tests/session_alloc.rs
    let bounds: Vec<usize> = (0..=p).map(|c| c * n / p).collect();
    // apslint: allow(alloc_in_hot_path) -- O(p) pointer bookkeeping, not element storage; within the steady-state budget pinned by rust/tests/session_alloc.rs
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(p);
    let mut rest = out;
    for c in 0..p {
        let (head, tail) = rest.split_at_mut(bounds[c + 1] - bounds[c]);
        slices.push(head);
        rest = tail;
    }
    if scratch.chunks.len() < threads {
        // apslint: allow(alloc_in_hot_path) -- per-thread unpack blocks grow on the first parallel fold only; steady state reuses them, as pinned by rust/tests/session_alloc.rs
        scratch.chunks.resize_with(threads, Vec::new);
    }

    par::par_chunks_mut_with_scratch(
        &mut slices,
        &mut scratch.chunks[..threads],
        1,
        threads,
        |c0, chunks, unpack| {
            unpack.clear();
            // apslint: allow(alloc_in_hot_path) -- grows each thread's unpack block to FOLD_BLOCK on the first parallel fold; steady state reuses it, as pinned by rust/tests/session_alloc.rs
            unpack.resize(super::FOLD_BLOCK, 0.0);
            let mut comp = [0.0f32; super::FOLD_BLOCK];
            for (k, chunk) in chunks.iter_mut().enumerate() {
                let c = c0 + k;
                let lo = bounds[c];
                if chunk.is_empty() {
                    continue;
                }
                // Exactly the single-threaded chunk fold.
                let start = (c + 1) % p;
                let mut b0 = 0usize;
                while b0 < chunk.len() {
                    let b1 = (b0 + super::FOLD_BLOCK).min(chunk.len());
                    let blk = &mut chunk[b0..b1];
                    strategy.decode_packed(&packed[start], ctx, lo + b0..lo + b1, blk);
                    let seg = &mut unpack[..b1 - b0];
                    if opts.kahan {
                        let comp = &mut comp[..blk.len()];
                        comp.fill(0.0);
                        for s in 1..p {
                            let w = (start + s) % p;
                            strategy.decode_packed(&packed[w], ctx, lo + b0..lo + b1, seg);
                            for i in 0..blk.len() {
                                fold_step(
                                    &mut blk[i],
                                    &mut comp[i],
                                    seg[i],
                                    opts.fmt,
                                    opts.mode,
                                    true,
                                );
                            }
                        }
                    } else {
                        let mut dummy = 0.0f32;
                        for s in 1..p {
                            let w = (start + s) % p;
                            strategy.decode_packed(&packed[w], ctx, lo + b0..lo + b1, seg);
                            for i in 0..blk.len() {
                                fold_step(
                                    &mut blk[i],
                                    &mut dummy,
                                    seg[i],
                                    opts.fmt,
                                    opts.mode,
                                    false,
                                );
                            }
                        }
                    }
                    b0 = b1;
                }
            }
        },
    );

    let elt_bytes = wire_bytes(opts);
    let moved = 2 * (p as u64 - 1) * (n as u64) / p as u64;
    ReduceStats { bytes_per_worker: moved * elt_bytes as u64, steps: 2 * (p - 1) }
}

/// Width of one element on the wire, rounded up to whole bytes (the paper
/// packs 8-bit formats into single bytes; FP32 is 4).
pub(crate) fn wire_bytes(opts: ReduceOptions) -> u32 {
    opts.fmt.total_bits().div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::{FpFormat, Rounding};

    #[test]
    fn ring_fold_order_is_rotated() {
        // With p=4 and a format so narrow that only the first operand
        // survives (adding small to big is absorbed), the chunk result
        // reveals which worker started the fold.
        let p = 4;
        let n = 4; // one element per chunk
        let fmt = FpFormat::new(5, 0); // 0 mantissa bits: 64+1 → 64
        let mut contribs = vec![vec![0.0f32; n]; p];
        for c in 0..n {
            // worker (c+1)%p holds 64, everyone else holds 1.
            for w in 0..p {
                contribs[w][c] = if w == (c + 1) % p { 64.0 } else { 1.0 };
            }
        }
        let opts = ReduceOptions { fmt, mode: Rounding::NearestEven, kahan: false };
        let (out, _) = all_reduce(&contribs, opts);
        // Start value 64 absorbs all the 1s → exactly 64 everywhere.
        assert_eq!(out, vec![64.0; n]);
    }

    #[test]
    fn uneven_chunks() {
        let p = 3;
        let n = 10; // 10 = 3+3+4-ish split
        let contribs: Vec<Vec<f32>> = (0..p).map(|w| vec![w as f32 + 1.0; n]).collect();
        let opts = ReduceOptions::fp32();
        let (out, stats) = all_reduce(&contribs, opts);
        assert_eq!(out, vec![6.0; n]);
        assert_eq!(stats.steps, 4);
    }

    #[test]
    fn kahan_reduces_ring_roundoff() {
        let p = 64;
        let n = 16;
        // worker 0 has a big value, the rest small ones that would be
        // absorbed one-by-one without compensation.
        let contribs: Vec<Vec<f32>> = (0..p)
            .map(|w| vec![if w == 0 { 256.0 } else { 1.0 }; n])
            .collect();
        let fmt = FpFormat::E5M2;
        let exact = 256.0 + (p as f32 - 1.0);
        let naive = all_reduce(
            &contribs,
            ReduceOptions { fmt, mode: Rounding::NearestEven, kahan: false },
        )
        .0;
        let kahan = all_reduce(
            &contribs,
            ReduceOptions { fmt, mode: Rounding::NearestEven, kahan: true },
        )
        .0;
        let err = |v: &Vec<f32>| v.iter().map(|x| (x - exact).abs()).sum::<f32>();
        assert!(err(&kahan) <= err(&naive), "kahan={kahan:?} naive={naive:?}");
    }
}

//! Hierarchical (grouped) all-reduce (Jia et al. [14]; paper §4.2).
//!
//! Workers are partitioned into groups of `k` consecutive ranks; the first
//! rank of each group is the *master*. Three phases:
//!
//! 1. **intra-group reduce** — each worker sends its gradient to the
//!    master, which folds them in rank order (`k`-term sequential fold in
//!    the wire precision);
//! 2. **inter-group ring all-reduce** — the `p/k` masters run a ring
//!    all-reduce over the partial sums (reusing [`super::ring`], so the
//!    rotated fold order is preserved);
//! 3. **broadcast** — masters broadcast the result (no arithmetic).
//!
//! Compared to a flat ring over `p` workers, the worst large-and-small
//! addition shrinks from `(p-1)×` to `(k-1)×` locally and `(p/k-1)×`
//! across masters — the mechanism behind Tables 8 and 9.

use super::{fold_step, ring, ReduceOptions, ReduceStats};
use crate::sync::wire::{PackScratch, PackedWire};
use crate::sync::{LayerCtx, SyncStrategy};
use crate::util::par;

/// Reusable scratch for [`all_reduce_with_scratch`]: the per-group
/// partial-sum buffers that the masters fold into. Owned by the caller
/// (in practice [`super::HierarchicalCollective`]) so steady-state
/// reductions reallocate nothing — each buffer grows to the largest
/// tensor seen and then stays.
#[derive(Clone, Debug, Default)]
pub struct HierScratch {
    partials: Vec<Vec<f32>>,
}

/// Run hierarchical all-reduce with groups of `group_size`, allocating
/// the output (wrapper over [`all_reduce_into`]).
pub fn all_reduce(
    contribs: &[Vec<f32>],
    group_size: usize,
    opts: ReduceOptions,
) -> (Vec<f32>, ReduceStats) {
    let mut out = vec![0.0f32; contribs[0].len()];
    let stats = all_reduce_into(contribs, group_size, &mut out, opts);
    (out, stats)
}

/// Hierarchical all-reduce into a caller-provided buffer with throwaway
/// scratch (one fresh `n`-element vector per group). Hot paths should
/// hold a [`HierScratch`] and call [`all_reduce_with_scratch`] instead.
pub fn all_reduce_into(
    contribs: &[Vec<f32>],
    group_size: usize,
    out: &mut [f32],
    opts: ReduceOptions,
) -> ReduceStats {
    let mut scratch = HierScratch::default();
    all_reduce_with_scratch(contribs, group_size, out, opts, &mut scratch)
}

/// Hierarchical all-reduce into a caller-provided buffer, reusing
/// `scratch` for the per-group partial sums. With a warm scratch nothing
/// is allocated per call: the Kahan compensation lane (formerly a fresh
/// `n`-element vector per group per call, the ROADMAP-tracked leak) now
/// lives in a stack-resident `FOLD_BLOCK`-element block inside the
/// cache-blocked fold.
pub fn all_reduce_with_scratch(
    contribs: &[Vec<f32>],
    group_size: usize,
    out: &mut [f32],
    opts: ReduceOptions,
    scratch: &mut HierScratch,
) -> ReduceStats {
    let p = contribs.len();
    // apslint: allow(panic_in_hot_path) -- the first contribution defines the layer shape; ragged input panics are the documented collective contract
    let n = contribs[0].len();
    assert!(group_size >= 1, "group size must be positive");
    assert!(
        p % group_size == 0,
        "world size {p} not divisible by group size {group_size}"
    );
    let num_groups = p / group_size;

    // Phase 1: intra-group fold at each master, in rank order (parallel
    // across groups — they are independent, each owning one scratch
    // partial). Chunked so small tensors stay on one thread. Blocking the
    // element loop changes memory-access order only, never the
    // per-element fold sequence, so results stay bit-identical.
    // apslint: allow(alloc_in_hot_path) -- grows only on topology change (empty Vec::new never allocates); steady state reuses the scratch, as pinned by rust/tests/session_alloc.rs
    scratch.partials.resize_with(num_groups, Vec::new);
    let groups_per_chunk = (par::PAR_THRESHOLD / (n * group_size).max(1)).max(1);
    par::par_chunks_mut(&mut scratch.partials, groups_per_chunk, |g0, chunk| {
        for (gi, acc) in chunk.iter_mut().enumerate() {
            let base = (g0 + gi) * group_size;
            acc.clear();
            acc.extend_from_slice(&contribs[base]);
            let mut comp = [0.0f32; super::FOLD_BLOCK];
            let mut b0 = 0usize;
            while b0 < n {
                let b1 = (b0 + super::FOLD_BLOCK).min(n);
                if opts.kahan {
                    let comp = &mut comp[..b1 - b0];
                    comp.fill(0.0);
                    for r in 1..group_size {
                        let src = &contribs[base + r][b0..b1];
                        let blk = &mut acc[b0..b1];
                        for i in 0..blk.len() {
                            fold_step(&mut blk[i], &mut comp[i], src[i], opts.fmt, opts.mode, true);
                        }
                    }
                } else {
                    let mut dummy = 0.0f32;
                    for r in 1..group_size {
                        let src = &contribs[base + r][b0..b1];
                        let blk = &mut acc[b0..b1];
                        for i in 0..blk.len() {
                            fold_step(
                                &mut blk[i],
                                &mut dummy,
                                src[i],
                                opts.fmt,
                                opts.mode,
                                false,
                            );
                        }
                    }
                }
                b0 = b1;
            }
        }
    });

    // Phase 2: ring all-reduce across masters.
    let ring_stats = if num_groups > 1 {
        ring::all_reduce_into(&scratch.partials, out, opts)
    } else {
        // apslint: allow(panic_in_hot_path) -- num_groups >= 1 is guaranteed by the divisibility assert above, so partials[0] exists
        out.copy_from_slice(&scratch.partials[0]);
        ReduceStats::default()
    };

    // Phase 3: broadcast (pure data movement).
    let elt_bytes = ring::wire_bytes(opts) as u64;
    // Per-worker wire traffic: a non-master sends n elements up and
    // receives n back; a master receives (k-1)·n, runs the ring, sends
    // (k-1)·n down. Report the master's (worst-case) traffic.
    let master_bytes =
        2 * (group_size as u64 - 1) * n as u64 * elt_bytes + ring_stats.bytes_per_worker;
    ReduceStats {
        bytes_per_worker: master_bytes,
        steps: 4 * (group_size - 1) + 2 * (num_groups.saturating_sub(1)),
    }
}

/// Hierarchical all-reduce over **packed** worker contributions: masters
/// fold their group's [`PackedWire`] segments in cache-blocked chunks
/// (unpack-block → fold) into the reusable per-group partials, then the
/// masters' dense partials run the standard inter-group ring. Per-element
/// fold order and precision match [`all_reduce_with_scratch`] exactly, so
/// with an exact `decode_packed` the result is bit-identical to the
/// simulated-f32 path. No repacking between phases: the intra-group
/// partials feed the ring directly, as in the dense path.
///
/// `unpack` is caller-owned block scratch ([`crate::sync::PackScratch`]).
/// Single-threaded, like [`ring::all_reduce_packed_into`]; `Sync`-safe
/// decoders take [`all_reduce_packed_with_scratch_par`] instead.
#[allow(clippy::too_many_arguments)] // mirrors the dense signature + (strategy, ctx, unpack)
pub fn all_reduce_packed_with_scratch(
    packed: &[PackedWire],
    group_size: usize,
    strategy: &dyn SyncStrategy,
    ctx: &LayerCtx,
    out: &mut [f32],
    opts: ReduceOptions,
    scratch: &mut HierScratch,
    unpack: &mut Vec<f32>,
) -> ReduceStats {
    let p = packed.len();
    let n = out.len();
    assert!(group_size >= 1, "group size must be positive");
    assert!(
        p % group_size == 0,
        "world size {p} not divisible by group size {group_size}"
    );
    let num_groups = p / group_size;

    // apslint: allow(alloc_in_hot_path) -- grows only on topology change (empty Vec::new never allocates); steady state reuses the scratch, as pinned by rust/tests/session_alloc.rs
    scratch.partials.resize_with(num_groups, Vec::new);
    unpack.clear();
    unpack.resize(super::FOLD_BLOCK, 0.0);
    let mut comp = [0.0f32; super::FOLD_BLOCK];
    for (g, acc) in scratch.partials.iter_mut().enumerate() {
        let base = g * group_size;
        acc.clear();
        acc.resize(n, 0.0);
        let mut b0 = 0usize;
        while b0 < n {
            let b1 = (b0 + super::FOLD_BLOCK).min(n);
            let blk = &mut acc[b0..b1];
            strategy.decode_packed(&packed[base], ctx, b0..b1, blk);
            let seg = &mut unpack[..b1 - b0];
            if opts.kahan {
                let comp = &mut comp[..blk.len()];
                comp.fill(0.0);
                for r in 1..group_size {
                    strategy.decode_packed(&packed[base + r], ctx, b0..b1, seg);
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut comp[i], seg[i], opts.fmt, opts.mode, true);
                    }
                }
            } else {
                let mut dummy = 0.0f32;
                for r in 1..group_size {
                    strategy.decode_packed(&packed[base + r], ctx, b0..b1, seg);
                    for i in 0..blk.len() {
                        fold_step(&mut blk[i], &mut dummy, seg[i], opts.fmt, opts.mode, false);
                    }
                }
            }
            b0 = b1;
        }
    }

    // Phase 2: ring all-reduce across the dense master partials — the
    // same code path the simulated wire takes.
    let ring_stats = if num_groups > 1 {
        ring::all_reduce_into(&scratch.partials, out, opts)
    } else {
        // apslint: allow(panic_in_hot_path) -- num_groups >= 1 is guaranteed by the divisibility assert above, so partials[0] exists
        out.copy_from_slice(&scratch.partials[0]);
        ReduceStats::default()
    };

    // Identical traffic accounting to the dense path (reports must stay
    // bit-identical across wire modes).
    let elt_bytes = ring::wire_bytes(opts) as u64;
    let master_bytes =
        2 * (group_size as u64 - 1) * n as u64 * elt_bytes + ring_stats.bytes_per_worker;
    ReduceStats {
        bytes_per_worker: master_bytes,
        steps: 4 * (group_size - 1) + 2 * (num_groups.saturating_sub(1)),
    }
}

/// Parallel twin of [`all_reduce_packed_with_scratch`] for `Sync`-safe
/// decoders (obtained through [`SyncStrategy::parallel_decoder`]): phase
/// 1's per-group master folds are distributed over worker threads as
/// contiguous group runs by the fixed-split schedule of
/// [`par::par_chunks_mut_with_scratch`], each thread folding its groups
/// with a private unpack block ([`PackScratch::chunks`], session-owned).
/// A group's whole rank-order fold chain stays on one thread, so results
/// are bit-identical to the single-threaded fold for any thread count
/// (`rust/tests/packed_parallel.rs` pins 1/2/4/8). Phase 2 (the masters'
/// dense ring) is shared with the single-threaded path unchanged.
///
/// Thread count: `pack.max_threads` (`0` = auto by tensor size and host
/// parallelism; explicit values are honored exactly — the test hook).
/// One thread delegates to the single-threaded fold.
#[allow(clippy::too_many_arguments)] // mirrors the single-threaded signature with PackScratch in place of the raw unpack block
pub fn all_reduce_packed_with_scratch_par(
    packed: &[PackedWire],
    group_size: usize,
    strategy: &(dyn SyncStrategy + Sync),
    ctx: &LayerCtx,
    out: &mut [f32],
    opts: ReduceOptions,
    scratch: &mut HierScratch,
    pack: &mut PackScratch,
) -> ReduceStats {
    let p = packed.len();
    let n = out.len();
    assert!(group_size >= 1, "group size must be positive");
    assert!(
        p % group_size == 0,
        "world size {p} not divisible by group size {group_size}"
    );
    let num_groups = p / group_size;
    let threads = match pack.max_threads {
        0 if n * p < par::PAR_THRESHOLD => 1,
        // apslint: allow(nondeterminism) -- thread count only selects how groups are assigned to threads; each group's rank-order fold chain is fixed, so results are bit-identical for any count (pinned by the rust/tests/packed_parallel.rs schedule-permutation suite)
        0 => par::num_threads().min(num_groups).max(1),
        k => k.min(num_groups),
    };
    if threads == 1 {
        return all_reduce_packed_with_scratch(
            packed,
            group_size,
            strategy,
            ctx,
            out,
            opts,
            scratch,
            &mut pack.chunk,
        );
    }

    // apslint: allow(alloc_in_hot_path) -- grows only on topology change (empty Vec::new never allocates); steady state reuses the scratch, as pinned by rust/tests/session_alloc.rs
    scratch.partials.resize_with(num_groups, Vec::new);
    if pack.chunks.len() < threads {
        // apslint: allow(alloc_in_hot_path) -- per-thread unpack blocks grow on the first parallel fold only; steady state reuses them, as pinned by rust/tests/session_alloc.rs
        pack.chunks.resize_with(threads, Vec::new);
    }

    // Phase 1: per-group master folds, each group wholly on one thread.
    par::par_chunks_mut_with_scratch(
        &mut scratch.partials,
        &mut pack.chunks[..threads],
        1,
        threads,
        |g0, groups, unpack| {
            unpack.clear();
            // apslint: allow(alloc_in_hot_path) -- grows each thread's unpack block to FOLD_BLOCK on the first parallel fold; steady state reuses it, as pinned by rust/tests/session_alloc.rs
            unpack.resize(super::FOLD_BLOCK, 0.0);
            let mut comp = [0.0f32; super::FOLD_BLOCK];
            for (gi, acc) in groups.iter_mut().enumerate() {
                let base = (g0 + gi) * group_size;
                acc.clear();
                // apslint: allow(alloc_in_hot_path) -- grows a group partial to the largest tensor seen, then reuses it; steady state pinned by rust/tests/session_alloc.rs
                acc.resize(n, 0.0);
                let mut b0 = 0usize;
                while b0 < n {
                    let b1 = (b0 + super::FOLD_BLOCK).min(n);
                    let blk = &mut acc[b0..b1];
                    strategy.decode_packed(&packed[base], ctx, b0..b1, blk);
                    let seg = &mut unpack[..b1 - b0];
                    if opts.kahan {
                        let comp = &mut comp[..blk.len()];
                        comp.fill(0.0);
                        for r in 1..group_size {
                            strategy.decode_packed(&packed[base + r], ctx, b0..b1, seg);
                            for i in 0..blk.len() {
                                fold_step(
                                    &mut blk[i],
                                    &mut comp[i],
                                    seg[i],
                                    opts.fmt,
                                    opts.mode,
                                    true,
                                );
                            }
                        }
                    } else {
                        let mut dummy = 0.0f32;
                        for r in 1..group_size {
                            strategy.decode_packed(&packed[base + r], ctx, b0..b1, seg);
                            for i in 0..blk.len() {
                                fold_step(
                                    &mut blk[i],
                                    &mut dummy,
                                    seg[i],
                                    opts.fmt,
                                    opts.mode,
                                    false,
                                );
                            }
                        }
                    }
                    b0 = b1;
                }
            }
        },
    );

    // Phase 2: ring all-reduce across the dense master partials — the
    // same code path the single-threaded and simulated wires take.
    let ring_stats = if num_groups > 1 {
        ring::all_reduce_into(&scratch.partials, out, opts)
    } else {
        // apslint: allow(panic_in_hot_path) -- num_groups >= 1 is guaranteed by the divisibility assert above, so partials[0] exists
        out.copy_from_slice(&scratch.partials[0]);
        ReduceStats::default()
    };

    let elt_bytes = ring::wire_bytes(opts) as u64;
    let master_bytes =
        2 * (group_size as u64 - 1) * n as u64 * elt_bytes + ring_stats.bytes_per_worker;
    ReduceStats {
        bytes_per_worker: master_bytes,
        steps: 4 * (group_size - 1) + 2 * (num_groups.saturating_sub(1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::{avg_roundoff_error, FpFormat, Rounding};
    use crate::collectives::Topology;

    #[test]
    fn group_of_one_is_pure_ring() {
        let p = 8;
        let n = 12;
        let contribs: Vec<Vec<f32>> =
            (0..p).map(|w| (0..n).map(|i| (w + i) as f32 * 0.5).collect()).collect();
        let opts = ReduceOptions::low_precision(FpFormat::E4M3);
        let (h, _) = all_reduce(&contribs, 1, opts);
        let (r, _) = ring::all_reduce(&contribs, opts);
        assert_eq!(h, r);
    }

    #[test]
    fn single_group_is_pure_fold() {
        let p = 4;
        let contribs: Vec<Vec<f32>> = (0..p).map(|w| vec![w as f32 + 1.0; 3]).collect();
        let (out, stats) = all_reduce(&contribs, p, ReduceOptions::fp32());
        assert_eq!(out, vec![10.0; 3]);
        assert_eq!(stats.steps, 4 * (p - 1));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_group_panics() {
        let contribs = vec![vec![0.0f32; 2]; 6];
        let _ = all_reduce(&contribs, 4, ReduceOptions::fp32());
    }

    #[test]
    fn table9_shape_hierarchical_beats_ring_in_low_precision() {
        // Mixed-scale gradients across 64 workers: the hierarchical
        // reduction should show lower Eq.-5 round-off than the flat ring,
        // reproducing the *shape* of Table 9.
        let p = 64;
        let n = 256;
        let contribs: Vec<Vec<f32>> = (0..p)
            .map(|w| {
                (0..n)
                    .map(|i| {
                        let x = ((w * 2654435761 + i * 40503) % 10007) as f32 / 10007.0;
                        (x - 0.5) * (1.0 + (w % 7) as f32)
                    })
                    .collect()
            })
            .collect();
        // Exact reference in f64.
        let exact: Vec<f32> = (0..n)
            .map(|i| contribs.iter().map(|c| c[i] as f64).sum::<f64>() as f32)
            .collect();
        let opts = ReduceOptions::low_precision(FpFormat::E5M2);
        let (ring_out, _) = ring::all_reduce(&contribs, opts);
        let (hier_out, _) = all_reduce(&contribs, 8, opts);
        let ring_err = avg_roundoff_error(&exact, &ring_out);
        let hier_err = avg_roundoff_error(&exact, &hier_out);
        assert!(
            hier_err < ring_err,
            "hier={hier_err:.4} ring={ring_err:.4}"
        );
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_mixed_sizes() {
        // One scratch reused over growing and shrinking tensors must give
        // exactly what the throwaway-scratch path gives.
        let mut scratch = HierScratch::default();
        let p = 8;
        for (salt, n) in [(1usize, 40usize), (2, 12), (3, 64)] {
            let contribs: Vec<Vec<f32>> = (0..p)
                .map(|w| {
                    (0..n)
                        .map(|i| ((w * 31 + i * 7 + salt) % 13) as f32 * 0.25 - 1.5)
                        .collect()
                })
                .collect();
            let opts = ReduceOptions::low_precision(FpFormat::E5M2);
            let mut a = vec![0.0f32; n];
            let _ = all_reduce_with_scratch(&contribs, 4, &mut a, opts, &mut scratch);
            let (b, _) = all_reduce(&contribs, 4, opts);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn steps_match_topology_formula() {
        let p = 256;
        let k = 16;
        let contribs = vec![vec![1.0f32; 4]; p];
        let (_, stats) = all_reduce(&contribs, k, ReduceOptions::fp32());
        assert_eq!(stats.steps, Topology::Hierarchical { group_size: k }.steps(p));
    }
}

//! Simulated distributed collectives (paper §4.1–§4.2).
//!
//! A [`SimCluster`] stands in for the paper's 8/32/256-node GPU clusters.
//! Each simulated worker owns a real gradient tensor; all-reduce is
//! executed element-wise **in the wire precision and in the exact
//! reduction order** of the corresponding real collective:
//!
//! * [`ring`] — ring all-reduce (reduce-scatter + all-gather,
//!   Baidu/Patarasuk-Yuan): every element is a sequential fold of all `p`
//!   contributions, so the last addition combines one local gradient with
//!   an up-to-`(p-1)×` larger partial sum — the round-off hazard the paper
//!   describes in §4.2.
//! * [`hierarchical`] — grouped all-reduce (Jia et al. [14]): intra-group
//!   gather-reduce to a master (`k`-term folds), ring all-reduce across
//!   the `p/k` masters, broadcast back. Fewer large-and-small additions,
//!   hence the lower round-off error of Tables 8–9.
//!
//! Since round-off depends only on operand values, operand precision, and
//! summation order — all three reproduced here — the simulation yields
//! bit-identical results to a real cluster running the same schedule.

pub mod hierarchical;
pub mod ring;

use crate::cpd::{quantize, FpFormat, Rounding};
use crate::sync::transport::{TransportError, TransportTraffic};
use crate::sync::wire::{PackScratch, PackedWire};
use crate::sync::{LayerCtx, SyncStrategy};

/// Elements per cache block in the fold kernels (4 KiB of f32): the unit
/// the packed reduction unpacks at a time, and the size of the
/// stack-resident Kahan compensation lane (so compensated folds allocate
/// nothing — the ROADMAP-tracked per-call vectors are gone).
pub(crate) const FOLD_BLOCK: usize = 1024;

/// All-reduce topology (paper §4.2 discusses the choice).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Topology {
    /// Flat ring all-reduce over all `p` workers.
    #[default]
    Ring,
    /// Hierarchical all-reduce with groups of `group_size` workers.
    Hierarchical { group_size: usize },
    /// Parameter server: workers push gradient shards to `shards`
    /// server shards and pull the reduced result, tolerating up to
    /// `staleness` rounds of lag per worker (Downpour-style
    /// non-blocking pushes; 0 = fully synchronous).
    Ps { shards: usize, staleness: usize },
}

impl Topology {
    /// Number of communication steps (paper §4.2: ring `2(p-1)`,
    /// hierarchical `4(k-1) + 2(p/k - 1)`; parameter server: one push
    /// plus one pull, world-independent).
    pub fn steps(&self, world: usize) -> usize {
        match *self {
            Topology::Ring => 2 * (world - 1),
            Topology::Hierarchical { group_size: k } => {
                assert!(world % k == 0, "world {world} not divisible by group {k}");
                4 * (k - 1) + 2 * (world / k - 1)
            }
            Topology::Ps { .. } => 2,
        }
    }

    /// Build the [`Collective`] implementing this topology over `world`
    /// workers — the bridge from the closed enum to the open trait layer.
    /// The parameter server is built over the in-process transport here;
    /// [`crate::sync::SyncSessionBuilder`] rebuilds it over the session's
    /// configured transport.
    pub fn collective(&self, world: usize) -> Box<dyn Collective> {
        match *self {
            Topology::Ring => Box::new(RingCollective::new(world)),
            Topology::Hierarchical { group_size } => {
                Box::new(HierarchicalCollective::new(world, group_size))
            }
            Topology::Ps { shards, staleness } => {
                Box::new(crate::sync::ps::PsCollective::new(world, shards, staleness))
            }
        }
    }
}

/// A pluggable all-reduce implementation over a fixed set of simulated
/// workers — the open counterpart of the closed [`Topology`] enum.
///
/// A collective owns its world size and writes reduced results into
/// caller-provided buffers, so a [`crate::sync::SyncSession`] can drive
/// it step after step without allocating element storage. Implementors
/// must emulate the summation *order* and operand precision of the real
/// schedule they model (see the module docs): given that, results are
/// bit-identical to a real cluster running the same schedule.
pub trait Collective {
    /// Short human name (bench/report labels).
    fn name(&self) -> &'static str;
    /// Number of data-parallel workers.
    fn world_size(&self) -> usize;
    /// Latency-bound steps of one message through this collective (used
    /// for fused-message accounting).
    fn steps_per_message(&self) -> usize;
    /// Sum-reduce `contribs` (one tensor per worker) elementwise into
    /// `out`, in the wire precision and summation order of the schedule.
    fn all_reduce_sum_into(
        &self,
        contribs: &[Vec<f32>],
        out: &mut [f32],
        opts: &ReduceOptions,
    ) -> ReduceStats;
    /// Max-reduce small integer payloads into `out` — the 1-byte-per-layer
    /// exponent agreement phase (APS Algorithm 1 line 4). Max is
    /// order-insensitive, so no precision emulation is needed; all
    /// implementations account it as a ring over 1-byte entries, matching
    /// the pre-trait `SimCluster::all_reduce_max_i8`.
    fn all_reduce_max_i8_into(&self, contribs: &[Vec<i8>], out: &mut [i8]) -> ReduceStats;

    /// Sum-reduce **packed** contributions (one [`PackedWire`] per
    /// worker, decoded through `strategy.decode_packed` with `ctx`) into
    /// `out`. Must produce bit-identical results and [`ReduceStats`] to
    /// [`Collective::all_reduce_sum_into`] over the unpacked values.
    ///
    /// The default materializes dense f32 contributions into
    /// `scratch.dense` and reuses the simulated-path reduce, so
    /// third-party collectives work on the packed wire unchanged (just
    /// without the traffic win). The built-in ring and hierarchical
    /// collectives override it with cache-blocked chunked folds that
    /// never build a dense copy of a contribution.
    fn all_reduce_packed_sum_into(
        &self,
        packed: &[PackedWire],
        strategy: &dyn SyncStrategy,
        ctx: &LayerCtx,
        out: &mut [f32],
        opts: &ReduceOptions,
        scratch: &mut PackScratch,
    ) -> ReduceStats {
        // apslint: allow(alloc_in_hot_path) -- default fallback for third-party collectives only; built-ins override with non-materializing folds. Grows on first call, then reuses the scratch.
        scratch.dense.resize_with(packed.len(), Vec::new);
        for (pw, d) in packed.iter().zip(scratch.dense.iter_mut()) {
            d.clear();
            d.resize(out.len(), 0.0);
            strategy.decode_packed(pw, ctx, 0..out.len(), d);
        }
        self.all_reduce_sum_into(&scratch.dense, out, opts)
    }

    /// Take the fault recorded by the most recent reduce, if any.
    /// Collectives that own a real transport (the parameter server)
    /// record channel failures here, because the reduce methods have no
    /// error channel; `Some` means the corresponding output was zeroed —
    /// a partial fold never escapes. Default: faultless.
    fn take_fault(&self) -> Option<TransportError> {
        None
    }

    /// Measured-vs-claimed octet accounting of the collective's owned
    /// transport, when it has one (the parameter server). Default: none.
    fn transport_traffic(&self) -> Option<TransportTraffic> {
        None
    }

    /// Elastic membership: include/exclude `worker`'s future
    /// contributions (graceful join/leave with gradient re-sharding).
    /// Returns whether the collective supports membership changes.
    fn set_member_active(&self, _worker: usize, _active: bool) -> bool {
        false
    }

    /// Straggler schedule: delay `worker`'s future contributions by
    /// `rounds` logical rounds (clamped to the collective's staleness
    /// budget). Returns whether supported.
    fn set_arrival_delay(&self, _worker: usize, _rounds: usize) -> bool {
        false
    }

    /// Drop `worker`'s channel on the owned transport (fault
    /// injection). Returns whether the collective owns a transport with
    /// real channels.
    fn kill_transport_peer(&self, _worker: usize) -> bool {
        false
    }

    /// Configure the owned transport's straggler patience: per-poll
    /// read timeout (milliseconds) × tolerated consecutive timeouts.
    /// Returns whether supported.
    fn set_transport_patience(&self, _read_timeout_ms: u64, _max_timeouts: usize) -> bool {
        false
    }

    /// Delay every send on `worker`'s owned-transport channel by
    /// `delay_ms` (wall-clock straggler injection). Returns whether
    /// supported.
    fn inject_transport_delay(&self, _worker: usize, _delay_ms: u64) -> bool {
        false
    }
}

/// Shared i8 max-reduce body (values + ring traffic accounting).
fn max_i8_into(contribs: &[Vec<i8>], out: &mut [i8], world: usize) -> ReduceStats {
    assert_eq!(contribs.len(), world, "one contribution per worker");
    // apslint: allow(panic_in_hot_path) -- world >= 1 is asserted at collective construction; the first contribution defines the shape
    let n = contribs[0].len();
    assert_eq!(out.len(), n);
    out.fill(i8::MIN);
    for c in contribs {
        assert_eq!(c.len(), n);
        for (o, &v) in out.iter_mut().zip(c) {
            *o = (*o).max(v);
        }
    }
    ReduceStats {
        bytes_per_worker: 2 * n as u64 * (world as u64 - 1) / world as u64,
        steps: 2 * (world - 1),
    }
}

/// Flat ring all-reduce over all workers ([`ring`]).
#[derive(Clone, Copy, Debug)]
pub struct RingCollective {
    world: usize,
}

impl RingCollective {
    pub fn new(world: usize) -> Self {
        assert!(world >= 1);
        RingCollective { world }
    }
}

impl Collective for RingCollective {
    fn name(&self) -> &'static str {
        "ring"
    }
    fn world_size(&self) -> usize {
        self.world
    }
    fn steps_per_message(&self) -> usize {
        Topology::Ring.steps(self.world)
    }
    fn all_reduce_sum_into(
        &self,
        contribs: &[Vec<f32>],
        out: &mut [f32],
        opts: &ReduceOptions,
    ) -> ReduceStats {
        assert_eq!(contribs.len(), self.world, "one contribution per worker");
        if self.world == 1 {
            // apslint: allow(panic_in_hot_path) -- world == 1 checked on the line above, so contribs[0] exists
            out.copy_from_slice(&contribs[0]);
            return ReduceStats::default();
        }
        ring::all_reduce_into(contribs, out, *opts)
    }
    fn all_reduce_max_i8_into(&self, contribs: &[Vec<i8>], out: &mut [i8]) -> ReduceStats {
        max_i8_into(contribs, out, self.world)
    }
    fn all_reduce_packed_sum_into(
        &self,
        packed: &[PackedWire],
        strategy: &dyn SyncStrategy,
        ctx: &LayerCtx,
        out: &mut [f32],
        opts: &ReduceOptions,
        scratch: &mut PackScratch,
    ) -> ReduceStats {
        assert_eq!(packed.len(), self.world, "one packed contribution per worker");
        if self.world == 1 {
            // apslint: allow(panic_in_hot_path) -- world == 1 checked on the line above, so packed[0] exists
            strategy.decode_packed(&packed[0], ctx, 0..out.len(), out);
            return ReduceStats::default();
        }
        // Codecs with a Sync-safe decoder take the parallel fold (which
        // itself degrades to the single-threaded one at one thread);
        // everything else keeps the single-threaded path. Bit-identical
        // either way — rust/tests/packed_parallel.rs pins it.
        match strategy.parallel_decoder() {
            Some(sync_strategy) => {
                ring::all_reduce_packed_into_par(packed, sync_strategy, ctx, out, *opts, scratch)
            }
            None => {
                ring::all_reduce_packed_into(packed, strategy, ctx, out, *opts, &mut scratch.chunk)
            }
        }
    }
}

/// Grouped (hierarchical) all-reduce ([`hierarchical`]).
///
/// Owns the per-group partial-sum scratch ([`hierarchical::HierScratch`])
/// so repeated reductions through one collective — the session hot path —
/// allocate no element storage once warm. The scratch sits behind a
/// `RefCell` because the [`Collective`] trait takes `&self`; calls do not
/// re-enter, so the borrow is never contended.
#[derive(Clone, Debug)]
pub struct HierarchicalCollective {
    world: usize,
    group_size: usize,
    scratch: std::cell::RefCell<hierarchical::HierScratch>,
}

impl HierarchicalCollective {
    pub fn new(world: usize, group_size: usize) -> Self {
        assert!(world >= 1 && group_size >= 1);
        HierarchicalCollective {
            world,
            group_size,
            scratch: std::cell::RefCell::new(hierarchical::HierScratch::default()),
        }
    }
}

impl Collective for HierarchicalCollective {
    fn name(&self) -> &'static str {
        "hierarchical"
    }
    fn world_size(&self) -> usize {
        self.world
    }
    fn steps_per_message(&self) -> usize {
        Topology::Hierarchical { group_size: self.group_size }.steps(self.world)
    }
    fn all_reduce_sum_into(
        &self,
        contribs: &[Vec<f32>],
        out: &mut [f32],
        opts: &ReduceOptions,
    ) -> ReduceStats {
        assert_eq!(contribs.len(), self.world, "one contribution per worker");
        if self.world == 1 {
            // apslint: allow(panic_in_hot_path) -- world == 1 checked on the line above, so contribs[0] exists
            out.copy_from_slice(&contribs[0]);
            return ReduceStats::default();
        }
        hierarchical::all_reduce_with_scratch(
            contribs,
            self.group_size,
            out,
            *opts,
            &mut self.scratch.borrow_mut(),
        )
    }
    fn all_reduce_max_i8_into(&self, contribs: &[Vec<i8>], out: &mut [i8]) -> ReduceStats {
        max_i8_into(contribs, out, self.world)
    }
    fn all_reduce_packed_sum_into(
        &self,
        packed: &[PackedWire],
        strategy: &dyn SyncStrategy,
        ctx: &LayerCtx,
        out: &mut [f32],
        opts: &ReduceOptions,
        scratch: &mut PackScratch,
    ) -> ReduceStats {
        assert_eq!(packed.len(), self.world, "one packed contribution per worker");
        if self.world == 1 {
            // apslint: allow(panic_in_hot_path) -- world == 1 checked on the line above, so packed[0] exists
            strategy.decode_packed(&packed[0], ctx, 0..out.len(), out);
            return ReduceStats::default();
        }
        // Same dispatch as the ring: Sync-safe decoders take the
        // parallel phase-1 fold, others the single-threaded one.
        match strategy.parallel_decoder() {
            Some(sync_strategy) => hierarchical::all_reduce_packed_with_scratch_par(
                packed,
                self.group_size,
                sync_strategy,
                ctx,
                out,
                *opts,
                &mut self.scratch.borrow_mut(),
                scratch,
            ),
            None => hierarchical::all_reduce_packed_with_scratch(
                packed,
                self.group_size,
                strategy,
                ctx,
                out,
                *opts,
                &mut self.scratch.borrow_mut(),
                &mut scratch.chunk,
            ),
        }
    }
}

/// Numeric behaviour of the reduction.
#[derive(Clone, Copy, Debug)]
pub struct ReduceOptions {
    /// Wire format: every partial sum is re-quantized into this format.
    pub fmt: FpFormat,
    /// Rounding mode for the re-quantization.
    pub mode: Rounding,
    /// Use Kahan-compensated accumulation at every reduction site
    /// (paper §5.1.1 — CPD exposes this for reduce/all-reduce).
    pub kahan: bool,
}

impl ReduceOptions {
    pub fn fp32() -> Self {
        ReduceOptions { fmt: FpFormat::FP32, mode: Rounding::NearestEven, kahan: false }
    }
    pub fn low_precision(fmt: FpFormat) -> Self {
        ReduceOptions { fmt, mode: Rounding::NearestEven, kahan: false }
    }
}

impl Default for ReduceOptions {
    /// FP32 wire, round-to-nearest-even, no compensation.
    fn default() -> Self {
        ReduceOptions::fp32()
    }
}

/// Byte-traffic accounting for one collective call (feeds [`crate::perfmodel`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReduceStats {
    /// Total bytes a single worker puts on the wire.
    pub bytes_per_worker: u64,
    /// Number of latency-bound communication steps.
    pub steps: usize,
}

/// A simulated cluster of `world_size` data-parallel workers.
#[derive(Clone, Copy, Debug)]
pub struct SimCluster {
    pub world_size: usize,
}

impl SimCluster {
    pub fn new(world_size: usize) -> Self {
        assert!(world_size >= 1);
        SimCluster { world_size }
    }

    /// All-reduce (sum) of one tensor replicated across workers.
    ///
    /// `contribs[w]` is worker `w`'s local tensor; all must share a length.
    /// Returns the reduced tensor every worker ends up holding, plus
    /// traffic stats. Reduction arithmetic follows `opts` exactly.
    pub fn all_reduce_sum(
        &self,
        contribs: &[Vec<f32>],
        topo: Topology,
        opts: ReduceOptions,
    ) -> (Vec<f32>, ReduceStats) {
        assert_eq!(contribs.len(), self.world_size, "one contribution per worker");
        let n = contribs[0].len();
        assert!(contribs.iter().all(|c| c.len() == n), "ragged contributions");
        if self.world_size == 1 {
            return (contribs[0].clone(), ReduceStats::default());
        }
        match topo {
            Topology::Ring => ring::all_reduce(contribs, opts),
            Topology::Hierarchical { group_size } => {
                hierarchical::all_reduce(contribs, group_size, opts)
            }
            Topology::Ps { shards, staleness } => {
                // Fresh synchronous server (no carried staleness state):
                // every worker's round-0 contribution arrives on time.
                let ps = crate::sync::ps::PsCollective::new(self.world_size, shards, staleness);
                let mut out = vec![0.0f32; n];
                let stats = ps.all_reduce_sum_into(contribs, &mut out, &opts);
                (out, stats)
            }
        }
    }

    /// All-reduce (max) over small integer payloads — the 8-bit exponent
    /// phase of APS (Algorithm 1 line 4). Max is order-insensitive, so no
    /// precision emulation is needed; traffic is 1 byte per entry.
    pub fn all_reduce_max_i8(&self, contribs: &[Vec<i8>]) -> (Vec<i8>, ReduceStats) {
        let mut out = vec![i8::MIN; contribs[0].len()];
        let stats = max_i8_into(contribs, &mut out, self.world_size);
        (out, stats)
    }
}

/// One elementwise fold step in the wire precision: `acc = Q(acc + v)`,
/// optionally Kahan-compensated with `comp`.
#[inline]
pub(crate) fn fold_step(
    acc: &mut f32,
    comp: &mut f32,
    v: f32,
    fmt: FpFormat,
    mode: Rounding,
    kahan: bool,
) {
    if kahan {
        let y = quantize(v - *comp, fmt, mode);
        let t = quantize(*acc + y, fmt, mode);
        *comp = quantize(quantize(t - *acc, fmt, mode) - y, fmt, mode);
        *acc = t;
    } else {
        *acc = quantize(*acc + v, fmt, mode);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker_grads(p: usize, n: usize) -> Vec<Vec<f32>> {
        (0..p)
            .map(|w| {
                (0..n)
                    .map(|i| ((w * 131 + i * 31) % 17) as f32 * 0.125 - 1.0)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fp32_ring_matches_plain_sum_closely() {
        let p = 8;
        let n = 64;
        let grads = worker_grads(p, n);
        let cluster = SimCluster::new(p);
        let (out, stats) = cluster.all_reduce_sum(&grads, Topology::Ring, ReduceOptions::fp32());
        for i in 0..n {
            let exact: f64 = grads.iter().map(|g| g[i] as f64).sum();
            assert!((out[i] as f64 - exact).abs() < 1e-4, "i={i}");
        }
        assert_eq!(stats.steps, 14);
        assert!(stats.bytes_per_worker > 0);
    }

    #[test]
    fn hierarchical_matches_ring_in_fp32() {
        let p = 16;
        let n = 40;
        let grads = worker_grads(p, n);
        let cluster = SimCluster::new(p);
        let (r, _) = cluster.all_reduce_sum(&grads, Topology::Ring, ReduceOptions::fp32());
        let (h, _) = cluster.all_reduce_sum(
            &grads,
            Topology::Hierarchical { group_size: 4 },
            ReduceOptions::fp32(),
        );
        for i in 0..n {
            assert!((r[i] - h[i]).abs() < 1e-4 * r[i].abs().max(1.0));
        }
    }

    #[test]
    fn single_worker_identity() {
        let grads = worker_grads(1, 10);
        let cluster = SimCluster::new(1);
        let (out, stats) = cluster.all_reduce_sum(
            &grads,
            Topology::Ring,
            ReduceOptions::low_precision(FpFormat::E5M2),
        );
        assert_eq!(out, grads[0]);
        assert_eq!(stats.bytes_per_worker, 0);
    }

    #[test]
    fn low_precision_order_sensitivity() {
        // In E5M2 the reduction result depends on topology — the whole
        // point of Tables 8–9. Verify ring and hierarchical genuinely
        // differ for a hostile input (mix of scales).
        let p = 16;
        let n = 32;
        let grads: Vec<Vec<f32>> = (0..p)
            .map(|w| (0..n).map(|i| if w == 0 { 8.0 } else { 0.25 + i as f32 * 0.01 }).collect())
            .collect();
        let cluster = SimCluster::new(p);
        let opts = ReduceOptions::low_precision(FpFormat::E5M2);
        let (r, _) = cluster.all_reduce_sum(&grads, Topology::Ring, opts);
        let (h, _) = cluster.all_reduce_sum(&grads, Topology::Hierarchical { group_size: 4 }, opts);
        assert_ne!(r, h, "expected order-dependent rounding to differ");
    }

    #[test]
    fn max_i8_allreduce() {
        let cluster = SimCluster::new(4);
        let contribs = vec![
            vec![1i8, -5, 0],
            vec![3, -8, 0],
            vec![-2, -1, 7],
            vec![0, 0, 0],
        ];
        let (out, stats) = cluster.all_reduce_max_i8(&contribs);
        assert_eq!(out, vec![3, 0, 7]);
        assert_eq!(stats.steps, 6);
    }

    #[test]
    fn steps_formula() {
        assert_eq!(Topology::Ring.steps(256), 510);
        // Paper §4.2 quotes "74 steps" for p=256, k=16, but its own formula
        // 4(k-1) + 2(p/k - 1) evaluates to 4·15 + 2·15 = 90. We implement
        // the formula; the prose constant appears to be an arithmetic slip
        // (see DESIGN.md §discrepancies). Either way ≪ 510 ring steps.
        assert_eq!(Topology::Hierarchical { group_size: 16 }.steps(256), 90);
        // Parameter server: one push + one pull, independent of world.
        assert_eq!(Topology::Ps { shards: 4, staleness: 1 }.steps(256), 2);
    }
}

//! Gaussian-mixture image classification — the CIFAR-10 stand-in.
//!
//! Each class `c` owns a set of per-class "prototype" patterns at multiple
//! spatial frequencies; an example is a noisy mixture of its class
//! prototypes. The task is learnable (a linear probe already beats chance)
//! but not trivial (noise + inter-class prototype overlap), so training
//! curves have the familiar shape and gradients have realistic dynamics.

use super::{Batch, Rng};

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticImages {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: usize,
    /// Noise standard deviation added on top of the class signal.
    pub noise: f32,
    /// Signal amplitude.
    pub signal: f32,
    seed: u64,
}

impl SyntheticImages {
    /// CIFAR-10-shaped generator (32×32×3, 10 classes). The noise level
    /// is set so the task is learnable but not saturable in a handful of
    /// steps — accuracy differences between precision configurations stay
    /// visible (the paper's Tables 4–6 regime).
    pub fn cifar_like(seed: u64) -> Self {
        SyntheticImages {
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 10,
            noise: 1.0,
            signal: 0.5,
            seed,
        }
    }

    /// Downscaled variant for fast tests (8×8×3).
    pub fn tiny(seed: u64) -> Self {
        SyntheticImages {
            height: 8,
            width: 8,
            channels: 3,
            num_classes: 10,
            noise: 0.5,
            signal: 1.0,
            seed,
        }
    }

    pub fn pixels(&self) -> usize {
        self.height * self.width * self.channels
    }

    /// The deterministic class prototype for class `c` (unit-ish scale).
    ///
    /// Two components so every model family can learn it: a spatial
    /// sinusoid mixture (what an MLP/linear probe reads) plus a
    /// per-(class, channel) bias that survives global average pooling
    /// (what conv+GAP classifiers read).
    fn prototype(&self, c: usize, idx: usize) -> f32 {
        let np = self.pixels() as f32;
        let x = idx as f32 / np;
        let ch = idx % self.channels;
        let c1 = (c as f32 + 1.0) * 2.399; // golden-angle-ish spread
        let c2 = (c as f32 + 1.0) * 5.113;
        let spatial = ((x * c1 * 12.0).sin() + (x * c2 * 5.0 + c as f32).cos()) * 0.5;
        let channel_bias = (c1 + ch as f32 * 2.1).sin() * 0.7;
        spatial + channel_bias
    }

    /// Generate example `i` of the infinite dataset: `(image, label)`.
    /// Example identity is global, so sharding is just index ranges.
    pub fn example(&self, i: u64) -> (Vec<f32>, u32) {
        let mut rng = Rng::new(self.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // apslint: allow(lossy_cast) -- the modulus bounds the value by num_classes, a u32
        let label = (rng.next_u64() % self.num_classes as u64) as u32;
        let n = self.pixels();
        let mut img = vec![0.0f32; n];
        for (idx, px) in img.iter_mut().enumerate() {
            let sig = self.prototype(label as usize, idx);
            *px = self.signal * sig + self.noise * rng.normal();
        }
        (img, label)
    }

    /// Generate a batch of examples `[start, start + bs)`.
    pub fn batch(&self, start: u64, bs: usize) -> Batch {
        let mut images = Vec::with_capacity(bs * self.pixels());
        let mut labels = Vec::with_capacity(bs);
        for k in 0..bs {
            let (img, lab) = self.example(start + k as u64);
            images.extend_from_slice(&img);
            labels.push(lab);
        }
        Batch { images, labels, batch_size: bs }
    }

    /// A fixed evaluation set (examples `[2^40, 2^40 + n)` — disjoint from
    /// any training index range used in practice).
    pub fn eval_batch(&self, n: usize) -> Batch {
        self.batch(1 << 40, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_examples() {
        let g = SyntheticImages::tiny(11);
        let (a, la) = g.example(5);
        let (b, lb) = g.example(5);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = g.example(6);
        assert_ne!(a, c);
    }

    #[test]
    fn batch_shapes() {
        let g = SyntheticImages::cifar_like(0);
        let b = g.batch(0, 16);
        assert_eq!(b.images.len(), 16 * 32 * 32 * 3);
        assert_eq!(b.labels.len(), 16);
        assert!(b.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn classes_are_linearly_separable_ish() {
        // The class signal must carry information: the mean image of class
        // a correlates with its prototype more than with class b's.
        let g = SyntheticImages::tiny(3);
        let n = 400;
        let mut means = vec![vec![0.0f64; g.pixels()]; g.num_classes];
        let mut counts = vec![0usize; g.num_classes];
        for i in 0..n {
            let (img, lab) = g.example(i);
            counts[lab as usize] += 1;
            for (m, &v) in means[lab as usize].iter_mut().zip(&img) {
                *m += v as f64;
            }
        }
        // correlation of class-0 mean with prototypes
        let proto = |c: usize| -> Vec<f64> {
            (0..g.pixels()).map(|i| g.prototype(c, i) as f64).collect()
        };
        let corr = |a: &[f64], b: &[f64]| -> f64 {
            let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
            let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
            dot / (na * nb + 1e-12)
        };
        for c in 0..3 {
            if counts[c] < 10 {
                continue;
            }
            let m: Vec<f64> = means[c].iter().map(|v| v / counts[c] as f64).collect();
            let own = corr(&m, &proto(c));
            let other = corr(&m, &proto((c + 1) % g.num_classes));
            assert!(own > other, "class {c}: own {own} other {other}");
            assert!(own > 0.5, "class {c} own-corr too weak: {own}");
        }
    }
}

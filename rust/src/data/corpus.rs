//! Synthetic token corpus for the transformer e2e driver.
//!
//! A Markov-ish stream: Zipfian unigram base distribution plus strong
//! local bigram structure (each token has a preferred successor set), so
//! a language model has real signal to learn — loss drops well below the
//! unigram entropy — while remaining fully deterministic.

use super::{Rng, TokenBatch};

#[derive(Clone, Copy, Debug)]
pub struct SyntheticCorpus {
    pub vocab_size: usize,
    pub seq_len: usize,
    seed: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab_size: usize, seq_len: usize, seed: u64) -> Self {
        assert!(vocab_size >= 8);
        SyntheticCorpus { vocab_size, seq_len, seed }
    }

    /// The deterministic preferred successor of token `t` (bigram rule).
    fn successor(&self, t: u32) -> u32 {
        // an affine map over the vocab — a permutation when gcd(a, V)=1
        let v = self.vocab_size as u64;
        let a = 2 * (v / 3) + 1; // odd, usually coprime-ish with v
        ((a * t as u64 + 17) % v) as u32
    }

    /// Generate sequence `i` (seq_len + 1 tokens → inputs and shifted
    /// targets).
    pub fn sequence(&self, i: u64) -> (Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(self.seed ^ i.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut toks = Vec::with_capacity(self.seq_len + 1);
        let mut cur = rng.zipf(self.vocab_size) as u32;
        toks.push(cur);
        for _ in 0..self.seq_len {
            // 75%: follow the bigram rule; 25%: resample from Zipf.
            cur = if rng.uniform() < 0.75 {
                self.successor(cur)
            } else {
                rng.zipf(self.vocab_size) as u32
            };
            toks.push(cur);
        }
        let inputs = toks[..self.seq_len].to_vec();
        let targets = toks[1..].to_vec();
        (inputs, targets)
    }

    pub fn batch(&self, start: u64, bs: usize) -> TokenBatch {
        let mut tokens = Vec::with_capacity(bs * self.seq_len);
        let mut targets = Vec::with_capacity(bs * self.seq_len);
        for k in 0..bs {
            let (t, y) = self.sequence(start + k as u64);
            tokens.extend_from_slice(&t);
            targets.extend_from_slice(&y);
        }
        TokenBatch { tokens, targets, batch_size: bs, seq_len: self.seq_len }
    }

    pub fn eval_batch(&self, n: usize) -> TokenBatch {
        self.batch(1 << 40, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c = SyntheticCorpus::new(64, 16, 9);
        assert_eq!(c.sequence(0), c.sequence(0));
        assert_ne!(c.sequence(0).0, c.sequence(1).0);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // The preferred successor must appear far more often than chance.
        let c = SyntheticCorpus::new(64, 64, 1);
        let mut follow = 0usize;
        let mut total = 0usize;
        for i in 0..50 {
            let (inp, tgt) = c.sequence(i);
            for (a, b) in inp.iter().zip(&tgt) {
                total += 1;
                if *b == c.successor(*a) {
                    follow += 1;
                }
            }
        }
        let frac = follow as f64 / total as f64;
        assert!(frac > 0.5, "successor fraction {frac}");
    }

    #[test]
    fn tokens_in_vocab() {
        let c = SyntheticCorpus::new(100, 32, 2);
        let b = c.batch(0, 8);
        assert_eq!(b.tokens.len(), 8 * 32);
        assert_eq!(b.targets.len(), 8 * 32);
        assert!(b.tokens.iter().all(|&t| t < 100));
        assert!(b.targets.iter().all(|&t| t < 100));
    }
}

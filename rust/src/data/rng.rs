//! Deterministic PRNG shared by every data generator and initializer.
//!
//! xoshiro256++ seeded via SplitMix64 — fast, well-distributed, and
//! trivially reproducible (no global state, no platform dependence). All
//! experiment results in EXPERIMENTS.md are keyed by these seeds.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (any u64 works, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-worker / per-epoch splits).
    pub fn fork(&self, stream: u64) -> Rng {
        Rng::new(self.s[0] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // apslint: allow(lossy_cast) -- exact: the shift keeps 24 bits, the f32 mantissa width; (1u64 << 24) is a power of two
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // apslint: allow(lossy_cast) -- the modulus bounds the value by n, which is a usize
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    #[inline]
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with N(0, std²).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for o in out.iter_mut() {
            *o = self.normal() * std;
        }
    }

    /// Zipf-like sample over [0, n): P(k) ∝ 1/(k+1).
    pub fn zipf(&mut self, n: usize) -> usize {
        // Inverse-CDF on the harmonic weights via rejection-free cumsum
        // would be O(n); use the standard approximation instead:
        // k = floor(exp(u * ln(n+1))) - 1 gives ≈ 1/(k+1) mass.
        let u = self.uniform().max(1e-7);
        let k = ((u * ((n as f32 + 1.0).ln())).exp() - 1.0) as usize;
        k.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent_streams() {
        let base = Rng::new(7);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let v1: Vec<u64> = (0..16).map(|_| f1.next_u64()).collect();
        let v2: Vec<u64> = (0..16).map(|_| f2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 40_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.03, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 16];
        for _ in 0..10_000 {
            counts[r.zipf(16)] += 1;
        }
        assert!(counts[0] > counts[8], "{counts:?}");
        assert!(counts.iter().sum::<usize>() == 10_000);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}

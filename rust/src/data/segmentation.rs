//! Procedural segmentation dataset — the cityscapes stand-in (Table 3).
//!
//! Images contain a textured background plus 1–3 geometric objects
//! (rectangles / discs) of distinct classes; the mask labels each pixel.
//! Small enough to train an FCN head in seconds, structured enough that
//! mIoU meaningfully separates good from broken training.

use super::{Rng, SegBatch};

#[derive(Clone, Copy, Debug)]
pub struct SyntheticSegmentation {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// Classes including background class 0.
    pub num_classes: usize,
    pub noise: f32,
    seed: u64,
}

impl SyntheticSegmentation {
    /// Default: 32×32 RGB with background + 4 object classes.
    pub fn new(seed: u64) -> Self {
        SyntheticSegmentation {
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 5,
            noise: 0.3,
            seed,
        }
    }

    /// Tiny variant for unit tests.
    pub fn tiny(seed: u64) -> Self {
        SyntheticSegmentation {
            height: 16,
            width: 16,
            channels: 3,
            num_classes: 4,
            noise: 0.25,
            seed,
        }
    }

    pub fn pixels(&self) -> usize {
        self.height * self.width
    }

    /// Per-class base color (distinct, deterministic).
    fn class_color(&self, c: usize, ch: usize) -> f32 {
        let phase = c as f32 * 2.399 + ch as f32 * 1.571;
        phase.sin() * 0.8
    }

    /// Generate example `i`: `(image NHWC-flat, mask HW-flat)`.
    pub fn example(&self, i: u64) -> (Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(self.seed ^ i.wrapping_mul(0xD134_2543_DE82_EF95));
        let (h, w, ch) = (self.height, self.width, self.channels);
        let mut mask = vec![0u32; h * w];
        // 1–3 objects of random class/shape/position
        let objects = 1 + rng.below(3);
        for _ in 0..objects {
            let class = 1 + rng.below(self.num_classes - 1) as u32;
            let cy = rng.below(h);
            let cx = rng.below(w);
            let r = 2 + rng.below(h / 3);
            let disc = rng.below(2) == 0;
            for y in 0..h {
                for x in 0..w {
                    let dy = y as i64 - cy as i64;
                    let dx = x as i64 - cx as i64;
                    let inside = if disc {
                        dy * dy + dx * dx <= (r * r) as i64
                    } else {
                        dy.unsigned_abs() as usize <= r && dx.unsigned_abs() as usize <= r
                    };
                    if inside {
                        mask[y * w + x] = class;
                    }
                }
            }
        }
        // Image: class color + texture + noise
        let mut img = vec![0.0f32; h * w * ch];
        for y in 0..h {
            for x in 0..w {
                let c = mask[y * w + x] as usize;
                for k in 0..ch {
                    let texture = ((x as f32 * 0.7 + y as f32 * 0.3 + k as f32).sin()) * 0.15;
                    img[(y * w + x) * ch + k] =
                        self.class_color(c, k) + texture + self.noise * rng.normal();
                }
            }
        }
        (img, mask)
    }

    pub fn batch(&self, start: u64, bs: usize) -> SegBatch {
        let mut images = Vec::with_capacity(bs * self.pixels() * self.channels);
        let mut masks = Vec::with_capacity(bs * self.pixels());
        for k in 0..bs {
            let (img, m) = self.example(start + k as u64);
            images.extend_from_slice(&img);
            masks.extend_from_slice(&m);
        }
        SegBatch { images, masks, batch_size: bs }
    }

    pub fn eval_batch(&self, n: usize) -> SegBatch {
        self.batch(1 << 40, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = SyntheticSegmentation::tiny(5);
        assert_eq!(g.example(3), g.example(3));
        assert_ne!(g.example(3).1, g.example(4).1);
    }

    #[test]
    fn masks_have_objects_and_background() {
        let g = SyntheticSegmentation::new(1);
        let mut any_fg = false;
        let mut any_bg = false;
        for i in 0..10 {
            let (_, m) = g.example(i);
            any_fg |= m.iter().any(|&c| c > 0);
            any_bg |= m.iter().any(|&c| c == 0);
            assert!(m.iter().all(|&c| c < g.num_classes as u32));
        }
        assert!(any_fg && any_bg);
    }

    #[test]
    fn image_pixels_track_mask_classes() {
        // Mean color inside an object must differ from background.
        let g = SyntheticSegmentation::new(2);
        let (img, m) = g.example(0);
        let ch = g.channels;
        let mut sums = vec![(0.0f64, 0usize); g.num_classes];
        for (p, &c) in m.iter().enumerate() {
            sums[c as usize].0 += img[p * ch] as f64;
            sums[c as usize].1 += 1;
        }
        let present: Vec<usize> =
            (0..g.num_classes).filter(|&c| sums[c].1 > 10).collect();
        assert!(present.len() >= 2);
        let m0 = sums[present[0]].0 / sums[present[0]].1 as f64;
        let m1 = sums[present[1]].0 / sums[present[1]].1 as f64;
        assert!((m0 - m1).abs() > 0.05, "class means too close: {m0} {m1}");
    }

    #[test]
    fn batch_shapes() {
        let g = SyntheticSegmentation::tiny(0);
        let b = g.batch(0, 4);
        assert_eq!(b.images.len(), 4 * 16 * 16 * 3);
        assert_eq!(b.masks.len(), 4 * 16 * 16);
    }
}

//! Deterministic synthetic datasets (DESIGN.md §3 substitutions).
//!
//! The paper trains on CIFAR-10, ImageNet and cityscapes — none available
//! here — so each workload is replaced by a deterministic synthetic
//! generator of the same tensor shapes whose gradients exhibit the
//! property APS exploits: per-layer gradient scales spread over many
//! orders of magnitude (verified by the Fig 1/2 reproductions).
//!
//! * [`synthetic`] — Gaussian-mixture image classification (CIFAR-like).
//! * [`segmentation`] — procedural shape masks (cityscapes stand-in).
//! * [`corpus`] — a synthetic token stream with Zipfian unigram statistics
//!   and local structure, for the transformer e2e driver.
//! * [`rng`] — the SplitMix64/xoshiro PRNG all generators share, so every
//!   experiment is bit-reproducible from its seed.

pub mod corpus;
pub mod rng;
pub mod segmentation;
pub mod synthetic;

pub use rng::Rng;

/// A classification minibatch: `images` is NHWC flattened, `labels` is
/// one `u32` class id per example.
#[derive(Clone, Debug)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<u32>,
    pub batch_size: usize,
}

/// A segmentation minibatch: per-pixel integer labels.
#[derive(Clone, Debug)]
pub struct SegBatch {
    pub images: Vec<f32>,
    /// `batch × h × w` class ids.
    pub masks: Vec<u32>,
    pub batch_size: usize,
}

/// A language-model minibatch: token ids and next-token targets.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub tokens: Vec<u32>,
    pub targets: Vec<u32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

/// Shard `global_batch` examples across `world` workers; worker `w` gets
/// the `w`-th contiguous slice. Panics unless evenly divisible (the
/// paper's experiments all use divisible batch sizes).
pub fn shard_range(global_batch: usize, world: usize, w: usize) -> std::ops::Range<usize> {
    assert!(global_batch % world == 0, "batch {global_batch} not divisible by world {world}");
    let per = global_batch / world;
    w * per..(w + 1) * per
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_the_batch() {
        let world = 8;
        let covered: Vec<usize> =
            (0..world).flat_map(|w| shard_range(4096, world, w)).collect();
        assert_eq!(covered.len(), 4096);
        assert_eq!(covered, (0..4096).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_batch_panics() {
        let _ = shard_range(10, 3, 0);
    }
}

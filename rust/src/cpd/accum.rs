//! Low-precision accumulation (paper §5.1.1).
//!
//! When a small number is added to a large one in a narrow format, the
//! small number's mantissa is right-shifted away — the "large-and-small
//! addition" problem the paper identifies in both GEMM accumulation and
//! gradient all-reduce. CPD offers two accumulators:
//!
//! * [`LowPrecisionAccumulator`] — the faithful emulation: the running sum
//!   lives in the custom format and *every* partial sum is re-quantized
//!   (what real low-precision hardware would do).
//! * [`KahanAccumulator`] — the same, plus Kahan compensated summation
//!   (Higham [13]); the compensation term also lives in the custom format.
//!   The paper introduces this into DL for reduce/all-reduce and GEMM.

use super::cast::{quantize, Rounding};
use super::format::FpFormat;

/// Running sum where every intermediate result is quantized to `fmt`.
#[derive(Clone, Copy, Debug)]
pub struct LowPrecisionAccumulator {
    fmt: FpFormat,
    mode: Rounding,
    sum: f32,
}

impl LowPrecisionAccumulator {
    pub fn new(fmt: FpFormat, mode: Rounding) -> Self {
        Self { fmt, mode, sum: 0.0 }
    }

    /// Add one term: `sum = Q(sum + Q(v))`.
    #[inline]
    pub fn add(&mut self, v: f32) {
        let qv = quantize(v, self.fmt, self.mode);
        self.sum = quantize(self.sum + qv, self.fmt, self.mode);
    }

    /// Add an already-quantized term: `sum = Q(sum + v)` (the all-reduce
    /// inner step, where operands arrive in the wire format).
    #[inline]
    pub fn add_quantized(&mut self, v: f32) {
        self.sum = quantize(self.sum + v, self.fmt, self.mode);
    }

    pub fn value(&self) -> f32 {
        self.sum
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
    }
}

/// Kahan-compensated running sum in a custom format.
///
/// All four intermediate quantities (`y`, `t`, the new compensation and the
/// new sum) are squeezed through `fmt`, so this models a hardware unit that
/// holds two low-precision registers rather than a hidden wide accumulator.
#[derive(Clone, Copy, Debug)]
pub struct KahanAccumulator {
    fmt: FpFormat,
    mode: Rounding,
    sum: f32,
    comp: f32,
}

impl KahanAccumulator {
    pub fn new(fmt: FpFormat, mode: Rounding) -> Self {
        Self { fmt, mode, sum: 0.0, comp: 0.0 }
    }

    /// Add one term with compensation.
    #[inline]
    pub fn add(&mut self, v: f32) {
        let q = |x: f32| quantize(x, self.fmt, self.mode);
        let y = q(q(v) - self.comp);
        let t = q(self.sum + y);
        self.comp = q(q(t - self.sum) - y);
        self.sum = t;
    }

    /// Add an already-quantized term (all-reduce inner step).
    #[inline]
    pub fn add_quantized(&mut self, v: f32) {
        let q = |x: f32| quantize(x, self.fmt, self.mode);
        let y = q(v - self.comp);
        let t = q(self.sum + y);
        self.comp = q(q(t - self.sum) - y);
        self.sum = t;
    }

    pub fn value(&self) -> f32 {
        self.sum
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.comp = 0.0;
    }
}

/// Sum a slice in the custom format with a plain low-precision accumulator.
pub fn sum_low_precision(xs: &[f32], fmt: FpFormat, mode: Rounding) -> f32 {
    let mut acc = LowPrecisionAccumulator::new(fmt, mode);
    for &x in xs {
        acc.add(x);
    }
    acc.value()
}

/// Sum a slice in the custom format with Kahan compensation.
pub fn sum_kahan(xs: &[f32], fmt: FpFormat, mode: Rounding) -> f32 {
    let mut acc = KahanAccumulator::new(fmt, mode);
    for &x in xs {
        acc.add(x);
    }
    acc.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    const RNE: Rounding = Rounding::NearestEven;

    #[test]
    fn fp32_accumulator_is_plain_sum() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32 * 0.25).collect();
        let got = sum_low_precision(&xs, FpFormat::FP32, RNE);
        let want: f32 = xs.iter().sum();
        assert_eq!(got, want);
    }

    #[test]
    fn small_terms_vanish_without_kahan() {
        // In E5M2 (2 mantissa bits) adding 1.0 repeatedly to a sum of 64
        // does nothing: 64 + 1 = 65 rounds back to 64 (ulp at 64 is 16).
        let f = FpFormat::E5M2;
        let mut acc = LowPrecisionAccumulator::new(f, RNE);
        acc.add(64.0);
        for _ in 0..32 {
            acc.add(1.0);
        }
        assert_eq!(acc.value(), 64.0);
    }

    #[test]
    fn kahan_recovers_small_terms() {
        // Kahan keeps the lost low-order parts in the compensation register
        // and releases them once they accumulate past an ulp. (In E4M3 the
        // ulp at 64 is 8, so naive addition of 1.0 stalls forever; Kahan
        // accumulates the compensation until it crosses the rounding
        // threshold. With only 2 mantissa bits the compensation itself can
        // hit ties-to-even and stall too — hence E4M3 here, and the
        // weaker `<=` property tested for E5M2 elsewhere.)
        let f = FpFormat::E4M3;
        let mut naive = LowPrecisionAccumulator::new(f, RNE);
        let mut kahan = KahanAccumulator::new(f, RNE);
        naive.add(64.0);
        kahan.add(64.0);
        for _ in 0..64 {
            naive.add(1.0);
            kahan.add(1.0);
        }
        let exact = 128.0f32;
        let kahan_err = (kahan.value() - exact).abs();
        let naive_err = (naive.value() - exact).abs();
        assert!(kahan_err < naive_err, "kahan={} naive={}", kahan.value(), naive.value());
        assert_eq!(naive.value(), 64.0);
        assert!(kahan_err <= 16.0, "kahan={}", kahan.value()); // within one ulp at 128
    }

    #[test]
    fn kahan_beats_naive_on_long_uniform_sum() {
        let f = FpFormat::E4M3;
        let xs: Vec<f32> = vec![0.1; 4096];
        let exact = 0.1f64 * 4096.0;
        let naive = sum_low_precision(&xs, f, RNE) as f64;
        let kahan = sum_kahan(&xs, f, RNE) as f64;
        assert!(
            (kahan - exact).abs() <= (naive - exact).abs(),
            "kahan={kahan} naive={naive} exact={exact}"
        );
    }

    #[test]
    fn reset_works() {
        let mut a = KahanAccumulator::new(FpFormat::E5M2, RNE);
        a.add(3.0);
        a.reset();
        assert_eq!(a.value(), 0.0);
        let mut b = LowPrecisionAccumulator::new(FpFormat::E5M2, RNE);
        b.add(3.0);
        b.reset();
        assert_eq!(b.value(), 0.0);
    }

    #[test]
    fn inf_propagates_through_accumulation() {
        // The paper's "domino effect": once INF enters, it never leaves.
        let f = FpFormat::E5M2;
        let mut acc = LowPrecisionAccumulator::new(f, RNE);
        acc.add(1e30); // overflows to INF in E5M2
        acc.add(-5.0);
        assert!(acc.value().is_infinite());
    }
}

//! Bit-exact quantization of `f32` values into a customized format.
//!
//! `quantize(x, fmt, Rounding::NearestEven)` returns the `f32` whose value
//! is exactly the `(exp_bits, man_bits)` representation of `x` — i.e. the
//! result of casting to the low-precision format and back up (every such
//! format is a subset of FP32). This is CPD's core primitive: the paper's
//! experiments all run arithmetic in FP32 but squeeze values through the
//! emulated format at the points where a real system would store or
//! transmit low-precision words.
//!
//! The implementation is pure integer bit manipulation on the significand
//! (no double rounding): decompose `|x| = sig · 2^(e-23)` with a 24-bit
//! significand, decide how many significand bits the target keeps at this
//! exponent (fewer in the subnormal range — gradual underflow), round the
//! dropped bits, and rebuild. Overflow follows IEEE: a post-rounding
//! magnitude above `max_value` becomes `±INF` (the paper's "cast to INF").

use super::format::FpFormat;

/// Rounding mode used when casting into the low-precision format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Rounding {
    /// Round to nearest, ties to even — the paper's choice (§4) and the
    /// mode used by every experiment in this repository.
    #[default]
    NearestEven,
    /// Truncate toward zero (for comparison studies).
    TowardZero,
    /// Unbiased stochastic rounding (QSGD/TernGrad-style); the `u64` per
    /// call comes from the caller's RNG so results stay reproducible.
    Stochastic(u64),
}

/// Quantize a single `f32` into `fmt`, returning the dequantized `f32`.
///
/// Semantics:
/// * `NaN` → `NaN`; `±INF` → `±INF`; `±0` preserved (incl. sign).
/// * Magnitudes that round above [`FpFormat::max_value`] → `±INF`.
/// * Magnitudes that round below the smallest subnormal → `±0`.
/// * `(8, 23)` is the identity on all finite values.
///
/// ```
/// use aps_cpd::cpd::{quantize, FpFormat, Rounding};
/// let f = FpFormat::E5M2; // mantissa step at 1.0 is 0.25
/// assert_eq!(quantize(1.1, f, Rounding::NearestEven), 1.0);
/// assert_eq!(quantize(1.125, f, Rounding::NearestEven), 1.0);  // tie → even
/// assert_eq!(quantize(1.375, f, Rounding::NearestEven), 1.5);  // tie → even
/// assert_eq!(quantize(1e6, f, Rounding::NearestEven), f32::INFINITY);
/// assert_eq!(quantize(1e-9, f, Rounding::NearestEven), 0.0);
/// ```
#[inline]
pub fn quantize(x: f32, fmt: FpFormat, mode: Rounding) -> f32 {
    if fmt.is_fp32() {
        return x;
    }
    quantize_shifted(x, 0, fmt, mode)
}

/// Quantize `x * 2^factor_exp` into `fmt` with a **single** rounding.
///
/// The power-of-two shift happens in exponent space (paper §3.3.1 — a
/// shift is lossless), so the only rounding is the cast into the target
/// format. This is the primitive APS uses on the wire path: it avoids the
/// double rounding that "scale in f32, then cast" would introduce when
/// the scaled value lands in the f32-subnormal range, and matches the
/// Python oracle (`ref.quantize_ref`) bit for bit.
#[inline]
pub fn quantize_shifted(x: f32, factor_exp: i32, fmt: FpFormat, mode: Rounding) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x.is_infinite() || x == 0.0 {
        return x; // preserves ±0 and ±INF
    }
    if fmt.is_fp32() && factor_exp == 0 {
        return x;
    }
    let neg = x.is_sign_negative();
    let bits = x.abs().to_bits();
    let raw_e = (bits >> 23) as i32;
    let raw_m = (bits & 0x007f_ffff) as u64;

    // |x| = sig * 2^(e - 23), sig in [2^23, 2^24) (normalized).
    let (e, sig): (i32, u64) = if raw_e == 0 {
        // f32 subnormal: value = raw_m * 2^-149; normalize.
        let lead = 63 - raw_m.leading_zeros() as i32; // index of top set bit
        let shift = 23 - lead;
        (-126 - shift, raw_m << shift)
    } else {
        (raw_e - 127, raw_m | (1 << 23))
    };
    // The APS power-of-two shift: pure exponent arithmetic (Fig 4).
    let e = e.saturating_add(factor_exp);

    // Far above the format's range: the value is ≥ 2^e > max_value even
    // before rounding (also keeps the bit-assembled pow2 in domain).
    if e > fmt.max_exponent() {
        return if neg { f32::NEG_INFINITY } else { f32::INFINITY };
    }

    let e_min = fmt.min_normal_exponent();
    // Significand bits kept at this exponent: man+1 for normals, fewer in
    // the subnormal range (gradual underflow).
    let keep = if e >= e_min {
        fmt.man_bits as i32 + 1
    } else {
        fmt.man_bits as i32 + 1 - (e_min - e)
    };
    let drop = 24 - keep; // bits of `sig` to round away (can exceed 24)

    let rounded: u64 = if drop <= 0 {
        sig
    } else if drop >= 63 {
        0 // far below the subnormal range; sig < 2^24 << 2^(drop-1), no tie
    } else {
        let floor = sig >> drop;
        let rem = sig & ((1u64 << drop) - 1);
        let half = 1u64 << (drop - 1);
        match mode {
            Rounding::NearestEven => {
                if rem > half || (rem == half && floor & 1 == 1) {
                    floor + 1
                } else {
                    floor
                }
            }
            Rounding::TowardZero => floor,
            Rounding::Stochastic(r) => {
                // Round up with probability rem / 2^drop (unbiased).
                let threshold = r & ((1u64 << drop) - 1);
                if rem > threshold {
                    floor + 1
                } else {
                    floor
                }
            }
        }
    };

    if rounded == 0 {
        return if neg { -0.0 } else { 0.0 };
    }
    // value = rounded * 2^(e - 23 + drop); exact in f64 (≤ 25-bit integer,
    // exponent ∈ [-149, e_max+1] — always a normal f64). Powers of two are
    // bit-assembled rather than computed with libm exp2 (≈2× on the slice
    // path, EXPERIMENTS.md §Perf).
    // apslint: allow(lossy_cast) -- exact: rounded is a <= 25-bit integer (see comment above), far below the 2^53 f64 mantissa
    let val = rounded as f64 * pow2_f64(e - 23 + drop.max(0));
    let max_val =
        (2.0 - pow2_f64(-(fmt.man_bits as i32))) * pow2_f64(fmt.max_exponent());
    let out = if val > max_val { f64::INFINITY } else { val };
    let out = out as f32; // exact: result is representable in f32
    if neg {
        -out
    } else {
        out
    }
}

/// Exact `2^k` for `k ∈ [-1022, 1023]` by exponent-field assembly.
#[inline(always)]
fn pow2_f64(k: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&k));
    f64::from_bits(((k + 1023) as u64) << 52)
}

/// Quantize `xs * 2^factor_exp` elementwise with a single rounding,
/// allocating the output (the APS wire-path downcast).
pub fn quantize_shifted_slice(
    xs: &[f32],
    factor_exp: i32,
    fmt: FpFormat,
    mode: Rounding,
) -> Vec<f32> {
    let mut out = vec![0.0; xs.len()];
    quantize_shifted_slice_into(xs, factor_exp, fmt, mode, &mut out);
    out
}

/// [`quantize_shifted_slice`] into a caller-provided buffer — the
/// allocation-free variant [`crate::sync::SyncSession`] uses on the wire
/// path. Bit-identical to the allocating version.
pub fn quantize_shifted_slice_into(
    xs: &[f32],
    factor_exp: i32,
    fmt: FpFormat,
    mode: Rounding,
    out: &mut [f32],
) {
    assert_eq!(xs.len(), out.len());
    // Hoist the mode match out of the element loop; on multi-core hosts
    // chunk across threads (pure elementwise work), on single-core run
    // the direct loop (the closure/thread plumbing alone costs ~2×).
    let run = |start: usize, chunk: &mut [f32]| {
        let src = &xs[start..start + chunk.len()];
        match mode {
            Rounding::Stochastic(seed) => {
                for (i, (&x, o)) in src.iter().zip(chunk.iter_mut()).enumerate() {
                    let gi = (start + i) as u64;
                    let r = splitmix64(seed ^ gi.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    *o = quantize_shifted(x, factor_exp, fmt, Rounding::Stochastic(r));
                }
            }
            Rounding::NearestEven => {
                for (&x, o) in src.iter().zip(chunk.iter_mut()) {
                    *o = quantize_shifted(x, factor_exp, fmt, Rounding::NearestEven);
                }
            }
            Rounding::TowardZero => {
                for (&x, o) in src.iter().zip(chunk.iter_mut()) {
                    *o = quantize_shifted(x, factor_exp, fmt, Rounding::TowardZero);
                }
            }
        }
    };
    // apslint: allow(nondeterminism) -- thread count only selects chunking; the stochastic-rounding RNG is keyed by absolute element index, so results are bit-identical for any thread count
    if crate::util::par::num_threads() > 1 && xs.len() >= crate::util::par::PAR_THRESHOLD {
        crate::util::par::par_chunks_mut(out, crate::util::par::PAR_THRESHOLD, run);
    } else {
        run(0, out);
    }
}

/// Quantize `x` into `fmt` and return its storage **bit-code** — the
/// `fmt.total_bits()`-bit word a real deployment would put on the wire
/// (sign ‖ biased exponent ‖ mantissa, IEEE-754-like layout, all-ones
/// exponent reserved for INF/NaN). Shares the rounding logic with
/// [`quantize`]; [`decode_bits`] is the exact inverse on every
/// representable value (NaN decodes to the canonical `f32::NAN`, which
/// is also what [`quantize`] returns for NaN inputs).
///
/// Formats with `man_bits == 0` have no NaN code (the single all-ones
/// exponent word is INF); encoding NaN into such a format panics in
/// debug builds — callers must escape to a raw representation first.
#[inline]
pub fn encode_bits(x: f32, fmt: FpFormat, mode: Rounding) -> u32 {
    code_of_representable(quantize(x, fmt, mode), fmt)
}

/// The bit-code of a value already exactly representable in `fmt`
/// (the extraction half of [`encode_bits`]).
pub(crate) fn code_of_representable(q: f32, fmt: FpFormat) -> u32 {
    let mb = fmt.man_bits as u32;
    let eb = fmt.exp_bits as u32;
    let sign = (q.is_sign_negative() as u32) << (eb + mb);
    let exp_ones = ((1u32 << eb) - 1) << mb;
    if q.is_nan() {
        debug_assert!(mb >= 1, "NaN has no bit-code in a zero-mantissa format");
        // canonical quiet NaN: all-ones exponent, MSB mantissa bit set
        return exp_ones | (1u32 << mb.saturating_sub(1));
    }
    if q.is_infinite() {
        return sign | exp_ones;
    }
    if q == 0.0 {
        return sign; // preserves the sign of -0.0
    }
    // Decompose |q| = sig · 2^(e − 23) with sig ∈ [2^23, 2^24).
    let bits = q.abs().to_bits();
    let raw_e = (bits >> 23) as i32;
    let raw_m = (bits & 0x007f_ffff) as u64;
    let (e, sig): (i32, u64) = if raw_e == 0 {
        let lead = 63 - raw_m.leading_zeros() as i32;
        let shift = 23 - lead;
        (-126 - shift, raw_m << shift)
    } else {
        (raw_e - 127, raw_m | (1 << 23))
    };
    debug_assert!(e <= fmt.max_exponent(), "{q:e} is out of range for {fmt}");
    let e_min = fmt.min_normal_exponent();
    if e >= e_min {
        // Normal in fmt: mantissa is the top man_bits of the significand.
        let drop = 23 - mb;
        debug_assert!(
            drop == 0 || sig & ((1u64 << drop) - 1) == 0,
            "{q:e} is not representable in {fmt}"
        );
        let man = ((sig >> drop) & ((1u64 << mb) - 1)) as u32;
        let biased = (e + fmt.bias()) as u32;
        sign | (biased << mb) | man
    } else {
        // Subnormal in fmt: value = man · 2^min_subnormal_exponent.
        let sh = 23 + fmt.min_subnormal_exponent() - e;
        debug_assert!((0..64).contains(&sh), "{q:e} below {fmt}'s subnormal range");
        debug_assert!(sig & ((1u64 << sh) - 1) == 0, "{q:e} is not representable in {fmt}");
        sign | (sig >> sh) as u32
    }
}

/// Decode a [`encode_bits`] bit-code back to the exact `f32` value of
/// that representable (the up-cast a receiver performs).
#[inline]
pub fn decode_bits(code: u32, fmt: FpFormat) -> f32 {
    let mb = fmt.man_bits as u32;
    let eb = fmt.exp_bits as u32;
    let man = code & ((1u32 << mb) - 1);
    let expf = (code >> mb) & ((1u32 << eb) - 1);
    let neg = (code >> (eb + mb)) & 1 == 1;
    let exp_ones = (1u32 << eb) - 1;
    let mag: f32 = if expf == exp_ones {
        if man == 0 {
            f32::INFINITY
        } else {
            return f32::NAN; // canonical, sign ignored (matches quantize)
        }
    } else if expf == 0 {
        (man as f64 * pow2_f64(fmt.min_subnormal_exponent())) as f32
    } else {
        let e = expf as i32 - fmt.bias();
        ((1.0 + man as f64 / (1u64 << mb) as f64) * pow2_f64(e)) as f32
    };
    if neg {
        -mag
    } else {
        mag
    }
}

/// Bulk [`encode_bits`] — the packed-wire downcast kernel
/// (`rust/src/sync/wire.rs` packs these codes at `fmt.total_bits()` each).
pub fn encode_bits_slice_into(xs: &[f32], fmt: FpFormat, mode: Rounding, out: &mut [u32]) {
    assert_eq!(xs.len(), out.len());
    match mode {
        Rounding::Stochastic(seed) => {
            // Same per-element draw derivation as `quantize_slice_into`,
            // so code and value paths agree on stochastic wires.
            for (i, (&x, o)) in xs.iter().zip(out.iter_mut()).enumerate() {
                let r = splitmix64(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                *o = encode_bits(x, fmt, Rounding::Stochastic(r));
            }
        }
        m => {
            for (&x, o) in xs.iter().zip(out.iter_mut()) {
                *o = encode_bits(x, fmt, m);
            }
        }
    }
}

/// Bulk [`decode_bits`] — the packed-wire upcast kernel.
pub fn decode_bits_slice_into(codes: &[u32], fmt: FpFormat, out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    for (&c, o) in codes.iter().zip(out.iter_mut()) {
        *o = decode_bits(c, fmt);
    }
}

/// Quantize a slice elementwise, allocating the output.
pub fn quantize_slice(xs: &[f32], fmt: FpFormat, mode: Rounding) -> Vec<f32> {
    let mut out = vec![0.0; xs.len()];
    quantize_slice_into(xs, &mut out, fmt, mode);
    out
}

/// Quantize `xs` elementwise into `out` (same length). The hot-path
/// variant used by the gradient-sync pipeline; see `benches/hotpath.rs`.
pub fn quantize_slice_into(xs: &[f32], out: &mut [f32], fmt: FpFormat, mode: Rounding) {
    assert_eq!(xs.len(), out.len());
    if fmt.is_fp32() {
        out.copy_from_slice(xs);
        return;
    }
    match mode {
        Rounding::Stochastic(seed) => {
            // Derive one draw per element from a counter-based SplitMix64
            // so slice quantization stays deterministic and parallelizable.
            for (i, (&x, o)) in xs.iter().zip(out.iter_mut()).enumerate() {
                let r = splitmix64(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                *o = quantize(x, fmt, Rounding::Stochastic(r));
            }
        }
        m => {
            for (&x, o) in xs.iter().zip(out.iter_mut()) {
                *o = quantize(x, fmt, m);
            }
        }
    }
}

/// In-place slice quantization.
pub fn quantize_slice_inplace(xs: &mut [f32], fmt: FpFormat, mode: Rounding) {
    if fmt.is_fp32() {
        return;
    }
    match mode {
        Rounding::Stochastic(seed) => {
            for (i, x) in xs.iter_mut().enumerate() {
                let r = splitmix64(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                *x = quantize(*x, fmt, Rounding::Stochastic(r));
            }
        }
        m => {
            for x in xs.iter_mut() {
                *x = quantize(*x, fmt, m);
            }
        }
    }
}

#[inline]
pub(crate) fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The unbiased exponent `ceil(log2(|x|))` used by Algorithm 1's
/// `FindMaxExp` (line 19). Exact powers of two return their exponent; other
/// values return `floor(log2|x|) + 1`. Returns `None` for zero/non-finite.
#[inline]
pub fn ceil_log2_abs(x: f32) -> Option<i32> {
    if x == 0.0 || !x.is_finite() {
        return None;
    }
    let bits = x.abs().to_bits();
    let raw_e = (bits >> 23) as i32;
    let raw_m = bits & 0x007f_ffff;
    if raw_e == 0 {
        // subnormal: value = raw_m * 2^-149
        let lead = 31 - raw_m.leading_zeros() as i32;
        let floor = lead - 149;
        // power of two iff a single bit set
        Some(if raw_m.count_ones() == 1 { floor } else { floor + 1 })
    } else {
        let floor = raw_e - 127;
        Some(if raw_m == 0 { floor } else { floor + 1 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RNE: Rounding = Rounding::NearestEven;

    #[test]
    fn identity_for_fp32() {
        for x in [0.0f32, -0.0, 1.5, -3.25e-12, 1e38, f32::MIN_POSITIVE / 8.0] {
            assert_eq!(quantize(x, FpFormat::FP32, RNE).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn specials() {
        let f = FpFormat::E5M2;
        assert!(quantize(f32::NAN, f, RNE).is_nan());
        assert_eq!(quantize(f32::INFINITY, f, RNE), f32::INFINITY);
        assert_eq!(quantize(f32::NEG_INFINITY, f, RNE), f32::NEG_INFINITY);
        assert_eq!(quantize(0.0, f, RNE).to_bits(), 0.0f32.to_bits());
        assert_eq!(quantize(-0.0, f, RNE).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn rne_ties_to_even() {
        let f = FpFormat::E5M2; // step at [1,2) is 0.25
        assert_eq!(quantize(1.125, f, RNE), 1.0); // 1.125 between 1.0, 1.25 → even 1.0
        assert_eq!(quantize(1.375, f, RNE), 1.5); // between 1.25, 1.5 → even 1.5
        assert_eq!(quantize(-1.125, f, RNE), -1.0);
        assert_eq!(quantize(1.1251, f, RNE), 1.25); // above tie → up
        assert_eq!(quantize(1.1249, f, RNE), 1.0);
    }

    #[test]
    fn overflow_to_inf() {
        let f = FpFormat::E5M2;
        let max = f.max_value() as f32; // 57344
        assert_eq!(quantize(max, f, RNE), max);
        // Below the rounding midpoint stays at max, above → INF.
        let ulp = 2f32.powi(15 - 2);
        assert_eq!(quantize(max + ulp * 0.49, f, RNE), max);
        assert_eq!(quantize(max + ulp * 0.51, f, RNE), f32::INFINITY);
        assert_eq!(quantize(-1e30, f, RNE), f32::NEG_INFINITY);
    }

    #[test]
    fn underflow_to_zero_and_subnormals() {
        let f = FpFormat::E5M2;
        let min_sub = f.min_subnormal() as f32; // 2^-16
        assert_eq!(quantize(min_sub, f, RNE), min_sub);
        // Half the min subnormal ties to even (0).
        assert_eq!(quantize(min_sub * 0.5, f, RNE), 0.0);
        assert_eq!(quantize(min_sub * 0.51, f, RNE), min_sub);
        assert_eq!(quantize(min_sub * 0.49, f, RNE), 0.0);
        assert_eq!(quantize(-min_sub * 0.75, f, RNE), -min_sub);
        // 1.5 * min_sub ties between 1*min_sub and 2*min_sub → even (2).
        assert_eq!(quantize(min_sub * 1.5, f, RNE), 2.0 * min_sub);
    }

    #[test]
    fn gradual_underflow_precision_loss() {
        let f = FpFormat::new(5, 2);
        // At 2^-15 (one below min normal 2^-14) only 2 significand bits
        // remain: representables are {2^-16, 2^-15, 1.5*2^-15}.
        let x = 1.25 * 2f32.powi(-15);
        let q = quantize(x, f, RNE);
        assert!(q == 2f32.powi(-15) || q == 1.5 * 2f32.powi(-15));
        assert_eq!(quantize(1.75 * 2f32.powi(-15), f, RNE), 2f32.powi(-14));
    }

    #[test]
    fn idempotent_on_all_representables() {
        for fmt in [
            FpFormat::E5M2,
            FpFormat::E4M3,
            FpFormat::E3M0,
            FpFormat::new(2, 3),
            FpFormat::new(6, 1),
        ] {
            for v in fmt.enumerate_magnitudes() {
                assert_eq!(quantize(v, fmt, RNE).to_bits(), v.to_bits(), "{fmt} {v}");
                assert_eq!(quantize(-v, fmt, RNE), -v, "{fmt} -{v}");
            }
        }
    }

    #[test]
    fn rounds_to_nearest_exhaustive_small_format() {
        // For E3M1, check against a brute-force nearest search over the
        // enumerated representables for a dense sample of inputs.
        let fmt = FpFormat::new(3, 1);
        let reps = fmt.enumerate_magnitudes();
        let max = fmt.max_value() as f32;
        let mut x = -1.5 * max;
        while x < 1.5 * max {
            let q = quantize(x, fmt, RNE);
            let ax = x.abs();
            // brute force nearest (ignoring tie direction)
            let mut best = f32::INFINITY;
            let mut bd = f32::INFINITY;
            for &r in &reps {
                let d = (ax - r).abs();
                if d < bd {
                    bd = d;
                    best = r;
                }
            }
            if ax > max {
                // overflow region handled separately
                let ulp = 2f32.powi(fmt.max_exponent() - fmt.man_bits as i32);
                if ax - max > ulp / 2.0 {
                    assert!(q.is_infinite(), "x={x} q={q}");
                } else {
                    assert_eq!(q.abs(), max, "x={x}");
                }
            } else {
                assert!(
                    (q.abs() - best).abs() <= bd + 1e-12,
                    "x={x} q={q} best={best}"
                );
                if q != 0.0 {
                    assert_eq!(q.is_sign_negative(), x.is_sign_negative());
                }
            }
            x += max / 613.0; // irrational-ish step to hit odd points
        }
    }

    #[test]
    fn toward_zero_truncates() {
        let f = FpFormat::E5M2;
        assert_eq!(quantize(1.24, f, Rounding::TowardZero), 1.0);
        assert_eq!(quantize(-1.24, f, Rounding::TowardZero), -1.0);
        assert_eq!(quantize(1.26, f, Rounding::TowardZero), 1.25);
    }

    #[test]
    fn stochastic_is_bracketing_and_roughly_unbiased() {
        let f = FpFormat::E5M2;
        let x = 1.1f32; // between 1.0 and 1.25
        let mut sum = 0.0f64;
        let n = 20_000u64;
        for i in 0..n {
            let q = quantize(x, f, Rounding::Stochastic(splitmix64(i)));
            assert!(q == 1.0 || q == 1.25, "q={q}");
            sum += q as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.1).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn f32_subnormal_inputs() {
        // Tiny f32 subnormal inputs flush to zero in narrow formats…
        let f = FpFormat::E5M2;
        let tiny = f32::from_bits(1); // 2^-149
        assert_eq!(quantize(tiny, f, RNE), 0.0);
        // …and are the identity under (8,23).
        assert_eq!(quantize(tiny, FpFormat::FP32, RNE), tiny);
        // A wide-exponent narrow-mantissa format keeps their scale
        // (down to its own subnormal floor 2^-129 for (8,3)).
        let g = FpFormat::new(8, 3);
        let x = f32::from_bits(0x0040_0000); // 2^-127, inside (8,3) range
        let q = quantize(x, g, RNE);
        assert_eq!(q, x, "2^-127 is exactly representable in (8,3)");
        // below half the (8,3) subnormal floor → flushes to zero
        let y = f32::from_bits(0x0007_0000); // ≈ 2^-130.2 < 2^-129/2…
        assert_eq!(quantize(y, g, RNE), 0.0);
    }

    #[test]
    fn ceil_log2() {
        assert_eq!(ceil_log2_abs(1.0), Some(0));
        assert_eq!(ceil_log2_abs(2.0), Some(1));
        assert_eq!(ceil_log2_abs(3.0), Some(2));
        assert_eq!(ceil_log2_abs(0.5), Some(-1));
        assert_eq!(ceil_log2_abs(0.75), Some(0));
        assert_eq!(ceil_log2_abs(-5.0), Some(3));
        assert_eq!(ceil_log2_abs(0.0), None);
        assert_eq!(ceil_log2_abs(f32::INFINITY), None);
        // subnormal powers of two and non-powers
        assert_eq!(ceil_log2_abs(f32::from_bits(1)), Some(-149));
        // 3·2^-149: log2 = 1.585 - 149 = -147.4 → ceil = -147
        assert_eq!(ceil_log2_abs(f32::from_bits(3)), Some(-147));
    }

    #[test]
    fn bit_codes_roundtrip_every_representable() {
        // decode_bits(code_of_representable(v)) must be the identity on
        // every finite representable (both signs), ±INF, ±0 and NaN —
        // exhaustively for small formats, FP32-wide ones included.
        for fmt in [
            FpFormat::E5M2,
            FpFormat::E4M3,
            FpFormat::E3M0,
            FpFormat::new(2, 3),
            FpFormat::new(8, 3),
            FpFormat::new(6, 1),
        ] {
            let mut seen = std::collections::HashSet::new();
            for v in fmt.enumerate_magnitudes() {
                for s in [v, -v] {
                    let code = encode_bits(s, fmt, RNE);
                    assert!(code < 1u32 << fmt.total_bits(), "{fmt} {s:e}: code {code:#x}");
                    let back = decode_bits(code, fmt);
                    assert_eq!(back.to_bits(), s.to_bits(), "{fmt} {s:e} -> {code:#x} -> {back:e}");
                    seen.insert(code);
                }
            }
            // distinct (sign, magnitude) pairs get distinct codes
            // (±0 are two distinct codes, as in IEEE storage)
            assert_eq!(seen.len(), 2 * fmt.finite_magnitude_count() as usize);
            // specials
            assert_eq!(decode_bits(encode_bits(f32::INFINITY, fmt, RNE), fmt), f32::INFINITY);
            assert_eq!(
                decode_bits(encode_bits(f32::NEG_INFINITY, fmt, RNE), fmt),
                f32::NEG_INFINITY
            );
            if fmt.man_bits >= 1 {
                let n = decode_bits(encode_bits(f32::NAN, fmt, RNE), fmt);
                assert_eq!(n.to_bits(), f32::NAN.to_bits(), "{fmt}: NaN must stay canonical");
            }
        }
    }

    #[test]
    fn encode_bits_shares_quantize_rounding() {
        // decode(encode(x)) == quantize(x) for arbitrary (unrepresentable)
        // inputs — the code path rounds exactly like the value path.
        let fmt = FpFormat::E5M2;
        let mut x = -80000.0f32;
        while x < 80000.0 {
            let q = quantize(x, fmt, RNE);
            let via_code = decode_bits(encode_bits(x, fmt, RNE), fmt);
            assert_eq!(via_code.to_bits(), q.to_bits(), "x={x}");
            x += 13.7;
        }
    }

    #[test]
    fn bit_code_slice_kernels_match_scalar() {
        let xs: Vec<f32> = (0..500).map(|i| (i as f32 - 250.0) * 0.731).collect();
        let fmt = FpFormat::E4M3;
        let mut codes = vec![0u32; xs.len()];
        encode_bits_slice_into(&xs, fmt, RNE, &mut codes);
        let mut decoded = vec![0.0f32; xs.len()];
        decode_bits_slice_into(&codes, fmt, &mut decoded);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(codes[i], encode_bits(x, fmt, RNE));
            assert_eq!(decoded[i].to_bits(), quantize(x, fmt, RNE).to_bits());
        }
        // stochastic mode derives the same per-element draws as
        // quantize_slice_into, so codes and values agree
        let mut s_codes = vec![0u32; xs.len()];
        encode_bits_slice_into(&xs, fmt, Rounding::Stochastic(99), &mut s_codes);
        let mut s_vals = vec![0.0f32; xs.len()];
        quantize_slice_into(&xs, &mut s_vals, fmt, Rounding::Stochastic(99));
        for (i, &c) in s_codes.iter().enumerate() {
            assert_eq!(decode_bits(c, fmt).to_bits(), s_vals[i].to_bits(), "elem {i}");
        }
    }

    #[test]
    fn bit_codes_handle_f32_subnormal_range_formats() {
        // BF16's subnormals live below f32's normal floor; the extraction
        // must normalize f32-subnormal significands correctly.
        let fmt = FpFormat::BF16;
        for e in -133..=-120i32 {
            let v = (e as f64).exp2() as f32;
            let code = encode_bits(v, fmt, RNE);
            assert_eq!(decode_bits(code, fmt).to_bits(), v.to_bits(), "2^{e}");
            let code = encode_bits(-v, fmt, RNE);
            assert_eq!(decode_bits(code, fmt).to_bits(), (-v).to_bits(), "-2^{e}");
        }
    }

    #[test]
    fn quantize_slice_matches_scalar() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.37).collect();
        let f = FpFormat::E4M3;
        let out = quantize_slice(&xs, f, RNE);
        for (&x, &o) in xs.iter().zip(&out) {
            assert_eq!(o, quantize(x, f, RNE));
        }
        let mut inplace = xs.clone();
        quantize_slice_inplace(&mut inplace, f, RNE);
        assert_eq!(inplace, out);
    }
}

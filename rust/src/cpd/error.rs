//! Round-off error metrics (paper Eq. 5, Table 9).

/// The paper's average relative round-off error (Eq. 5):
///
/// `mean_i | (grad_h[i] - grad_l[i]) / grad_h[i] |`
///
/// Elements where the high-precision value is exactly zero are skipped
/// (the relative error is undefined there); non-finite low-precision
/// values count as 100% error per element, capped, so a diverged reduction
/// reads as a large-but-finite percentage as in Table 9.
pub fn avg_roundoff_error(grad_h: &[f32], grad_l: &[f32]) -> f64 {
    assert_eq!(grad_h.len(), grad_l.len());
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for (&h, &l) in grad_h.iter().zip(grad_l) {
        if h == 0.0 || !h.is_finite() {
            continue;
        }
        let rel = if l.is_finite() {
            (((h - l) as f64) / h as f64).abs()
        } else {
            1.0
        };
        sum += rel.min(1.0);
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Maximum relative round-off error over elements (same conventions).
pub fn max_roundoff_error(grad_h: &[f32], grad_l: &[f32]) -> f64 {
    assert_eq!(grad_h.len(), grad_l.len());
    let mut worst = 0.0f64;
    for (&h, &l) in grad_h.iter().zip(grad_l) {
        if h == 0.0 || !h.is_finite() {
            continue;
        }
        let rel = if l.is_finite() {
            (((h - l) as f64) / h as f64).abs().min(1.0)
        } else {
            1.0
        };
        worst = worst.max(rel);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_on_identical() {
        let a = [1.0f32, -2.0, 3.5];
        assert_eq!(avg_roundoff_error(&a, &a), 0.0);
        assert_eq!(max_roundoff_error(&a, &a), 0.0);
    }

    #[test]
    fn simple_relative_error() {
        let h = [2.0f32, 4.0];
        let l = [1.0f32, 4.0];
        assert!((avg_roundoff_error(&h, &l) - 0.25).abs() < 1e-12);
        assert!((max_roundoff_error(&h, &l) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn skips_zero_reference() {
        let h = [0.0f32, 2.0];
        let l = [5.0f32, 1.0];
        assert!((avg_roundoff_error(&h, &l) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn nonfinite_low_counts_as_full_error() {
        let h = [1.0f32, 1.0];
        let l = [f32::INFINITY, 1.0];
        assert!((avg_roundoff_error(&h, &l) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn error_capped_at_one() {
        let h = [0.001f32];
        let l = [100.0f32];
        assert_eq!(avg_roundoff_error(&h, &l), 1.0);
    }
}

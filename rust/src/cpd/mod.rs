//! CPD — the Customized-Precision Deep-learning substrate (paper §5).
//!
//! Everything the paper's CPD system provides, in Rust:
//!
//! * [`FpFormat`] — an arbitrary floating-point format with
//!   `exp_bits ∈ [2, 8]` and `man_bits ∈ [0, 23]`, IEEE-754-like layout
//!   (sign / biased exponent / mantissa, all-ones exponent reserved for
//!   `INF`/`NaN`, subnormals supported).
//! * [`cast`] — bit-exact FP32 → custom → FP32 quantization with
//!   round-to-nearest-even (the paper's choice, §4), plus toward-zero and
//!   stochastic rounding for comparison studies; `encode_bits` /
//!   `decode_bits` (and their bulk slice kernels) convert between values
//!   and the format's storage bit-codes for the packed wire path.
//! * [`accum`] — low-precision accumulators (every intermediate value is
//!   re-quantized, the behaviour in paper Fig 12) and the Kahan-compensated
//!   variant (paper §5.1.1).
//! * [`gemm`] — GEMM with a customized-precision accumulator, both naive
//!   and Kahan (paper §5.1, Fig 12).
//! * [`error`] — the average relative round-off error of Eq. 5.

pub mod accum;
pub mod cast;
pub mod error;
pub mod format;
pub mod gemm;

pub use accum::{KahanAccumulator, LowPrecisionAccumulator};
pub use cast::{
    ceil_log2_abs, decode_bits, decode_bits_slice_into, encode_bits, encode_bits_slice_into,
    quantize, quantize_shifted, quantize_shifted_slice, quantize_shifted_slice_into,
    quantize_slice, quantize_slice_inplace, quantize_slice_into, Rounding,
};
pub use error::{avg_roundoff_error, max_roundoff_error};
pub use format::FpFormat;

//! Arbitrary floating-point format descriptors (paper Table 1).
//!
//! A format is `(exp_bits, man_bits)` with an IEEE-754-like layout:
//! one sign bit, `exp_bits` biased-exponent bits (bias `2^(exp_bits-1)-1`,
//! all-ones exponent reserved for INF/NaN), `man_bits` mantissa bits, and
//! gradual underflow (subnormals). Every such format with `exp_bits ≤ 8`
//! and `man_bits ≤ 23` is a strict subset of IEEE FP32, which is what lets
//! CPD emulate it bit-exactly inside `f32` storage.

use std::fmt;

/// A customized floating-point format `(exp_bits, man_bits)`.
///
/// ```
/// use aps_cpd::cpd::FpFormat;
/// let e5m2 = FpFormat::new(5, 2);       // paper's 8-bit (exp:5, man:2)
/// assert_eq!(e5m2.total_bits(), 8);
/// assert_eq!(e5m2.max_exponent(), 15);  // values up to ~2^15 (Table 1)
/// assert_eq!(e5m2.min_subnormal_exponent(), -16); // down to 2^-16
/// ```
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Number of exponent bits, in `[2, 8]`.
    pub exp_bits: u8,
    /// Number of explicit mantissa bits, in `[0, 23]`.
    pub man_bits: u8,
}

impl FpFormat {
    /// IEEE 754 single precision (identity quantization).
    pub const FP32: FpFormat = FpFormat { exp_bits: 8, man_bits: 23 };
    /// IEEE 754 half precision.
    pub const FP16: FpFormat = FpFormat { exp_bits: 5, man_bits: 10 };
    /// bfloat16.
    pub const BF16: FpFormat = FpFormat { exp_bits: 8, man_bits: 7 };
    /// The 8-bit (exp:5, man:2) format used throughout the paper (≈E5M2).
    pub const E5M2: FpFormat = FpFormat { exp_bits: 5, man_bits: 2 };
    /// The 8-bit (exp:4, man:3) format used throughout the paper (≈E4M3,
    /// but with an IEEE-style INF, matching the paper's semantics).
    pub const E4M3: FpFormat = FpFormat { exp_bits: 4, man_bits: 3 };
    /// The 4-bit (exp:3, man:0) format of Table 4.
    pub const E3M0: FpFormat = FpFormat { exp_bits: 3, man_bits: 0 };
    /// The "FP16" of Wang et al. [27] (exp:6, man:9) from Table 1.
    pub const E6M9: FpFormat = FpFormat { exp_bits: 6, man_bits: 9 };

    /// Create a format, panicking on out-of-range bit counts.
    pub const fn new(exp_bits: u8, man_bits: u8) -> Self {
        assert!(exp_bits >= 2 && exp_bits <= 8, "exp_bits must be in [2, 8]");
        assert!(man_bits <= 23, "man_bits must be in [0, 23]");
        FpFormat { exp_bits, man_bits }
    }

    /// Total storage bits including the sign bit.
    pub const fn total_bits(&self) -> u32 {
        1 + self.exp_bits as u32 + self.man_bits as u32
    }

    /// Exponent bias: `2^(exp_bits-1) - 1`.
    pub const fn bias(&self) -> i32 {
        (1i32 << (self.exp_bits - 1)) - 1
    }

    /// Largest unbiased exponent of a *normal* number (all-ones exponent
    /// field is reserved for INF/NaN), i.e. the paper's `upper_bound_exp`
    /// from Algorithm 1 line 1.
    pub const fn max_exponent(&self) -> i32 {
        self.bias()
    }

    /// Smallest unbiased exponent of a normal number: `1 - bias`.
    pub const fn min_normal_exponent(&self) -> i32 {
        1 - self.bias()
    }

    /// Exponent of the smallest positive subnormal: `min_normal - man_bits`
    /// (with `man_bits = 0` there are no subnormals other than zero).
    pub const fn min_subnormal_exponent(&self) -> i32 {
        self.min_normal_exponent() - self.man_bits as i32
    }

    /// Largest finite representable magnitude: `(2 - 2^-man) * 2^max_exp`.
    pub fn max_value(&self) -> f64 {
        (2.0 - (-(self.man_bits as i32)).exp2()) * self.max_exponent().exp2()
    }

    /// Smallest positive normal magnitude: `2^min_normal_exponent`.
    pub fn min_normal(&self) -> f64 {
        self.min_normal_exponent().exp2()
    }

    /// Smallest positive (subnormal) magnitude: `2^min_subnormal_exponent`.
    pub fn min_subnormal(&self) -> f64 {
        self.min_subnormal_exponent().exp2()
    }

    /// Machine epsilon of the format: `2^-man_bits`.
    pub fn epsilon(&self) -> f64 {
        (-(self.man_bits as i32)).exp2()
    }

    /// True when quantizing to this format is the identity on finite `f32`.
    pub const fn is_fp32(&self) -> bool {
        self.exp_bits == 8 && self.man_bits == 23
    }

    /// The representable range as exponents `[min_subnormal, max]`, as the
    /// paper's Table 1 reports it (e.g. `(5, 2)` → `[-16, 15]`).
    pub const fn exponent_range(&self) -> (i32, i32) {
        (self.min_subnormal_exponent(), self.max_exponent())
    }

    /// Number of distinct finite non-negative values (for exhaustive tests).
    pub const fn finite_magnitude_count(&self) -> u32 {
        // subnormals (incl. zero) + normals
        let subnormals = 1u32 << self.man_bits;
        let normals = (((1u32 << self.exp_bits) - 2) as u32) << self.man_bits;
        subnormals + normals
    }

    /// Enumerate every finite non-negative representable value, ascending.
    /// Useful for exhaustive round-trip tests on small formats.
    pub fn enumerate_magnitudes(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.finite_magnitude_count() as usize);
        let man_count = 1u32 << self.man_bits;
        // subnormals: m * 2^(min_normal - man_bits), m in [0, 2^man)
        for m in 0..man_count {
            out.push((m as f64 * self.min_subnormal()) as f32);
        }
        // normals: (1 + m/2^man) * 2^e
        for e in self.min_normal_exponent()..=self.max_exponent() {
            let scale = (e as f64).exp2();
            for m in 0..man_count {
                out.push(((1.0 + m as f64 / man_count as f64) * scale) as f32);
            }
        }
        out
    }
}

/// `exp2` helper on i32 exponents (f64 has ample range for exp_bits ≤ 8).
trait Exp2 {
    fn exp2(self) -> f64;
}
impl Exp2 for i32 {
    fn exp2(self) -> f64 {
        (self as f64).exp2()
    }
}

impl fmt::Debug for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "E{}M{}", self.exp_bits, self.man_bits)
    }
}

impl fmt::Display for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "({}, {}): {}bits",
            self.exp_bits,
            self.man_bits,
            self.total_bits()
        )
    }
}

impl std::str::FromStr for FpFormat {
    type Err = String;

    /// Parse `"e5m2"`, `"E5M2"`, `"5,2"` or `"(5,2)"`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let t = s.trim().to_ascii_lowercase();
        let parse2 = |a: &str, b: &str| -> Result<FpFormat, String> {
            let e: u8 = a.trim().parse().map_err(|_| format!("bad exp bits in {s:?}"))?;
            let m: u8 = b.trim().parse().map_err(|_| format!("bad man bits in {s:?}"))?;
            if !(2..=8).contains(&e) || m > 23 {
                return Err(format!("format out of range: exp {e} man {m}"));
            }
            Ok(FpFormat::new(e, m))
        };
        if let Some(rest) = t.strip_prefix('e') {
            if let Some((e, m)) = rest.split_once('m') {
                return parse2(e, m);
            }
        }
        let t = t.trim_start_matches('(').trim_end_matches(')');
        if let Some((e, m)) = t.split_once(',') {
            return parse2(e, m);
        }
        match t.as_ref() {
            "fp32" | "f32" => Ok(FpFormat::FP32),
            "fp16" | "f16" => Ok(FpFormat::FP16),
            "bf16" | "bfloat16" => Ok(FpFormat::BF16),
            _ => Err(format!("unrecognized format {s:?} (try e5m2 or 5,2)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ranges() {
        // Paper Table 1: representable ranges [2^min_sub, 2^max_exp].
        assert_eq!(FpFormat::FP32.exponent_range(), (-149, 127));
        assert_eq!(FpFormat::FP16.exponent_range(), (-24, 15));
        assert_eq!(FpFormat::BF16.exponent_range(), (-133, 127));
        assert_eq!(FpFormat::E6M9.exponent_range(), (-39, 31));
        assert_eq!(FpFormat::E5M2.exponent_range(), (-16, 15));
    }

    #[test]
    fn bias_and_bounds() {
        let f = FpFormat::new(5, 2);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.max_exponent(), 15);
        assert_eq!(f.min_normal_exponent(), -14);
        assert_eq!(f.max_value(), 1.75 * (15f64).exp2());
        assert_eq!(f.min_subnormal(), (-16f64).exp2());
    }

    #[test]
    fn e3m0_degenerate_mantissa() {
        let f = FpFormat::E3M0;
        assert_eq!(f.total_bits(), 4);
        assert_eq!(f.bias(), 3);
        assert_eq!(f.max_exponent(), 3);
        // No mantissa bits: only subnormal value is zero.
        assert_eq!(f.min_subnormal_exponent(), f.min_normal_exponent());
        assert_eq!(f.max_value(), 8.0);
    }

    #[test]
    fn enumerate_counts() {
        let f = FpFormat::new(3, 1);
        let vals = f.enumerate_magnitudes();
        assert_eq!(vals.len(), f.finite_magnitude_count() as usize);
        // strictly ascending
        for w in vals.windows(2) {
            assert!(w[0] < w[1], "{:?}", w);
        }
        assert_eq!(vals[0], 0.0);
        assert_eq!(*vals.last().unwrap() as f64, f.max_value());
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["e5m2", "E4M3", "5,2", "(3, 0)", "fp16", "bf16", "fp32"] {
            let f: FpFormat = s.parse().unwrap();
            assert!(f.exp_bits >= 2);
        }
        assert!("e9m1".parse::<FpFormat>().is_err());
        assert!("e5m24".parse::<FpFormat>().is_err());
        assert!("garbage".parse::<FpFormat>().is_err());
    }

    #[test]
    fn display() {
        assert_eq!(FpFormat::E5M2.to_string(), "(5, 2): 8bits");
        assert_eq!(format!("{:?}", FpFormat::E4M3), "E4M3");
    }
}

//! GEMM with customized-precision accumulators (paper §5.1, Fig 12).
//!
//! Existing frameworks compute a dot product in FP32 and cast *once* at the
//! end (the "QPyTorch style" the paper criticizes in Fig 12). CPD instead
//! quantizes each product and each partial sum, exposing the accumulator
//! precision to the experimenter. Three accumulation strategies:
//!
//! * [`AccumStrategy::WideThenCast`] — FP32 dot product, single final cast
//!   (the baseline the paper says is numerically misleading).
//! * [`AccumStrategy::LowPrecision`] — every multiply result and running
//!   sum is quantized (faithful emulation).
//! * [`AccumStrategy::Kahan`] — like `LowPrecision` but with Kahan
//!   compensation (the paper's proposed remedy).

use super::accum::{KahanAccumulator, LowPrecisionAccumulator};
use super::cast::{quantize, Rounding};
use super::format::FpFormat;

/// How dot-product accumulation is performed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumStrategy {
    /// FP32 accumulation, quantize only the final result (QPyTorch-style).
    WideThenCast,
    /// Quantize each product and each partial sum (CPD default).
    LowPrecision,
    /// Low-precision Kahan-compensated accumulation.
    Kahan,
}

/// Dot product of two vectors under a custom-precision accumulator.
///
/// Inputs are first quantized to `fmt` (they would be stored in the custom
/// format in a real system); the accumulation then follows `strategy`.
pub fn dot(a: &[f32], b: &[f32], fmt: FpFormat, mode: Rounding, strategy: AccumStrategy) -> f32 {
    assert_eq!(a.len(), b.len());
    match strategy {
        AccumStrategy::WideThenCast => {
            let mut s = 0.0f32;
            for (&x, &y) in a.iter().zip(b) {
                let qx = quantize(x, fmt, mode);
                let qy = quantize(y, fmt, mode);
                s += qx * qy;
            }
            quantize(s, fmt, mode)
        }
        AccumStrategy::LowPrecision => {
            let mut acc = LowPrecisionAccumulator::new(fmt, mode);
            for (&x, &y) in a.iter().zip(b) {
                let qx = quantize(x, fmt, mode);
                let qy = quantize(y, fmt, mode);
                acc.add(qx * qy); // add() quantizes the product first
            }
            acc.value()
        }
        AccumStrategy::Kahan => {
            let mut acc = KahanAccumulator::new(fmt, mode);
            for (&x, &y) in a.iter().zip(b) {
                let qx = quantize(x, fmt, mode);
                let qy = quantize(y, fmt, mode);
                acc.add(qx * qy);
            }
            acc.value()
        }
    }
}

/// Row-major `m×k · k×n → m×n` GEMM with a custom-precision accumulator.
pub fn gemm(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    fmt: FpFormat,
    mode: Rounding,
    strategy: AccumStrategy,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    // Gather B columns to keep the inner loop contiguous.
    let mut col = vec![0.0f32; k];
    for j in 0..n {
        for (p, cv) in col.iter_mut().enumerate() {
            *cv = b[p * n + j];
        }
        for i in 0..m {
            let row = &a[i * k..(i + 1) * k];
            c[i * n + j] = dot(row, &col, fmt, mode, strategy);
        }
    }
    c
}

/// FP32 reference GEMM (row-major), for error measurement.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::error::avg_roundoff_error;
    const RNE: Rounding = Rounding::NearestEven;

    fn seq(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn fp32_strategies_agree_with_reference() {
        let a = seq(6, |i| i as f32 * 0.5 - 1.0);
        let b = seq(6, |i| 1.0 - i as f32 * 0.25);
        let c_ref = gemm_f32(&a, &b, 2, 3, 2);
        for s in [AccumStrategy::WideThenCast, AccumStrategy::LowPrecision, AccumStrategy::Kahan] {
            let c = gemm(&a, &b, 2, 3, 2, FpFormat::FP32, RNE, s);
            for (x, y) in c.iter().zip(&c_ref) {
                assert!((x - y).abs() < 1e-5, "{s:?}");
            }
        }
    }

    #[test]
    fn fig12_wide_cast_hides_accumulator_error() {
        // A long dot product of small terms: the wide accumulator gets the
        // right answer and casts once; the low-precision accumulator stalls
        // (paper Fig 12's point — the results genuinely differ).
        let f = FpFormat::new(4, 2);
        let a = vec![1.0f32; 256];
        let b = vec![0.5f32; 256];
        let wide = dot(&a, &b, f, RNE, AccumStrategy::WideThenCast);
        let low = dot(&a, &b, f, RNE, AccumStrategy::LowPrecision);
        // exact = 128; wide rounds 128 into the format (may saturate to max
        // or INF depending on range) but low stalls far earlier.
        assert!(low < wide, "low={low} wide={wide}");
    }

    #[test]
    fn kahan_improves_gemm_accuracy() {
        let f = FpFormat::E4M3;
        let m = 4;
        let k = 128;
        let n = 4;
        let a = seq(m * k, |i| ((i * 2654435761) % 1000) as f32 / 1000.0 - 0.5);
        let b = seq(k * n, |i| ((i * 40503) % 1000) as f32 / 1000.0 - 0.5);
        let c_ref = gemm_f32(&a, &b, m, k, n);
        let c_low = gemm(&a, &b, m, k, n, f, RNE, AccumStrategy::LowPrecision);
        let c_kah = gemm(&a, &b, m, k, n, f, RNE, AccumStrategy::Kahan);
        let e_low = avg_roundoff_error(&c_ref, &c_low);
        let e_kah = avg_roundoff_error(&c_ref, &c_kah);
        assert!(e_kah <= e_low, "kahan={e_kah} naive={e_low}");
    }

    #[test]
    fn gemm_shapes() {
        let a = vec![1.0; 3 * 5];
        let b = vec![1.0; 5 * 2];
        let c = gemm(&a, &b, 3, 5, 2, FpFormat::FP32, RNE, AccumStrategy::WideThenCast);
        assert_eq!(c.len(), 6);
        assert!(c.iter().all(|&x| x == 5.0));
    }
}

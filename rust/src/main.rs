//! `aps` — the launcher CLI for the APS/CPD system.
//!
//! Subcommands:
//! * `train --config <toml>` — run a distributed-training experiment.
//! * `formats [names…]` — print Table 1 (representable ranges).
//! * `comm [--world N]` — price gradient sync with the α–β model (Fig 11).
//! * `roundoff [--world N --format F]` — Table 9 round-off sweep.
//! * `gradshow --model M` — gradient exponent histograms (Figs 1–2).

use anyhow::Result;
use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::config::ExperimentConfig;
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::{avg_roundoff_error, FpFormat};
use aps_cpd::data::Rng;
use aps_cpd::metrics::ExpHistogram;
use aps_cpd::perfmodel::{fig11_table, NetworkModel};
use aps_cpd::runtime::Engine;
use aps_cpd::util::cli::Args;
use aps_cpd::util::table::Table;

const USAGE: &str = "\
aps — Auto-Precision Scaling for distributed deep learning

USAGE:
  aps train    --config <file.toml> [--artifacts DIR] [--log-every N]
  aps formats  [e5m2 e4m3 fp16 …]
  aps comm     [--world N]
  aps roundoff [--world N] [--format e5m2] [--elements N] [--seed S]
  aps gradshow --model NAME [--artifacts DIR] [--world N] [--warm-steps N]
";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => train(&args),
        Some("formats") => cmd_formats(&args.positional),
        Some("comm") => cmd_comm(args.get_usize("world", 32)?),
        Some("roundoff") => cmd_roundoff(&args),
        Some("gradshow") => cmd_gradshow(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn train(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_path(args.require("config")?)?;
    let artifacts = args.get("artifacts", "artifacts");
    let engine = Engine::cpu()?;
    eprintln!("PJRT platform: {}", engine.platform());
    let model = engine.load_model(&artifacts, &cfg.model)?;
    eprintln!(
        "model {} — {} params in {} tensors, local batch {}",
        model.spec.name,
        model.spec.total_params(),
        model.spec.params.len(),
        model.spec.batch
    );

    // The legacy SyncOptions.method mirrors the strategy when it has a
    // closed-enum name; the strategy override below is authoritative and
    // also carries codecs the enum cannot name (ternary, topk).
    let method = cfg.strategy.as_sync_method().unwrap_or(SyncMethod::Fp32);
    let sync = SyncOptions::new(method)
        .with_topology(cfg.topology)
        .with_kahan(cfg.kahan)
        .with_fp32_last_layer(cfg.fp32_last_layer);

    let mut setup = TrainerSetup::new(cfg.world_size, sync);
    setup.strategy = Some(cfg.strategy.clone());
    setup.wire = cfg.wire;
    setup.transport = cfg.transport;
    setup.bucket_bytes = cfg.bucket_bytes;
    setup.fold_threads = cfg.fold_threads;
    setup.encode_threads = cfg.encode_threads;
    setup.hybrid = cfg.hybrid;
    setup.optimizer = cfg.optimizer;
    setup.schedule = cfg.schedule.clone();
    setup.epochs = cfg.epochs;
    setup.steps_per_epoch = cfg.steps_per_epoch;
    setup.eval_examples = cfg.eval_examples;
    setup.track_roundoff = cfg.track_roundoff;
    setup.seed = cfg.seed;
    setup.log_every = args.get_usize("log-every", 10)?;

    let mut trainer = Trainer::new(&model, setup)?;
    let outcome = trainer.train(cfg.name.clone())?;

    println!("== {} ==", outcome.name);
    println!(
        "final {} = {:.4}",
        trainer.workload().metric_name(),
        outcome.final_metric
    );
    if let Some(macc) = outcome.final_macc {
        println!("final mAcc = {macc:.4}");
    }
    println!("steps = {}, wall = {:.1}s", outcome.steps_run, outcome.wall_secs);
    // payload is schedule-inclusive (ring/hierarchical moved bytes);
    // the packed figure is per gradient set — don't compare them as
    // compression ratio across rows with different collectives.
    println!(
        "comm/worker: collective payload {} KiB, exponent-phase {} B{}",
        outcome.comm_payload_bytes / 1024,
        outcome.comm_exponent_bytes,
        if outcome.diverged { "  [DIVERGED]" } else { "" }
    );
    println!(
        "codec wire (packed, per gradient set, whole run): {} KiB",
        outcome.comm_honest_bytes / 1024
    );
    if !outcome.roundoff.points.is_empty() {
        println!("mean Eq.5 round-off = {:.4}", outcome.mean_roundoff());
    }
    Ok(())
}

fn cmd_formats(names: &[String]) -> Result<()> {
    let list: Vec<FpFormat> = if names.is_empty() {
        vec![
            FpFormat::FP32,
            FpFormat::FP16,
            FpFormat::BF16,
            FpFormat::E6M9,
            FpFormat::E5M2,
            FpFormat::E4M3,
            FpFormat::E3M0,
        ]
    } else {
        names
            .iter()
            .map(|s| s.parse().map_err(|e: String| anyhow::anyhow!(e)))
            .collect::<Result<_>>()?
    };
    let mut t = Table::new(&["format", "exp bits", "man bits", "range"]);
    for f in list {
        let (lo, hi) = f.exponent_range();
        t.row(&[
            f.to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            format!("[2^{lo}, 2^{hi}]"),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_comm(world: usize) -> Result<()> {
    let rows = fig11_table(&NetworkModel::v100_nccl(), world);
    let mut t = Table::new(&["layer", "fp16 ms", "exp ms", "payload ms", "aps ms", "speedup"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.3}", r.fp16_ms),
            format!("{:.4}", r.aps_exp_phase_ms),
            format!("{:.3}", r.aps_payload_ms),
            format!("{:.3}", r.aps_total_ms),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_roundoff(args: &Args) -> Result<()> {
    let world = args.get_usize("world", 256)?;
    let fmt: FpFormat = args
        .get("format", "e5m2")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let elements = args.get_usize("elements", 4096)?;
    let seed = args.get_u64("seed", 42)?;

    let mut rng = Rng::new(seed);
    let contribs: Vec<Vec<f32>> = (0..world)
        .map(|_| (0..elements).map(|_| rng.normal() * 0.01).collect())
        .collect();
    let exact: Vec<f32> = (0..elements)
        .map(|i| contribs.iter().map(|c| c[i] as f64).sum::<f64>() as f32)
        .collect();
    let cluster = SimCluster::new(world);
    let mut t = Table::new(&["topology", "Eq.5 round-off"]);
    let mut groups: Vec<usize> = vec![4, 8, 16, 32, 64];
    groups.retain(|g| world % g == 0 && *g <= world);
    for g in groups {
        let (out, _) = cluster.all_reduce_sum(
            &contribs,
            Topology::Hierarchical { group_size: g },
            ReduceOptions::low_precision(fmt),
        );
        t.row(&[
            format!("hierarchical k={g}"),
            format!("{:.2}%", 100.0 * avg_roundoff_error(&exact, &out)),
        ]);
    }
    let (out, _) =
        cluster.all_reduce_sum(&contribs, Topology::Ring, ReduceOptions::low_precision(fmt));
    t.row(&[
        format!("ring ({world})"),
        format!("{:.2}%", 100.0 * avg_roundoff_error(&exact, &out)),
    ]);
    t.print();
    Ok(())
}

fn cmd_gradshow(args: &Args) -> Result<()> {
    let model_name = args.require("model")?;
    let artifacts = args.get("artifacts", "artifacts");
    let world = args.get_usize("world", 8)?;
    let warm_steps = args.get_usize("warm-steps", 5)?;

    let engine = Engine::cpu()?;
    let model = engine.load_model(&artifacts, &model_name)?;
    let sync = SyncOptions::new(SyncMethod::Fp32);
    let mut setup = TrainerSetup::new(world, sync);
    setup.epochs = 1;
    setup.steps_per_epoch = warm_steps;
    let mut trainer = Trainer::new(&model, setup)?;
    // A few warm steps so gradients are not at-init artifacts.
    let mut out = Default::default();
    for s in 0..warm_steps {
        trainer.step(0, s, &mut out)?;
    }
    let grads = trainer.snapshot_gradients(warm_steps)?;
    for (l, g) in grads.iter().enumerate() {
        let mut h = ExpHistogram::gradient_window();
        h.add_all(g);
        println!(
            "--- layer {l} ({}, {} elems, p50 2^{}) ---",
            model.spec.params[l].name,
            g.len(),
            h.percentile_exp(50.0)
        );
        print!("{}", h.ascii(40));
    }
    Ok(())
}

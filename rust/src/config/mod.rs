//! TOML experiment configuration (the launcher's input format).
//!
//! Every experiment in EXPERIMENTS.md is a config file under `configs/`
//! plus a seed; the CLI (`aps train --config …`) and the benches both go
//! through [`ExperimentConfig`] so runs are reproducible from the file
//! alone. Parsed with the in-crate TOML subset ([`crate::util::toml`]).
//! See `configs/quickstart.toml` for a commented example.

use crate::aps::{HybridSchedule, SyncMethod};
use crate::collectives::Topology;
use crate::cpd::FpFormat;
use crate::optim::{LrSchedule, OptimizerKind};
use crate::sync::{StrategySpec, TransportSpec, WireMode};
use crate::util::toml::TomlDoc;
use crate::Result;
use anyhow::{anyhow, Context};
use std::path::Path;

/// A full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Artifact name under `artifacts/` (mlp, davidnet, resnet, fcn,
    /// transformer).
    pub model: String,
    pub seed: u64,

    pub world_size: usize,
    pub topology: Topology,

    /// The synchronization strategy, parsed by name from `sync.method`
    /// (`fp32 | naive | loss_scaling | aps | ternary | topk | qsgd`, any
    /// of which may be wrapped in residual error feedback with an `ef:`
    /// prefix, e.g. `ef:topk`).
    pub strategy: StrategySpec,
    /// How the session materializes wire traffic (`sync.wire`:
    /// `packed | simulated`; packed — the default — moves bit-packed
    /// `WireCost` bytes through the simulated collectives).
    pub wire: WireMode,
    /// Which transport the overlapped path exchanges packed segments
    /// over (`sync.transport`: `in_process | shared_mem | tcp`; only
    /// meaningful with `wire = "packed"`).
    pub transport: TransportSpec,
    /// Bucket fusion threshold for `step_overlapped`, in honest wire
    /// bytes (`sync.bucket_bytes`; 0 — the default — picks an automatic
    /// size from the model's total traffic and the pool width).
    pub bucket_bytes: usize,
    /// Consumer-side (packed fold) thread count
    /// (`sync.threads = { fold = K, … }`, or the older flat
    /// `sync.fold_threads` spelling; 0 — the default — auto-sizes per
    /// layer). Feeds `SyncSessionBuilder::with_fold_threads`.
    pub fold_threads: usize,
    /// Producer-side (per-worker encode fan-out) thread count
    /// (`sync.threads = { encode = K, … }`, or flat
    /// `sync.encode_threads`; 0 — the default — auto-sizes per layer,
    /// 1 keeps the serial encode loop). Feeds
    /// `SyncSessionBuilder::with_encode_threads`.
    pub encode_threads: usize,
    pub kahan: bool,
    pub fp32_last_layer: bool,
    pub hybrid: Option<HybridSchedule>,

    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub schedule: LrSchedule,
    pub optimizer: OptimizerKind,
    pub eval_examples: usize,
    pub track_roundoff: bool,
}

impl ExperimentConfig {
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing config {path:?}"))
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;

        // [experiment]
        let name = doc.get("experiment", "name")?.as_str()?.to_string();
        let model = doc.get("experiment", "model")?.as_str()?.to_string();
        let seed = doc
            .opt("experiment", "seed")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(42) as u64;

        // [cluster]
        let world_size = doc.get("cluster", "world_size")?.as_usize()?;
        // `sync.topology` is the canonical spelling (the topology is a
        // property of gradient sync); `cluster.topology` stays accepted
        // for older configs and loses when both are present.
        let topo_name = doc
            .opt("sync", "topology")
            .or_else(|| doc.opt("cluster", "topology"))
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "ring".to_string());
        let group_size = doc
            .opt("cluster", "group_size")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(16);
        // [ps] — parameter-server shape, read only when selected.
        let ps_shards = doc
            .opt("ps", "shards")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(2);
        let ps_staleness = doc
            .opt("ps", "staleness")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(0);
        let topology = match topo_name.as_str() {
            "ring" => Topology::Ring,
            "hierarchical" => Topology::Hierarchical { group_size },
            "ps" => {
                if ps_shards == 0 {
                    return Err(anyhow!("ps.shards must be >= 1"));
                }
                Topology::Ps { shards: ps_shards, staleness: ps_staleness }
            }
            other => {
                return Err(anyhow!("unknown topology {other:?} (ring|hierarchical|ps)"))
            }
        };

        // [sync]
        let fmt: FpFormat = doc
            .opt("sync", "format")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "e5m2".to_string())
            .parse()
            .map_err(|e: String| anyhow!("sync.format: {e}"))?;
        let loss_scale_exp = doc
            .opt("sync", "loss_scale_exp")
            .map(|v| v.as_i64())
            .transpose()?
            .unwrap_or(0) as i32;
        let topk_frac = doc
            .opt("sync", "topk_frac")
            .map(|v| v.as_f32())
            .transpose()?
            .unwrap_or(0.25);
        let ternary_seed = doc
            .opt("sync", "ternary_seed")
            .map(|v| v.as_usize())
            .transpose()?
            .map(|s| s as u64)
            .unwrap_or(seed);
        let qsgd_bits = doc
            .opt("sync", "qsgd_bits")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(4);
        let qsgd_bucket = doc
            .opt("sync", "qsgd_bucket")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(256);

        // Codec names, with an optional `ef:` prefix wrapping the codec in
        // residual error feedback (sync::ErrorFeedback). The prefix is
        // stripped exactly once, so `ef:ef:…` falls through to the
        // unknown-method arm.
        let method_name = doc.get("sync", "method")?.as_str()?;
        let (base_name, wrap_ef) = match method_name.strip_prefix("ef:") {
            Some(inner) => (inner, true),
            None => (method_name, false),
        };
        let base = match base_name {
            "fp32" => StrategySpec::Fp32,
            "naive" => StrategySpec::Naive { fmt },
            "loss_scaling" => StrategySpec::LossScaling { fmt, factor_exp: loss_scale_exp },
            "aps" => StrategySpec::Aps { fmt },
            "ternary" => StrategySpec::Ternary { seed: ternary_seed },
            "topk" => {
                if topk_frac <= 0.0 || topk_frac > 1.0 {
                    return Err(anyhow!("sync.topk_frac must be in (0, 1], got {topk_frac}"));
                }
                StrategySpec::TopK { frac: topk_frac }
            }
            "qsgd" => {
                if !(2..=8).contains(&qsgd_bits) {
                    return Err(anyhow!("sync.qsgd_bits must be in 2..=8, got {qsgd_bits}"));
                }
                if qsgd_bucket == 0 {
                    return Err(anyhow!("sync.qsgd_bucket must be positive"));
                }
                StrategySpec::Qsgd {
                    bits: qsgd_bits as u8,
                    bucket: qsgd_bucket,
                    seed: ternary_seed,
                }
            }
            other => {
                return Err(anyhow!(
                    "unknown sync.method {other:?} \
                     (fp32|naive|loss_scaling|aps|ternary|topk|qsgd, optional ef: prefix)"
                ))
            }
        };
        let strategy = if wrap_ef {
            StrategySpec::ErrorFeedback { inner: Box::new(base) }
        } else {
            base
        };
        let wire = match doc
            .opt("sync", "wire")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "packed".to_string())
            .as_str()
        {
            "packed" => WireMode::Packed,
            "simulated" => WireMode::Simulated,
            other => return Err(anyhow!("unknown sync.wire {other:?} (packed|simulated)")),
        };
        let transport_name = doc
            .opt("sync", "transport")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "in_process".to_string());
        let transport = TransportSpec::parse(&transport_name).ok_or_else(|| {
            anyhow!("unknown sync.transport {transport_name:?} (in_process|shared_mem|tcp)")
        })?;
        let bucket_bytes = doc
            .opt("sync", "bucket_bytes")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(0);
        // `sync.threads = { fold = K, encode = K }` is the canonical
        // spelling for the session's two thread budgets; the flat
        // `sync.fold_threads` / `sync.encode_threads` keys stay accepted
        // as aliases for older configs and lose when the table names the
        // same side.
        let mut fold_threads = doc
            .opt("sync", "fold_threads")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(0);
        let mut encode_threads = doc
            .opt("sync", "encode_threads")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(0);
        if let Some(v) = doc.opt("sync", "threads") {
            let table = v.as_table().map_err(|e| anyhow!("sync.threads: {e}"))?;
            for (key, val) in table {
                let n = val
                    .as_usize()
                    .map_err(|e| anyhow!("sync.threads.{key}: {e}"))?;
                match key.as_str() {
                    "fold" => fold_threads = n,
                    "encode" => encode_threads = n,
                    other => {
                        return Err(anyhow!(
                            "unknown sync.threads key {other:?} (fold|encode)"
                        ))
                    }
                }
            }
        }
        let kahan = doc.opt("sync", "kahan").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
        let fp32_last_layer = doc
            .opt("sync", "fp32_last_layer")
            .map(|v| v.as_bool())
            .transpose()?
            .unwrap_or(false);
        let hybrid_fp32_epochs = doc
            .opt("sync", "hybrid_fp32_epochs")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(0);
        let hybrid = if hybrid_fp32_epochs > 0 {
            // `low` mirrors the strategy when it has a legacy method name;
            // for codecs outside the closed enum the trainer's strategy
            // override carries the real low-precision codec and `low` is
            // never consulted.
            let low = strategy.as_sync_method().unwrap_or(SyncMethod::Fp32);
            Some(HybridSchedule { fp32_epochs: hybrid_fp32_epochs, low })
        } else {
            None
        };

        // [train]
        let epochs = doc.get("train", "epochs")?.as_usize()?;
        let steps_per_epoch = doc.get("train", "steps_per_epoch")?.as_usize()?;
        let constant_lr = doc
            .opt("train", "constant_lr")
            .map(|v| v.as_f32())
            .transpose()?
            .unwrap_or(0.1);
        let schedule = match doc
            .opt("train", "lr_schedule")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "constant".to_string())
            .as_str()
        {
            "davidnet" => LrSchedule::davidnet_recipe(epochs as f32),
            "resnet18" => LrSchedule::resnet18_recipe(),
            "constant" => LrSchedule::Constant { lr: constant_lr },
            other => return Err(anyhow!("unknown lr_schedule {other:?}")),
        };
        let momentum = doc
            .opt("train", "momentum")
            .map(|v| v.as_f32())
            .transpose()?
            .unwrap_or(0.9);
        let weight_decay = doc
            .opt("train", "weight_decay")
            .map(|v| v.as_f32())
            .transpose()?
            .unwrap_or(1e-4);
        let optimizer = match doc
            .opt("train", "optimizer")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "sgd".to_string())
            .as_str()
        {
            "sgd" => OptimizerKind::Sgd { momentum, weight_decay, nesterov: false },
            "nesterov" => OptimizerKind::Sgd { momentum, weight_decay, nesterov: true },
            "lars" => OptimizerKind::Lars {
                momentum,
                weight_decay,
                eta: 0.001,
                epsilon: 1e-9,
            },
            other => return Err(anyhow!("unknown optimizer {other:?}")),
        };
        let eval_examples = doc
            .opt("train", "eval_examples")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(256);
        let track_roundoff = doc
            .opt("train", "track_roundoff")
            .map(|v| v.as_bool())
            .transpose()?
            .unwrap_or(false);

        Ok(ExperimentConfig {
            name,
            model,
            seed,
            world_size,
            topology,
            strategy,
            wire,
            transport,
            bucket_bytes,
            fold_threads,
            encode_threads,
            kahan,
            fp32_last_layer,
            hybrid,
            epochs,
            steps_per_epoch,
            schedule,
            optimizer,
            eval_examples,
            track_roundoff,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[experiment]
name = "test"
model = "mlp"
seed = 7

[cluster]
world_size = 8
topology = "hierarchical"
group_size = 4

[sync]
method = "aps"
format = "e4m3"
kahan = true

[train]
epochs = 2
steps_per_epoch = 5
lr_schedule = "constant"
constant_lr = 0.05
optimizer = "nesterov"
"#;

    #[test]
    fn parse_full_config() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.topology, Topology::Hierarchical { group_size: 4 });
        assert_eq!(cfg.strategy, StrategySpec::Aps { fmt: FpFormat::E4M3 });
        assert!(cfg.kahan);
        assert!(cfg.hybrid.is_none());
        match cfg.optimizer {
            OptimizerKind::Sgd { nesterov, .. } => assert!(nesterov),
            _ => panic!("expected sgd"),
        }
        assert_eq!(cfg.schedule, LrSchedule::Constant { lr: 0.05 });
    }

    #[test]
    fn defaults_fill_in() {
        let minimal = r#"
[experiment]
name = "m"
model = "mlp"
[cluster]
world_size = 4
[sync]
method = "fp32"
[train]
epochs = 1
steps_per_epoch = 2
"#;
        let cfg = ExperimentConfig::from_toml_str(minimal).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.topology, Topology::Ring);
        assert_eq!(cfg.strategy, StrategySpec::Fp32);
        assert_eq!(cfg.eval_examples, 256);
        assert!(!cfg.track_roundoff);
    }

    #[test]
    fn bad_values_error() {
        let bad_topo = SAMPLE.replace("hierarchical", "mesh");
        assert!(ExperimentConfig::from_toml_str(&bad_topo).is_err());
        let bad_method = SAMPLE.replace("\"aps\"", "\"magic\"");
        assert!(ExperimentConfig::from_toml_str(&bad_method).is_err());
        let bad_fmt = SAMPLE.replace("e4m3", "e99m1");
        assert!(ExperimentConfig::from_toml_str(&bad_fmt).is_err());
    }

    #[test]
    fn ps_topology_parses_with_knobs_and_defaults() {
        // `sync.topology` is canonical and wins over `cluster.topology`
        // (SAMPLE says hierarchical there).
        let ps = SAMPLE.replace("kahan = true", "kahan = true\ntopology = \"ps\"");
        let cfg = ExperimentConfig::from_toml_str(&ps).unwrap();
        assert_eq!(
            cfg.topology,
            Topology::Ps { shards: 2, staleness: 0 },
            "defaults: 2 shards, fully synchronous"
        );

        // The legacy cluster-section spelling still selects PS, and the
        // [ps] section supplies the shape.
        let ps = SAMPLE
            .replace("topology = \"hierarchical\"", "topology = \"ps\"")
            .replace("group_size = 4", "group_size = 4\n\n[ps]\nshards = 8\nstaleness = 3");
        let cfg = ExperimentConfig::from_toml_str(&ps).unwrap();
        assert_eq!(cfg.topology, Topology::Ps { shards: 8, staleness: 3 });

        let bad = SAMPLE
            .replace("topology = \"hierarchical\"", "topology = \"ps\"")
            .replace("group_size = 4", "group_size = 4\n\n[ps]\nshards = 0");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err(), "zero shards must error");
    }

    #[test]
    fn hybrid_parses() {
        let with_hybrid = SAMPLE.replace("kahan = true", "kahan = true\nhybrid_fp32_epochs = 3");
        let cfg = ExperimentConfig::from_toml_str(&with_hybrid).unwrap();
        let h = cfg.hybrid.unwrap();
        assert_eq!(h.fp32_epochs, 3);
        assert_eq!(h.method_at(2), SyncMethod::Fp32);
        assert_eq!(h.method_at(3), SyncMethod::Aps { fmt: FpFormat::E4M3 });
    }

    #[test]
    fn loss_scaling_config() {
        let ls = SAMPLE
            .replace("method = \"aps\"", "method = \"loss_scaling\"\nloss_scale_exp = 12");
        let cfg = ExperimentConfig::from_toml_str(&ls).unwrap();
        assert_eq!(
            cfg.strategy,
            StrategySpec::LossScaling { fmt: FpFormat::E4M3, factor_exp: 12 }
        );
    }

    #[test]
    fn ternary_and_topk_parse_by_name() {
        let t = SAMPLE.replace("method = \"aps\"", "method = \"ternary\"");
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        // ternary seed defaults to the experiment seed
        assert_eq!(cfg.strategy, StrategySpec::Ternary { seed: 7 });

        let t = SAMPLE.replace("method = \"aps\"", "method = \"ternary\"\nternary_seed = 99");
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        assert_eq!(cfg.strategy, StrategySpec::Ternary { seed: 99 });

        let k = SAMPLE.replace("method = \"aps\"", "method = \"topk\"\ntopk_frac = 0.1");
        let cfg = ExperimentConfig::from_toml_str(&k).unwrap();
        assert_eq!(cfg.strategy, StrategySpec::TopK { frac: 0.1 });

        let bad = SAMPLE.replace("method = \"aps\"", "method = \"topk\"\ntopk_frac = 1.5");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
    }

    #[test]
    fn qsgd_parses_with_knobs_and_defaults() {
        let q = SAMPLE.replace("method = \"aps\"", "method = \"qsgd\"");
        let cfg = ExperimentConfig::from_toml_str(&q).unwrap();
        assert_eq!(cfg.strategy, StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 7 });

        let q = SAMPLE.replace(
            "method = \"aps\"",
            "method = \"qsgd\"\nqsgd_bits = 2\nqsgd_bucket = 64",
        );
        let cfg = ExperimentConfig::from_toml_str(&q).unwrap();
        assert_eq!(cfg.strategy, StrategySpec::Qsgd { bits: 2, bucket: 64, seed: 7 });

        let bad = SAMPLE.replace("method = \"aps\"", "method = \"qsgd\"\nqsgd_bits = 9");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
        let bad = SAMPLE.replace("method = \"aps\"", "method = \"qsgd\"\nqsgd_bucket = 0");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
    }

    #[test]
    fn wire_mode_parses_and_defaults_to_packed() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.wire, WireMode::Packed, "packed is the default");
        let sim = SAMPLE.replace("kahan = true", "kahan = true\nwire = \"simulated\"");
        let cfg = ExperimentConfig::from_toml_str(&sim).unwrap();
        assert_eq!(cfg.wire, WireMode::Simulated);
        let explicit = SAMPLE.replace("kahan = true", "kahan = true\nwire = \"packed\"");
        let cfg = ExperimentConfig::from_toml_str(&explicit).unwrap();
        assert_eq!(cfg.wire, WireMode::Packed);
        let bad = SAMPLE.replace("kahan = true", "kahan = true\nwire = \"telepathy\"");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
    }

    #[test]
    fn transport_parses_and_defaults_to_in_process() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.transport, TransportSpec::InProcess, "in-process is the default");
        assert_eq!(cfg.bucket_bytes, 0, "bucket size defaults to auto");
        for (name, want) in [
            ("in_process", TransportSpec::InProcess),
            ("shm", TransportSpec::SharedMem),
            ("shared_mem", TransportSpec::SharedMem),
            ("tcp", TransportSpec::Tcp),
        ] {
            let t = SAMPLE
                .replace("kahan = true", &format!("kahan = true\ntransport = \"{name}\""));
            let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
            assert_eq!(cfg.transport, want, "{name}");
        }
        let bb = SAMPLE.replace("kahan = true", "kahan = true\nbucket_bytes = 65536");
        let cfg = ExperimentConfig::from_toml_str(&bb).unwrap();
        assert_eq!(cfg.bucket_bytes, 65536);
        let bad = SAMPLE.replace("kahan = true", "kahan = true\ntransport = \"carrier_pigeon\"");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
    }

    #[test]
    fn thread_budgets_parse_table_and_flat_aliases() {
        // Defaults: both sides auto-size.
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!((cfg.fold_threads, cfg.encode_threads), (0, 0));

        // Canonical inline-table spelling, either side or both.
        let t = SAMPLE
            .replace("kahan = true", "kahan = true\nthreads = { fold = 4, encode = 2 }");
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        assert_eq!((cfg.fold_threads, cfg.encode_threads), (4, 2));
        let t = SAMPLE.replace("kahan = true", "kahan = true\nthreads = { encode = 8 }");
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        assert_eq!((cfg.fold_threads, cfg.encode_threads), (0, 8));

        // The flat aliases still parse…
        let t = SAMPLE
            .replace("kahan = true", "kahan = true\nfold_threads = 3\nencode_threads = 5");
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        assert_eq!((cfg.fold_threads, cfg.encode_threads), (3, 5));

        // …and lose to the table when it names the same side, while an
        // un-named side keeps the alias value.
        let t = SAMPLE.replace(
            "kahan = true",
            "kahan = true\nfold_threads = 3\nencode_threads = 5\nthreads = { encode = 1 }",
        );
        let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
        assert_eq!((cfg.fold_threads, cfg.encode_threads), (3, 1));

        // Unknown table keys and non-integer values error loudly.
        let bad = SAMPLE.replace("kahan = true", "kahan = true\nthreads = { decode = 4 }");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
        let bad = SAMPLE.replace("kahan = true", "kahan = true\nthreads = { fold = \"all\" }");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
        let bad = SAMPLE.replace("kahan = true", "kahan = true\nthreads = 4");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err(), "scalar threads must error");
    }

    #[test]
    fn ef_qsgd_label_round_trips_the_knobs() {
        // Config → spec → label must carry the qsgd bits/bucket knobs
        // through the ef: wrapper, so bench/table rows stay attributable
        // to the exact configuration that produced them.
        let q = SAMPLE.replace(
            "method = \"aps\"",
            "method = \"ef:qsgd\"\nqsgd_bits = 5\nqsgd_bucket = 64",
        );
        let cfg = ExperimentConfig::from_toml_str(&q).unwrap();
        assert_eq!(
            cfg.strategy,
            StrategySpec::ErrorFeedback {
                inner: Box::new(StrategySpec::Qsgd { bits: 5, bucket: 64, seed: 7 })
            }
        );
        assert_eq!(cfg.strategy.label(), "ef:qsgd b5/64");
        // and unwrapped, for completeness
        let q = SAMPLE.replace(
            "method = \"aps\"",
            "method = \"qsgd\"\nqsgd_bits = 3\nqsgd_bucket = 128",
        );
        let cfg = ExperimentConfig::from_toml_str(&q).unwrap();
        assert_eq!(cfg.strategy.label(), "qsgd b3/128");
    }

    #[test]
    fn ef_prefix_wraps_any_codec() {
        for (name, want) in [
            ("ef:ternary", StrategySpec::Ternary { seed: 7 }),
            ("ef:topk", StrategySpec::TopK { frac: 0.25 }),
            ("ef:qsgd", StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 7 }),
            ("ef:aps", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ] {
            let t = SAMPLE.replace("method = \"aps\"", &format!("method = \"{name}\""));
            let cfg = ExperimentConfig::from_toml_str(&t).unwrap();
            assert_eq!(
                cfg.strategy,
                StrategySpec::ErrorFeedback { inner: Box::new(want) },
                "{name}"
            );
            // ef-wrapped codecs have no closed-enum method; the trainer's
            // strategy override carries them.
            assert_eq!(cfg.strategy.as_sync_method(), None);
        }
        let bad = SAMPLE.replace("method = \"aps\"", "method = \"ef:ef:fp32\"");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
        let bad = SAMPLE.replace("method = \"aps\"", "method = \"ef:magic\"");
        assert!(ExperimentConfig::from_toml_str(&bad).is_err());
    }
}

//! Steady-state allocation test for the session hot path.
//!
//! A `SyncSession` promises no per-step element-storage allocation once
//! its buffers are warm, and that promise now extends through
//! `HierarchicalCollective` (per-group partials in reusable scratch),
//! `ErrorFeedback` (residual and reconstruction buffers), the packed
//! wire path (per-worker `PackedWire` byte buffers, the shared encode
//! stage and the unpack chunk are all session-owned — and packed is the
//! session default, so the ring/hierarchical cases below pin it), and
//! Kahan-compensated reductions (compensation now lives in stack blocks
//! inside the fold kernels — the formerly ROADMAP-tracked per-call
//! vectors are gone, pinned by the `with_kahan(true)` cases). This
//! binary installs a byte-counting global allocator and pins the
//! promise: after a warmup, several steps together must allocate less
//! than a small pointer-bookkeeping budget — orders of magnitude below
//! one gradient tensor.
//!
//! Everything runs inside a single `#[test]` so no concurrently-running
//! test can pollute the counter. The single-threaded cases keep tensor
//! sizes below the parallelism threshold so the collectives spawn no
//! threads (the default auto encode pool also stays inline there); the
//! parallel packed-fold and parallel-encode cases at the end run
//! `with_fold_threads(4)` / `with_encode_threads(4)` on a larger model
//! under a budget that admits per-step thread-spawn bookkeeping
//! (`std::thread` allocates a few hundred bytes per spawn) but stays far
//! below one element buffer — pinning that the per-thread unpack chunks
//! and the per-worker encode-twin lanes (stages, residuals, top-k
//! selection scratch) are session-owned, not re-allocated per step.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::sync::{StrategySpec, SyncSession, SyncSessionBuilder, WireMode};

struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            ALLOCATED.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn grads(world: usize, salt: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &n)| {
                    (0..n)
                        .map(|i| ((w * 31 + l * 7 + i * 13 + salt) % 17) as f32 * 0.125 - 1.0)
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// Warm `session` on four inputs, then measure the bytes allocated by
/// four further steps and assert they stay under `budget`.
fn assert_steady_state(label: &str, mut session: SyncSession, layers: &[usize], budget: u64) {
    let world = session.world_size();
    // Pre-build every input so the measured window contains only step().
    let inputs: Vec<_> = (0..8).map(|salt| grads(world, salt, layers)).collect();
    for g in inputs.iter().take(4) {
        let _ = session.step(g);
    }
    let before = ALLOCATED.load(Ordering::SeqCst);
    for g in inputs.iter().skip(4) {
        let (reduced, report) = session.step(g);
        // keep the results observable so nothing is optimized away
        assert!(reduced[0][0].is_finite());
        assert!(report.layers.len() == layers.len());
    }
    let delta = ALLOCATED.load(Ordering::SeqCst) - before;
    let element_bytes: u64 = layers.iter().map(|&n| n as u64 * 4).sum();
    assert!(
        delta < budget,
        "{label}: steady-state steps allocated {delta} B (budget {budget} B; one \
         gradient set is {element_bytes} B) — an element buffer is being reallocated per step"
    );
}

#[test]
fn steady_state_steps_allocate_no_element_storage() {
    let world = 8;
    // n·world stays under par::PAR_THRESHOLD (16 Ki elements) per layer.
    let layers = [1024usize, 512, 96];
    // One gradient set is ~6.4 KiB per worker; the pointer-bookkeeping
    // budget for 4 steps sits far below a single layer buffer (the old
    // per-call hierarchical partials alone allocated ~13 KiB per step).
    let budget = 12 * 1024;

    // Ring, APS: the baseline hot path.
    assert_steady_state(
        "ring/aps",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .build(),
        &layers,
        budget,
    );

    // Hierarchical, APS: pins the ROADMAP fix — per-group partials must
    // come from the collective's reusable scratch, not fresh vectors.
    assert_steady_state(
        "hierarchical/aps",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_topology(Topology::Hierarchical { group_size: 4 })
            .build(),
        &layers,
        budget,
    );

    // Hierarchical, error-feedback-wrapped top-k: the new subsystem obeys
    // the same contract once residual buffers are warm.
    assert_steady_state(
        "hierarchical/ef:topk",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::ErrorFeedback {
                inner: Box::new(StrategySpec::TopK { frac: 0.25 }),
            })
            .with_topology(Topology::Hierarchical { group_size: 4 })
            .build(),
        &layers,
        budget,
    );

    // Kahan-compensated sessions, both topologies: pins the closed
    // ROADMAP item — compensation used to allocate one n-element vector
    // per reduce call (~26 KiB/step here), which would blow this budget.
    assert_steady_state(
        "ring/aps+kahan",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_kahan(true)
            .build(),
        &layers,
        budget,
    );
    assert_steady_state(
        "hierarchical/aps+kahan",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_kahan(true)
            .with_topology(Topology::Hierarchical { group_size: 4 })
            .build(),
        &layers,
        budget,
    );

    // Top-k sparsification: the selection now runs on session-owned
    // (|value|, index) scratch — one fill + one select per encode, no
    // per-call temporaries. A per-encode scratch rebuild (8 B x 1024
    // elements x 8 workers x 4 steps) would blow this budget ~20x over.
    assert_steady_state(
        "ring/topk",
        SyncSessionBuilder::new(world).spec(StrategySpec::TopK { frac: 0.25 }).build(),
        &layers,
        budget,
    );

    // The legacy simulated wire keeps the same guarantee (packed is the
    // default above; this pins the explicit opt-out too).
    assert_steady_state(
        "ring/aps simulated-wire",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_wire(WireMode::Simulated)
            .build(),
        &layers,
        budget,
    );

    // Parallel packed fold, both collectives: with `with_fold_threads(4)`
    // every layer takes the parallel entry points, so the measured window
    // covers the per-thread unpack chunks and (hierarchical) per-group
    // partials. Those are session-owned and warm after the warmup steps;
    // the only per-step allocation left is thread-spawn bookkeeping
    // (~12 spawns/step here) plus the waived O(world) slice vectors. The
    // budget sits above that but far below the 80 KB head layer — a
    // per-step re-allocation of the 4 KiB-per-thread unpack chunks alone
    // (4 threads x 3 layers x 4 steps) would blow it several times over.
    let par_layers = [20_000usize, 512, 96];
    let par_budget = 48 * 1024;
    assert_steady_state(
        "ring/aps parallel-fold",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_fold_threads(4)
            .build(),
        &par_layers,
        par_budget,
    );
    assert_steady_state(
        "hierarchical/ternary parallel-fold",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 5 })
            .with_fold_threads(4)
            .with_topology(Topology::Hierarchical { group_size: 4 })
            .build(),
        &par_layers,
        par_budget,
    );

    // Parallel encode fan-out, forced 4-way (fold kept single-threaded
    // so the window isolates the producer side): every layer takes the
    // twin-lane entry points, so the measured steps cover the per-lane
    // stage buffers and the twins' own scratch (error-feedback residuals,
    // top-k selection pairs). All of it is session-owned and warm after
    // warmup; what remains per step is encode-side thread-spawn
    // bookkeeping (12 spawns/step here), the same order the parallel-fold
    // cases above admit.
    assert_steady_state(
        "ring/aps parallel-encode",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_encode_threads(4)
            .with_fold_threads(1)
            .build(),
        &par_layers,
        par_budget,
    );
    assert_steady_state(
        "ring/ef:topk parallel-encode",
        SyncSessionBuilder::new(world)
            .spec(StrategySpec::ErrorFeedback {
                inner: Box::new(StrategySpec::TopK { frac: 0.25 }),
            })
            .with_encode_threads(4)
            .with_fold_threads(1)
            .build(),
        &par_layers,
        par_budget,
    );
}

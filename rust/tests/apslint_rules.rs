//! Tier-1: apslint rule semantics, waiver handling, and the whole-repo
//! gate — plus the schedule-permutation determinism check that backs the
//! `nondeterminism` waivers on `num_threads()` call sites.
//!
//! Each rule gets a fire / no-fire / waived fixture triple so a rule that
//! silently stops matching (or starts over-matching) fails here before it
//! fails in CI review. The whole-repo smoke runs the real binary's code
//! path (`lint::run` + `Config::repo_default()`) and asserts the tree
//! stays clean: zero unwaived diagnostics.

use std::path::Path;

use aps_cpd::lint::{self, check_source, Config, HotSpec, Severity};
use aps_cpd::util::par;

/// Config with one hot function `step` in files ending `sync/hot.rs`.
fn hot_cfg() -> Config {
    Config {
        hot: vec![HotSpec {
            file_suffix: "sync/hot.rs".to_string(),
            functions: vec!["step".to_string()],
        }],
        nd_path_fragments: vec![],
        nd_fn_prefixes: vec![],
    }
}

/// Config with nd scope: `encode*` functions under `sync/`.
fn nd_cfg() -> Config {
    Config {
        hot: vec![],
        nd_path_fragments: vec!["sync/".to_string()],
        nd_fn_prefixes: vec!["encode".to_string()],
    }
}

fn fatal_rules(path: &str, src: &str, cfg: &Config) -> Vec<&'static str> {
    check_source(path, src, cfg)
        .iter()
        .filter(|d| d.is_fatal())
        .map(|d| d.rule)
        .collect()
}

// ---- alloc_in_hot_path ------------------------------------------------

#[test]
fn alloc_fires_in_hot_fn() {
    let src = "fn step() { let v: Vec<u8> = Vec::new(); drop(v); }\n";
    assert_eq!(fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()), ["alloc_in_hot_path"]);
}

#[test]
fn alloc_silent_outside_hot_fn() {
    let src = "fn setup() { let v: Vec<u8> = Vec::new(); drop(v); }\n";
    assert!(fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()).is_empty());
}

#[test]
fn alloc_waiver_downgrades_to_waived() {
    let src = "fn step() {\n\
               // apslint: allow(alloc_in_hot_path) -- warmup only\n\
               let v: Vec<u8> = Vec::new(); drop(v); }\n";
    let diags = check_source("rust/src/sync/hot.rs", src, &hot_cfg());
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].rule, "alloc_in_hot_path");
    assert_eq!(diags[0].waived.as_deref(), Some("warmup only"));
    assert!(!diags[0].is_fatal());
}

#[test]
fn alloc_ignores_test_code() {
    let src = "#[cfg(test)]\nmod tests {\n    fn step() { let v = vec![1u8]; drop(v); }\n}\n";
    assert!(fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()).is_empty());
}

// ---- wire_honesty -----------------------------------------------------

const DISHONEST_IMPL: &str = "\
impl SyncStrategy for TopK {
    fn wire_cost(&self, n: usize) -> u64 { n as u64 }
    fn encode(&self, xs: &[f32]) -> Vec<f32> { xs.to_vec() }
}
";

const HONEST_IMPL: &str = "\
impl SyncStrategy for TopK {
    fn wire_cost(&self, n: usize) -> u64 { n as u64 }
    fn encode_packed(&self, xs: &[f32]) -> PackedWire { todo!() }
    fn decode_packed(&self, w: &PackedWire, out: &mut [f32]) {}
}
";

#[test]
fn wire_honesty_fires_on_cost_without_packed_codec() {
    let got = fatal_rules("rust/src/sync/custom.rs", DISHONEST_IMPL, &Config::empty());
    assert_eq!(got, ["wire_honesty"]);
}

#[test]
fn wire_honesty_silent_when_packed_codec_present() {
    assert!(fatal_rules("rust/src/sync/custom.rs", HONEST_IMPL, &Config::empty()).is_empty());
}

#[test]
fn wire_honesty_waivable() {
    let src = "// apslint: allow(wire_honesty) -- prototype, dense-only by design\n\
               impl SyncStrategy for TopK {\n\
                   fn wire_cost(&self, n: usize) -> u64 { n as u64 }\n\
               }\n";
    let diags = check_source("rust/src/sync/custom.rs", src, &Config::empty());
    assert_eq!(diags.len(), 1);
    assert!(!diags[0].is_fatal());
}

// ---- lossy_cast -------------------------------------------------------

#[test]
fn lossy_cast_fires_on_narrowing() {
    let src = "fn f(x: u64) -> u32 { x as u32 }\n";
    assert_eq!(fatal_rules("rust/src/aps/mod.rs", src, &Config::empty()), ["lossy_cast"]);
}

#[test]
fn lossy_cast_silent_on_widening() {
    let src = "fn f(x: u32) -> u64 { x as u64 }\n";
    assert!(fatal_rules("rust/src/aps/mod.rs", src, &Config::empty()).is_empty());
}

#[test]
fn lossy_cast_silent_on_float_to_int_quantization() {
    // Quantization is the repo's whole point; float → int is intentional.
    let src = "fn f(x: f32) -> i8 { x as i8 }\n";
    assert!(fatal_rules("rust/src/cpd/q.rs", src, &Config::empty()).is_empty());
}

#[test]
fn lossy_cast_tracks_let_bindings_and_chains() {
    let src = "fn f() { let x: u64 = big(); let y = x as u64 as u32; use_(y); }\n";
    assert_eq!(fatal_rules("rust/src/aps/mod.rs", src, &Config::empty()), ["lossy_cast"]);
}

#[test]
fn lossy_cast_waivable() {
    let src = "fn f(x: u64) -> u32 {\n\
               // apslint: allow(lossy_cast) -- bounded by modulus above\n\
               x as u32 }\n";
    let diags = check_source("rust/src/aps/mod.rs", src, &Config::empty());
    assert_eq!(diags.len(), 1);
    assert!(!diags[0].is_fatal());
}

// ---- unsafe_code ------------------------------------------------------

#[test]
fn unsafe_fires_anywhere_in_non_test_code() {
    let src = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert_eq!(fatal_rules("rust/src/util/x.rs", src, &Config::empty()), ["unsafe_code"]);
}

#[test]
fn unsafe_silent_in_test_mod() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *const u8) -> u8 { unsafe { *p } }\n}\n";
    assert!(fatal_rules("rust/src/util/x.rs", src, &Config::empty()).is_empty());
}

#[test]
fn unsafe_waivable() {
    let src = "// apslint: allow(unsafe_code) -- FFI boundary, audited\n\
               fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    let diags = check_source("rust/src/util/x.rs", src, &Config::empty());
    assert_eq!(diags.len(), 1);
    assert!(!diags[0].is_fatal());
}

// ---- panic_in_hot_path ------------------------------------------------

#[test]
fn panic_fires_on_unwrap_in_hot_fn() {
    let src = "fn step(x: Option<u8>) -> u8 { x.unwrap() }\n";
    assert_eq!(
        fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()),
        ["panic_in_hot_path"]
    );
}

#[test]
fn panic_fires_on_literal_index_in_hot_fn() {
    let src = "fn step(xs: &[u8]) -> u8 { xs[0] }\n";
    assert_eq!(
        fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()),
        ["panic_in_hot_path"]
    );
}

#[test]
fn panic_silent_outside_hot_path() {
    let src = "fn setup(x: Option<u8>) -> u8 { x.unwrap() }\n";
    assert!(fatal_rules("rust/src/sync/hot.rs", src, &hot_cfg()).is_empty());
}

#[test]
fn panic_waivable() {
    let src = "fn step(xs: &[u8]) -> u8 {\n\
               // apslint: allow(panic_in_hot_path) -- length asserted by caller\n\
               xs[0] }\n";
    let diags = check_source("rust/src/sync/hot.rs", src, &hot_cfg());
    assert_eq!(diags.len(), 1);
    assert!(!diags[0].is_fatal());
}

// ---- nondeterminism ---------------------------------------------------

#[test]
fn nondeterminism_fires_on_hashmap_in_scope() {
    let src = "fn encode_x() { let m: std::collections::HashMap<u8, u8> = Default::default(); drop(m); }\n";
    assert_eq!(fatal_rules("rust/src/sync/s.rs", src, &nd_cfg()), ["nondeterminism"]);
}

#[test]
fn nondeterminism_fires_on_thread_count_in_scope() {
    let src = "fn encode_x(n: usize) -> usize { crate::util::par::num_threads().min(n) }\n";
    assert_eq!(fatal_rules("rust/src/sync/s.rs", src, &nd_cfg()), ["nondeterminism"]);
}

#[test]
fn nondeterminism_silent_outside_scope() {
    // Same body, but the function name is not an nd prefix and the file
    // is outside the nd path fragments.
    let src = "fn report() { let m: std::collections::HashMap<u8, u8> = Default::default(); drop(m); }\n";
    assert!(fatal_rules("rust/src/sync/s.rs", src, &nd_cfg()).is_empty());
    let src2 = "fn encode_x() { let m: std::collections::HashMap<u8, u8> = Default::default(); drop(m); }\n";
    assert!(fatal_rules("rust/src/metrics/s.rs", src2, &nd_cfg()).is_empty());
}

#[test]
fn nondeterminism_waivable() {
    let src = "fn encode_x(n: usize) -> usize {\n\
               // apslint: allow(nondeterminism) -- schedule-only, results index-keyed\n\
               crate::util::par::num_threads().min(n) }\n";
    let diags = check_source("rust/src/sync/s.rs", src, &nd_cfg());
    assert_eq!(diags.len(), 1);
    assert!(!diags[0].is_fatal());
}

// ---- overlap hot set --------------------------------------------------
//
// The bucketed-overlap PR widened the repo hot set: the per-bucket
// encode/fold/drain entry points (`step_overlapped`,
// `encode_bucket_layers`, `overlap_worker` in sync/session.rs) and the
// transport frame path (`exchange`, `serialize_frame_into`,
// `deserialize_frame` in sync/transport.rs). Pin that the *default*
// config covers them — a fixture violation in a matching file must
// fire — and that cold transport setup stays out of the hot set.

#[test]
fn repo_default_covers_overlap_session_entry_points() {
    for name in ["step_overlapped", "encode_bucket_layers", "overlap_worker"] {
        let src = format!("fn {name}() {{ let v: Vec<u8> = Vec::new(); drop(v); }}\n");
        assert_eq!(
            fatal_rules("rust/src/sync/session.rs", &src, &Config::repo_default()),
            ["alloc_in_hot_path"],
            "{name} must be in the repo-default hot set"
        );
    }
}

#[test]
fn repo_default_covers_transport_frame_path() {
    for name in ["exchange", "serialize_frame_into", "deserialize_frame"] {
        let src = format!("fn {name}(x: Option<u8>) -> u8 {{ x.unwrap() }}\n");
        assert_eq!(
            fatal_rules("rust/src/sync/transport.rs", &src, &Config::repo_default()),
            ["panic_in_hot_path"],
            "{name} must be in the repo-default hot set"
        );
    }
}

#[test]
fn repo_default_covers_frame_assign_on_wire() {
    let src = "fn assign_parts() { let v: Vec<u8> = vec![0u8]; drop(v); }\n";
    assert_eq!(
        fatal_rules("rust/src/sync/wire.rs", src, &Config::repo_default()),
        ["alloc_in_hot_path"],
        "assign_parts must be in the repo-default hot set"
    );
}

#[test]
fn transport_setup_is_cold() {
    // Connection setup allocates by design (socket vectors, slab rings,
    // channel seeding); `new` is not hot-listed, so no waiver needed.
    let src = "fn new(world: usize) -> Tcp { let v: Vec<u8> = Vec::with_capacity(world); todo!() }\n";
    assert!(
        fatal_rules("rust/src/sync/transport.rs", src, &Config::repo_default()).is_empty(),
        "transport construction must stay out of the hot set"
    );
}

// ---- parallel-encode hot set ------------------------------------------
//
// The parallel-encode PR widened the hot set again: the twin-lane pool's
// per-layer fan-out entry points (`encode_layer_packed`,
// `encode_layer_dense` in sync/session.rs) run once per layer per step.
// Pin that the default config covers them for the alloc rule, that the
// `encode` nd-prefix auto-scopes them for nondeterminism, and that pool
// construction (build()/set_strategy() time) stays cold.

#[test]
fn repo_default_covers_parallel_encode_entry_points() {
    for name in ["encode_layer_packed", "encode_layer_dense"] {
        let src = format!("fn {name}() {{ let v: Vec<u8> = Vec::new(); drop(v); }}\n");
        assert_eq!(
            fatal_rules("rust/src/sync/session.rs", &src, &Config::repo_default()),
            ["alloc_in_hot_path"],
            "{name} must be in the repo-default hot set"
        );
    }
}

#[test]
fn parallel_encode_entry_points_are_nd_scoped() {
    // `encode_*` under sync/ is already nondeterminism scope, so a
    // thread-count dependency inside the fan-out fires without any
    // hot-set listing.
    let src = "fn encode_layer_packed(n: usize) -> usize { crate::util::par::num_threads().min(n) }\n";
    assert_eq!(
        fatal_rules("rust/src/sync/session.rs", src, &Config::repo_default()),
        ["nondeterminism"],
        "encode_layer_packed must be nondeterminism-scoped via the encode prefix"
    );
}

#[test]
fn encode_pool_construction_is_cold() {
    // Building the twin pool allocates by design (one lane per worker);
    // it runs at build()/set_strategy() time, never per step.
    let src =
        "fn build_encode_pool(world: usize) { let v: Vec<u8> = Vec::with_capacity(world); drop(v); }\n";
    assert!(
        fatal_rules("rust/src/sync/session.rs", src, &Config::repo_default()).is_empty(),
        "pool construction must stay out of the hot set"
    );
}

// ---- waiver syntax ----------------------------------------------------

#[test]
fn waiver_without_reason_is_error() {
    let src = "// apslint: allow(unsafe_code)\nfn f() {}\n";
    let diags = check_source("rust/src/util/x.rs", src, &Config::empty());
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].rule, "waiver_syntax");
    assert!(diags[0].is_fatal());
}

#[test]
fn waiver_with_unknown_rule_is_warning() {
    let src = "// apslint: allow(no_such_rule) -- oops\nfn f() {}\n";
    let diags = check_source("rust/src/util/x.rs", src, &Config::empty());
    assert_eq!(diags.len(), 1);
    assert_eq!(diags[0].severity, Severity::Warning);
    assert!(!diags[0].is_fatal());
}

#[test]
fn doc_comments_never_carry_waivers() {
    // Documentation *about* waivers (like the lint module's own docs)
    // must not parse as waivers — or trip waiver_syntax.
    let src = "/// Write `// apslint: allow(rule)` to waive.\nfn f() {}\n";
    assert!(check_source("rust/src/util/x.rs", src, &Config::empty()).is_empty());
}

// ---- whole-repo smoke -------------------------------------------------

/// The gate CI enforces: the tree, scanned with the repo config, has zero
/// unwaived diagnostics (waivers with written reasons are fine).
#[test]
fn repo_is_clean_under_default_config() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint::run(root, &Config::repo_default()).expect("scan repo");
    assert!(report.files_scanned > 0, "scanner found no files — wrong root?");
    let fatal: Vec<String> =
        report.diagnostics.iter().filter(|d| d.is_fatal()).map(|d| d.render()).collect();
    assert!(
        report.ok(),
        "unwaived apslint diagnostics:\n{}",
        fatal.join("\n")
    );
}

// ---- schedule permutation ---------------------------------------------

/// Local splitmix64 (private copy; `cpd::cast::splitmix64` is pub(crate))
/// so the per-element work below is keyed by absolute index alone.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The contract behind every `nondeterminism` waiver on a
/// `num_threads()` site: chunking is schedule-only. Run the same
/// index-keyed element kernel under 1, 2, and 8 threads and assert the
/// outputs are bit-identical.
#[test]
fn par_chunks_schedule_is_bit_invariant() {
    let n = 100_003; // prime: uneven chunks at every thread count
    let kernel = |start: usize, chunk: &mut [f32]| {
        for (i, x) in chunk.iter_mut().enumerate() {
            let gi = (start + i) as u64;
            // 24-bit draw → exact in f32, like the stochastic codecs.
            *x = (splitmix64(gi) >> 40) as f32;
        }
    };
    let mut runs: Vec<Vec<u32>> = Vec::new();
    for threads in [1usize, 2, 8] {
        let mut out = vec![0.0f32; n];
        par::par_chunks_mut_with(&mut out, 64, threads, kernel);
        runs.push(out.iter().map(|v| v.to_bits()).collect());
    }
    assert_eq!(runs[0], runs[1], "1 vs 2 threads diverged");
    assert_eq!(runs[0], runs[2], "1 vs 8 threads diverged");
}

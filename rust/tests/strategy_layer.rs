//! The strategy/collective/session layer's integration suite:
//!
//! * **equivalence** — every legacy `SyncMethod`, run through its
//!   `SyncStrategy` impl inside a `SyncSession`, is bit-identical
//!   (gradients *and* `SyncReport` accounting) to the pre-trait
//!   `aps::legacy::synchronize` across topologies and option knobs;
//! * **reuse** — a session reused across ≥3 steps yields exactly the
//!   reports and outputs of fresh sessions (the no-allocation design
//!   cannot leak state between steps);
//! * **properties** (util::ptest) — per-strategy encode/decode
//!   round-trips on hostile random inputs;
//! * **convergence** — the net-new ternary and top-k codecs train a
//!   synthetic least-squares workload without divergence.

use aps_cpd::aps::{legacy, SyncMethod, SyncOptions};
use aps_cpd::collectives::{SimCluster, Topology};
use aps_cpd::cpd::{quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::sync::{ErrorFeedback, StrategySpec, SyncSessionBuilder};
use aps_cpd::util::ptest::{check_msg, generators};

/// Deterministic mixed-scale per-worker gradients (the Fig-2 situation).
fn scaled_grads(world: usize, salt: usize, layers: &[(usize, f32)]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &(n, scale))| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 2654435761 + l * 97 + i * 131 + salt * 7919) % 2003;
                            (h as f32 / 2003.0 - 0.5) * scale
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn assert_bit_identical(label: &str, world: usize, opts: &SyncOptions, grads: &[Vec<Vec<f32>>]) {
    let cluster = SimCluster::new(world);
    let (old_out, old_rep) = legacy::synchronize(&cluster, grads, opts);
    let mut session = SyncSessionBuilder::from_sync_options(world, opts).build();
    let (new_out, new_rep) = session.step(grads);

    assert_eq!(old_out.len(), new_out.len(), "{label}: layer count");
    for (l, (o, n)) in old_out.iter().zip(new_out.iter()).enumerate() {
        assert_eq!(o.len(), n.len(), "{label}: layer {l} length");
        for (i, (a, b)) in o.iter().zip(n.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: layer {l} elem {i}: legacy {a:e} vs session {b:e}"
            );
        }
    }
    assert_eq!(&old_rep, new_rep, "{label}: SyncReport accounting");
}

#[test]
fn legacy_methods_bit_identical_through_session() {
    let layers = [(96usize, 1.0f32), (64, 1e-6), (33, 2.5e3)];
    let methods = [
        SyncMethod::Fp32,
        SyncMethod::Naive { fmt: FpFormat::E5M2 },
        SyncMethod::Naive { fmt: FpFormat::E3M0 },
        SyncMethod::LossScaling { fmt: FpFormat::E5M2, factor_exp: 8 },
        SyncMethod::Aps { fmt: FpFormat::E5M2 },
        SyncMethod::Aps { fmt: FpFormat::E4M3 },
    ];
    for (mi, method) in methods.into_iter().enumerate() {
        for topo in [Topology::Ring, Topology::Hierarchical { group_size: 4 }] {
            let world = 8;
            let grads = scaled_grads(world, mi, &layers);
            let base = SyncOptions::new(method).with_topology(topo);
            assert_bit_identical(&format!("{method:?}/{topo:?}"), world, &base, &grads);
        }
    }
}

#[test]
fn option_knobs_bit_identical_through_session() {
    let world = 8;
    let grads = scaled_grads(world, 3, &[(64, 1e-5), (48, 1.0)]);
    let aps = SyncMethod::Aps { fmt: FpFormat::E5M2 };
    let variants = [
        ("kahan", SyncOptions::new(aps).with_kahan(true)),
        ("fp32_last_layer", SyncOptions::new(aps).with_fp32_last_layer(true)),
        ("fused", SyncOptions::new(aps).with_fused(true)),
        ("no_average", SyncOptions::new(aps).with_average(false)),
        ("toward_zero", SyncOptions::new(aps).with_rounding(Rounding::TowardZero)),
        (
            "everything",
            SyncOptions::new(SyncMethod::Naive { fmt: FpFormat::E4M3 })
                .with_topology(Topology::Hierarchical { group_size: 2 })
                .with_kahan(true)
                .with_fp32_last_layer(true)
                .with_fused(true),
        ),
    ];
    for (label, opts) in variants {
        assert_bit_identical(label, world, &opts, &grads);
    }
}

#[test]
fn session_reuse_matches_fresh_calls_across_steps() {
    // The no-allocation smoke test: one session reused over ≥3 distinct
    // steps must produce exactly what a fresh session (and the legacy
    // path) produces for each step — buffer reuse can't leak state.
    // Layer sizes shrink and grow across steps to stress buffer resizing.
    let world = 8;
    let shapes: [&[(usize, f32)]; 4] =
        [&[(64, 1.0), (32, 1e-6)], &[(16, 1e3), (8, 1e-4)], &[(128, 0.1), (5, 1.0)], &[(64, 1.0)]];
    for spec in [
        StrategySpec::Fp32,
        StrategySpec::Aps { fmt: FpFormat::E5M2 },
        StrategySpec::Naive { fmt: FpFormat::E4M3 },
        StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        StrategySpec::TopK { frac: 0.5 },
    ] {
        let mut reused = SyncSessionBuilder::new(world).spec(spec.clone()).build();
        for (step, layers) in shapes.iter().enumerate() {
            let grads = scaled_grads(world, step, layers);
            let (r_out, r_rep) = reused.step(&grads);
            let r_out = r_out.to_vec();
            let r_rep = r_rep.clone();
            let mut fresh = SyncSessionBuilder::new(world).spec(spec.clone()).build();
            let (f_out, f_rep) = fresh.step(&grads);
            for (l, (a, b)) in r_out.iter().zip(f_out.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{spec:?} step {step} layer {l} elem {i}"
                    );
                }
            }
            assert_eq!(&r_rep, f_rep, "{spec:?} step {step} report");
        }
    }
}

#[test]
fn error_feedback_with_zero_residual_is_bit_identical_to_unwrapped() {
    // The first step of a fresh ErrorFeedback wrapper runs with all-zero
    // residuals, and must be bit-transparent: gradients AND SyncReport
    // identical to the legacy (unwrapped) path for every paper method,
    // across topologies.
    let layers = [(96usize, 1.0f32), (64, 1e-6), (33, 2.5e3)];
    let methods = [
        SyncMethod::Fp32,
        SyncMethod::Naive { fmt: FpFormat::E5M2 },
        SyncMethod::LossScaling { fmt: FpFormat::E5M2, factor_exp: 8 },
        SyncMethod::Aps { fmt: FpFormat::E5M2 },
    ];
    for (mi, method) in methods.into_iter().enumerate() {
        for topo in [Topology::Ring, Topology::Hierarchical { group_size: 4 }] {
            let world = 8;
            let grads = scaled_grads(world, mi, &layers);
            let opts = SyncOptions::new(method).with_topology(topo);
            let cluster = SimCluster::new(world);
            let (old_out, old_rep) = legacy::synchronize(&cluster, &grads, &opts);
            let mut session = SyncSessionBuilder::from_sync_options(world, &opts)
                .strategy(Box::new(ErrorFeedback::new(StrategySpec::from(method).build())))
                .build();
            let (new_out, new_rep) = session.step(&grads);
            let label = format!("ef({method:?})/{topo:?}");
            for (l, (o, n)) in old_out.iter().zip(new_out.iter()).enumerate() {
                for (i, (a, b)) in o.iter().zip(n.iter()).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{label}: layer {l} elem {i}");
                }
            }
            assert_eq!(&old_rep, new_rep, "{label}: SyncReport accounting");
        }
    }
}

#[test]
fn error_feedback_fp32_stays_transparent_across_steps() {
    // A lossless inner codec accumulates no residual, so the wrapper must
    // stay bit-identical to the bare strategy over a multi-step session.
    let world = 4;
    let mut plain = SyncSessionBuilder::new(world).spec(StrategySpec::Fp32).build();
    let mut wrapped =
        SyncSessionBuilder::new(world).spec(StrategySpec::Fp32).error_feedback().build();
    for step in 0..4 {
        let grads = scaled_grads(world, step, &[(48, 1.0), (16, 1e-5)]);
        let (po, pr) = plain.step(&grads);
        let po = po.to_vec();
        let pr = pr.clone();
        let (wo, wr) = wrapped.step(&grads);
        for (l, (a, b)) in po.iter().zip(wo.iter()).enumerate() {
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "step {step} layer {l} elem {i}");
            }
        }
        assert_eq!(&pr, wr, "step {step} report");
    }
}

#[test]
fn ternary_sessions_replay_deterministically() {
    // Stochastic codec, deterministic stream: two sessions with the same
    // seed walking the same steps must agree bit-for-bit.
    let world = 4;
    let mut a = SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 11 }).build();
    let mut b = SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 11 }).build();
    for step in 0..3 {
        let grads = scaled_grads(world, step, &[(64, 0.3), (32, 2.0)]);
        let (oa, ra) = a.step(&grads);
        let oa = oa.to_vec();
        let ra = ra.clone();
        let (ob, rb) = b.step(&grads);
        assert_eq!(oa.as_slice(), ob, "step {step}");
        assert_eq!(&ra, rb, "step {step} report");
    }
    // A different seed must (overwhelmingly) produce different symbols.
    let mut c = SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 12 }).build();
    let grads = scaled_grads(world, 0, &[(64, 0.3), (32, 2.0)]);
    let (oc, _) = c.step(&grads);
    let mut d = SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 11 }).build();
    let (od, _) = d.step(&grads);
    assert_ne!(oc, od, "seeds 11 vs 12 should diverge");
}

#[test]
fn prop_naive_world1_is_pure_quantize() {
    // With one worker and averaging off, a naive session is exactly the
    // wire cast: output bits == quantize_shifted_slice(src, 0, fmt).
    check_msg(
        "naive session (world 1) == quantize",
        31,
        200,
        |rng| (generators::nasty_vec(rng, 64), generators::format(rng)),
        |(xs, fmt)| {
            let grads = vec![vec![xs.clone()]];
            let mut s = SyncSessionBuilder::new(1)
                .spec(StrategySpec::Naive { fmt: *fmt })
                .with_average(false)
                .build();
            let (out, _) = s.step(&grads);
            let want = quantize_shifted_slice(xs, 0, *fmt, Rounding::NearestEven);
            for (i, (a, b)) in want.iter().zip(out[0].iter()).enumerate() {
                let same = (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits();
                if !same {
                    return Err(format!("elem {i}: want {a:e} got {b:e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fp32_world1_is_identity() {
    check_msg(
        "fp32 session (world 1, no average) is the identity",
        32,
        200,
        |rng| generators::nasty_vec(rng, 64),
        |xs| {
            let grads = vec![vec![xs.clone()]];
            let mut s =
                SyncSessionBuilder::new(1).spec(StrategySpec::Fp32).with_average(false).build();
            let (out, report) = s.step(&grads);
            for (i, (a, b)) in xs.iter().zip(out[0].iter()).enumerate() {
                let same = (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits();
                if !same {
                    return Err(format!("elem {i}: {a:e} -> {b:e}"));
                }
            }
            if report.payload_bytes != 0 {
                return Err("single worker moves no bytes".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aps_session_never_overflows() {
    // Eq. 1–4 through the trait layer: any gradients, any format — no
    // wire overflow and finite outputs.
    check_msg(
        "APS session never overflows",
        33,
        60,
        |rng| {
            let p = 2 + rng.below(7);
            let layers = 1 + rng.below(3);
            let scale = (rng.range(-30.0, 30.0)).exp2();
            let grads: Vec<Vec<Vec<f32>>> = (0..p)
                .map(|_| {
                    (0..layers)
                        .map(|_| (0..16).map(|_| rng.normal() * scale).collect())
                        .collect()
                })
                .collect();
            (grads, generators::format(rng))
        },
        |(grads, fmt)| {
            let mut s = SyncSessionBuilder::new(grads.len())
                .spec(StrategySpec::Aps { fmt: *fmt })
                .build();
            let (out, report) = s.step(grads);
            if report.any_overflow() {
                return Err("overflow on the wire".into());
            }
            if out.iter().flatten().any(|v| v.is_infinite()) {
                return Err("INF in output".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ternary_outputs_are_symbol_averages() {
    // Every reduced element is (k/world)·s for integer |k| ≤ world: the
    // sum of world ternary symbols, exactly representable on a BF16 wire.
    check_msg(
        "ternary reduced values are k·s/world",
        34,
        80,
        |rng| {
            let world = 2 + rng.below(6);
            let scale = (rng.range(-8.0, 8.0)).exp2();
            let grads: Vec<Vec<Vec<f32>>> = (0..world)
                .map(|_| vec![(0..24).map(|_| rng.normal() * scale).collect()])
                .collect();
            (grads, rng.next_u64())
        },
        |(grads, seed)| {
            let world = grads.len();
            let mut s = SyncSessionBuilder::new(world)
                .spec(StrategySpec::Ternary { seed: *seed })
                .build();
            // the agreed scale: 2^(max ceil-log2 over all workers)
            let max_abs = grads
                .iter()
                .flat_map(|w| w[0].iter())
                .fold(0.0f32, |m, &x| m.max(x.abs()));
            let (out, _) = s.step(grads);
            if max_abs == 0.0 {
                return if out[0].iter().all(|&v| v == 0.0) {
                    Ok(())
                } else {
                    Err("zero grads must reduce to zero".into())
                };
            }
            let e = (max_abs as f64).log2().ceil() as i32;
            let s_scale = (e as f64).exp2();
            for (i, &v) in out[0].iter().enumerate() {
                let k = v as f64 * world as f64 / s_scale;
                if (k - k.round()).abs() > 1e-4 || k.abs() > world as f64 + 1e-4 {
                    return Err(format!("elem {i}: {v:e} is not k·s/p (k = {k})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_keeps_k_and_zeroes_rest() {
    check_msg(
        "top-k session output support is the union of kept elements",
        35,
        120,
        |rng| {
            let n = 4 + rng.below(60);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            (xs, 0.1 + rng.uniform() * 0.9)
        },
        |(xs, frac)| {
            let frac32 = *frac as f32;
            let grads = vec![vec![xs.clone()]];
            let mut s = SyncSessionBuilder::new(1)
                .spec(StrategySpec::TopK { frac: frac32 })
                .with_average(false)
                .build();
            let (out, _) = s.step(&grads);
            let n = xs.len();
            // the same arithmetic the strategy uses (f32 frac widened)
            let k = ((frac32 as f64 * n as f64).ceil() as usize).clamp(1, n);
            let kept = out[0].iter().filter(|&&v| v != 0.0).count();
            // ≥ k survivors is impossible to exceed except via magnitude
            // ties; zeros in the input also shrink the support.
            let nonzero_in = xs.iter().filter(|&&x| x != 0.0).count();
            if kept > n || kept < k.min(nonzero_in) {
                return Err(format!("kept {kept} of {n} (k = {k})"));
            }
            // survivors are bitwise the inputs
            for (a, b) in xs.iter().zip(out[0].iter()) {
                if *b != 0.0 && a.to_bits() != b.to_bits() {
                    return Err(format!("survivor changed: {a:e} -> {b:e}"));
                }
            }
            Ok(())
        },
    );
}

/// Train `min ‖Xw − y‖²` with simulated data-parallel workers through a
/// session; returns (initial mse, final mse, saw_nan).
fn train_least_squares(spec: StrategySpec, steps: usize, lr: f32) -> (f64, f64, bool) {
    let world = 4;
    let d = 24;
    let local_batch = 8;
    let mut rng = Rng::new(1234);
    let w_true: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
    let mut w = vec![0.0f32; d];
    let mut session = SyncSessionBuilder::new(world).spec(spec).build();

    let mse = |w: &[f32], rng: &mut Rng| -> f64 {
        let mut acc = 0.0f64;
        for _ in 0..64 {
            let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let y: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum();
            let p: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum();
            acc += ((p - y) as f64).powi(2);
        }
        acc / 64.0
    };

    let mut eval_rng = Rng::new(77);
    let initial = mse(&w, &mut eval_rng);
    let mut saw_nan = false;
    for _ in 0..steps {
        // each worker: gradient of ½(w·x − y)² over its local batch
        let grads: Vec<Vec<Vec<f32>>> = (0..world)
            .map(|_| {
                let mut g = vec![0.0f32; d];
                for _ in 0..local_batch {
                    let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                    let y: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum();
                    let p: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
                    let e = (p - y) / local_batch as f32;
                    for (gi, xi) in g.iter_mut().zip(&x) {
                        *gi += e * xi;
                    }
                }
                vec![g]
            })
            .collect();
        let (reduced, _) = session.step(&grads);
        for (wi, gi) in w.iter_mut().zip(reduced[0].iter()) {
            *wi -= lr * gi;
            if !wi.is_finite() {
                saw_nan = true;
            }
        }
        if saw_nan {
            break;
        }
    }
    let mut eval_rng = Rng::new(77);
    let final_mse = mse(&w, &mut eval_rng);
    (initial, final_mse, saw_nan)
}

#[test]
fn ternary_trains_without_divergence() {
    let (initial, final_mse, saw_nan) = train_least_squares(
        StrategySpec::Ternary { seed: 5 },
        600,
        0.05,
    );
    assert!(!saw_nan, "ternary diverged to NaN");
    assert!(
        final_mse < initial * 0.2,
        "ternary failed to train: {initial:.4} -> {final_mse:.4}"
    );
}

#[test]
fn topk_trains_without_divergence() {
    let (initial, final_mse, saw_nan) =
        train_least_squares(StrategySpec::TopK { frac: 0.25 }, 400, 0.1);
    assert!(!saw_nan, "top-k diverged to NaN");
    assert!(
        final_mse < initial * 0.2,
        "top-k failed to train: {initial:.4} -> {final_mse:.4}"
    );
}

#[test]
fn qsgd_trains_without_divergence() {
    // 4-bit QSGD quantizes far finer than ternary, which passes the same
    // workload — so the ternary/top-k thresholds are comfortably safe.
    let (initial, final_mse, saw_nan) =
        train_least_squares(StrategySpec::Qsgd { bits: 4, bucket: 16, seed: 5 }, 400, 0.1);
    assert!(!saw_nan, "qsgd diverged to NaN");
    assert!(
        final_mse < initial * 0.2,
        "qsgd failed to train: {initial:.4} -> {final_mse:.4}"
    );
}

#[test]
fn aps_trains_the_same_workload_for_reference() {
    let (initial, final_mse, saw_nan) = train_least_squares(
        StrategySpec::Aps { fmt: FpFormat::E5M2 },
        400,
        0.1,
    );
    assert!(!saw_nan);
    assert!(final_mse < initial * 0.05, "APS reference: {initial:.4} -> {final_mse:.4}");
}

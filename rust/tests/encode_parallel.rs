//! The parallel-encode suite: the producer-side mirror of
//! `packed_parallel.rs` — encode parallelism may repartition which
//! thread runs a worker's encode→pack chain, never the arithmetic.
//!
//! * **schedule independence** — for every conformance codec (the same
//!   11 the codec contract covers), sessions fanning the per-worker
//!   encode out over 2/4/8 encode threads (and the auto setting)
//!   produce bit-identical reduced gradients, `SyncReport`s and measured
//!   wire traffic to the serial encode loop (`with_encode_threads(1)`,
//!   which builds no twin pool at all) and the simulated-wire baseline,
//!   on hostile `nasty_f32` inputs, across ring, hierarchical and
//!   parameter-server collectives. Explicit `with_encode_threads(k > 1)`
//!   forces a k-way split even on layers below the auto threshold, so
//!   the permutation coverage is real on every layer shape here,
//!   including the 9-element tail. Stateful codecs are the hard cases
//!   pinned: error-feedback twins accumulate per-worker residuals across
//!   both steps, and QSGD's encode→`encode_packed` coupling stays on one
//!   lane.
//! * **opt-in closure** — every built-in strategy (and its
//!   error-feedback wrapper) returns an encode twin from
//!   `parallel_encoder`, so the session's parallel path actually covers
//!   the whole family; the trait default (`None`, third-party codecs
//!   stay serial) is also pinned.
//! * **tree-reduction prepare** — `aps::local_max_exp` is now a
//!   fixed-block tree reduction; a property test pins it to the plain
//!   serial max-abs scan at sizes straddling the reduction threshold
//!   (the combine tree is fixed by the block size, never the host's
//!   thread count), and a large-layer session sweep pins the whole
//!   prepare→encode→fold pipeline above the threshold end to end.
//!
//! The `nondeterminism`/`alloc_in_hot_path` waivers on the encode-pool
//! entry points in `sync/session.rs` cite this suite as their evidence.

use aps_cpd::aps::local_max_exp;
use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::data::Rng;
use aps_cpd::sync::{StrategySpec, SyncSessionBuilder, SyncStrategy, WireMode};
use aps_cpd::util::ptest::generators;

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// The same 11-codec family the conformance contract pins.
fn specs() -> Vec<(&'static str, StrategySpec)> {
    vec![
        ("fp32", StrategySpec::Fp32),
        ("naive/e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling/e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        ),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 9 }),
        ("topk@0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd b4/32", StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 }),
        ("ef:ternary", ef(StrategySpec::Ternary { seed: 9 })),
        ("ef:topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 })),
    ]
}

/// Hostile per-worker gradients from the shared `nasty_f32` stream.
fn nasty_grads(rng: &mut Rng, world: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|_| {
            layers
                .iter()
                .map(|&n| (0..n).map(|_| generators::nasty_f32(rng)).collect())
                .collect()
        })
        .collect()
}

/// One (world, topology) cell of the encode-schedule matrix: run the
/// serial-encode packed session, the serial-encode simulated session,
/// one packed session per encode-thread setting, and one parallel
/// simulated session, all in lockstep over two steps, asserting every
/// step's reduced gradients, reports and measured traffic agree
/// bit-for-bit. Two steps matter: error-feedback residuals in the twin
/// lanes must match the serial wrapper's per-worker slots *after* they
/// have accumulated history.
fn check_encode_cell(label: &str, spec: &StrategySpec, world: usize, topo: Topology) {
    // One layer above typical chunk sizes plus small and odd tails, so
    // forced splits exercise uneven lane chunks at every world size.
    let layers = [33usize, 4096, 9];
    let mut rng = Rng::new(0xE4C0DE ^ world as u64 ^ label.len() as u64);
    let build = |encode_threads: usize, wire: WireMode| {
        SyncSessionBuilder::new(world)
            .spec(spec.clone())
            .with_topology(topo)
            .with_encode_threads(encode_threads)
            .with_wire(wire)
            .build()
    };
    // The reference: the classic serial encode loop (no twin pool).
    let mut base = build(1, WireMode::Packed);
    let mut sim = build(1, WireMode::Simulated);
    // 0 = auto sizing; 2/4/8 = forced lane splits (distinct schedules
    // even on the 9-element layer and at world 1, where the pool is
    // skipped entirely).
    let encode_threads = [0usize, 2, 4, 8];
    let mut par: Vec<_> =
        encode_threads.iter().map(|&k| build(k, WireMode::Packed)).collect();
    let mut par_sim = build(4, WireMode::Simulated);
    for step in 0..2 {
        let grads = nasty_grads(&mut rng, world, &layers);
        let (bo, br) = base.step(&grads);
        let bo = bo.to_vec();
        let br = br.clone();
        let bm = base.wire_moved();
        let (so, sr) = sim.step(&grads);
        for (l, (a, b)) in bo.iter().zip(so.iter()).enumerate() {
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                     packed(serial encode) {x:e} vs simulated {y:e}"
                );
            }
        }
        assert_eq!(&br, sr, "{label}/{topo:?} w{world} step {step}: packed vs simulated report");
        for (session, &k) in par.iter_mut().zip(encode_threads.iter()) {
            let (po, pr) = session.step(&grads);
            let po = po.to_vec();
            let pr = pr.clone();
            for (l, (a, b)) in po.iter().zip(bo.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                         {k} encode threads {x:e} vs serial encode {y:e}"
                    );
                }
            }
            assert_eq!(
                pr, br,
                "{label}/{topo:?} w{world} step {step}: report diverged at {k} encode threads"
            );
            assert_eq!(
                session.wire_moved(),
                bm,
                "{label}/{topo:?} w{world} step {step}: moved traffic diverged at {k} \
                 encode threads"
            );
        }
        // The dense-wire fan-out (`encode_layer_dense`) against the
        // serial simulated session.
        let (qo, qr) = par_sim.step(&grads);
        for (l, (a, b)) in qo.iter().zip(bo.iter()).enumerate() {
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                     simulated 4-thread encode {x:e} vs serial {y:e}"
                );
            }
        }
        assert_eq!(
            qr, &br,
            "{label}/{topo:?} w{world} step {step}: simulated parallel-encode report diverged"
        );
        assert_eq!(
            par_sim.wire_moved(),
            None,
            "{label}/{topo:?} w{world}: simulated sessions measure no packed traffic"
        );
    }
}

#[test]
fn parallel_encode_is_schedule_independent_on_the_ring() {
    for (label, spec) in &specs() {
        for world in [1usize, 2, 4, 8] {
            check_encode_cell(label, spec, world, Topology::Ring);
        }
    }
}

#[test]
fn parallel_encode_is_schedule_independent_hierarchically() {
    for (label, spec) in &specs() {
        for (world, group_size) in [(2usize, 2usize), (4, 2), (8, 4), (8, 2)] {
            check_encode_cell(label, spec, world, Topology::Hierarchical { group_size });
        }
    }
}

#[test]
fn parallel_encode_is_schedule_independent_through_the_parameter_server() {
    for (label, spec) in &specs() {
        for (world, shards) in [(4usize, 2usize), (8, 4)] {
            check_encode_cell(label, spec, world, Topology::Ps { shards, staleness: 0 });
        }
    }
}

#[test]
fn every_built_in_codec_returns_an_encode_twin() {
    for (label, spec) in &specs() {
        let strategy = spec.build();
        let twin = strategy.parallel_encoder();
        assert!(
            twin.is_some(),
            "{label}: built-in strategies must opt into the parallel encode fan-out"
        );
        let twin = twin.unwrap();
        assert_eq!(
            twin.name(),
            strategy.name(),
            "{label}: the twin must be the same codec, configured identically"
        );
        assert_eq!(
            twin.wire_format(),
            strategy.wire_format(),
            "{label}: the twin must share the strategy's wire format"
        );
    }
}

#[test]
fn third_party_codecs_stay_serial_by_default() {
    /// A minimal custom codec that does not override `parallel_encoder`.
    struct Identity;
    impl SyncStrategy for Identity {
        fn name(&self) -> &'static str {
            "identity"
        }
        fn wire_format(&self) -> FpFormat {
            FpFormat::FP32
        }
        fn encode(&mut self, src: &[f32], _ctx: &aps_cpd::sync::LayerCtx, out: &mut [f32]) {
            out.copy_from_slice(src);
        }
        fn decode(&mut self, _data: &mut [f32], _ctx: &aps_cpd::sync::LayerCtx) {}
    }
    assert!(
        Identity.parallel_encoder().is_none(),
        "the trait default must keep third-party codecs on the serial encode loop"
    );
    // A session built around it still works — it just never builds a
    // twin pool, whatever the knob says.
    let g: Vec<Vec<Vec<f32>>> = (0..2).map(|w| vec![vec![w as f32 + 0.5; 8]]).collect();
    let mut s = SyncSessionBuilder::new(2)
        .strategy(Box::new(Identity))
        .with_encode_threads(8)
        .with_wire(WireMode::Simulated)
        .build();
    let (out, _) = s.step(&g);
    assert_eq!(out[0][0], 1.0, "0.5 and 1.5 average to 1.0");
}

/// The serial reference `local_max_exp` replaced: a plain left-to-right
/// max-abs scan over the raw f32s, with the same zero/non-finite
/// handling.
fn serial_local_max_exp(grad: &[f32], world_size: usize) -> Option<i32> {
    let mut max_abs = 0.0f32;
    for &x in grad {
        let a = x.abs();
        if a > max_abs {
            max_abs = a;
        }
    }
    if max_abs == 0.0 || !max_abs.is_finite() {
        return None;
    }
    let v = max_abs as f64 * world_size as f64;
    Some(v.log2().ceil() as i32)
}

#[test]
fn tree_reduction_prepare_matches_the_serial_scan() {
    // Sizes straddling every interesting boundary: empty, one block,
    // ragged multi-block, and well past the reduction-parallelism
    // threshold (64 Ki), where the host actually spawns threads. The
    // combine tree is fixed by the block size, so whatever the machine's
    // thread count, the tree result must equal the serial scan exactly.
    let mut rng = Rng::new(0x7EE_5CA2);
    for &n in &[0usize, 1, 63, 4096, 4097, 20_000, (64 << 10) + 17, 150_001] {
        for world in [1usize, 8, 256] {
            // Finite-only stream (the session's prepare contract).
            let xs: Vec<f32> = (0..n)
                .map(|_| {
                    let mut v = generators::nasty_f32(&mut rng);
                    if !v.is_finite() {
                        v = 1.5e-3;
                    }
                    v
                })
                .collect();
            assert_eq!(
                local_max_exp(&xs, world),
                serial_local_max_exp(&xs, world),
                "n={n} world={world}: tree max-abs diverged from the serial scan"
            );
        }
        // Zeros → None, and a planted ±INF (divergent layer) → None,
        // regardless of where in the block structure it lands.
        let zeros = vec![0.0f32; n];
        assert_eq!(local_max_exp(&zeros, 8), None, "n={n}: all-zero layer");
        if n > 0 {
            let mut inf = vec![1.0f32; n];
            inf[n / 2] = f32::INFINITY;
            assert_eq!(local_max_exp(&inf, 8), None, "n={n}: divergent layer");
        }
    }
}

#[test]
fn large_layer_pipeline_is_encode_thread_independent_above_the_scan_threshold() {
    // One layer past REDUCE_PAR_THRESHOLD: the APS prepare scan and the
    // auto encode fan-out both actually go parallel here, and a
    // large-bucket QSGD pins the bucket-norm tree at a size where it
    // spans many blocks. Two steps, bit-compared against the serial
    // encode loop.
    let layers = [(64usize << 10) + 257];
    let world = 4;
    for (label, spec) in [
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("qsgd big-bucket", StrategySpec::Qsgd { bits: 4, bucket: 1 << 17, seed: 9 }),
    ] {
        let mut rng = Rng::new(0xB16_1A7E5 ^ label.len() as u64);
        let mut serial = SyncSessionBuilder::new(world)
            .spec(spec.clone())
            .with_encode_threads(1)
            .build();
        let mut auto = SyncSessionBuilder::new(world).spec(spec.clone()).build();
        let mut forced = SyncSessionBuilder::new(world)
            .spec(spec.clone())
            .with_encode_threads(8)
            .build();
        for step in 0..2 {
            let grads = nasty_grads(&mut rng, world, &layers);
            let (so, sr) = serial.step(&grads);
            let so = so.to_vec();
            let sr = sr.clone();
            for (pname, session) in [("auto", &mut auto), ("8-thread", &mut forced)] {
                let (po, pr) = session.step(&grads);
                for (i, (x, y)) in so[0].iter().zip(po[0].iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{label} step {step} elem {i}: serial vs {pname} encode"
                    );
                }
                assert_eq!(pr, &sr, "{label} step {step}: {pname} report diverged");
            }
        }
    }
}

//! L1↔L3 cross-test: execute the AOT-compiled Pallas quantize kernel
//! through the PJRT runtime and compare against the Rust `cpd::cast`
//! path on random tensors — the artifact a production deployment would
//! ship must agree with the coordinator's own arithmetic.

use aps_cpd::cpd::{quantize_shifted, FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::runtime::Engine;
use aps_cpd::util::ptest::generators::nasty_f32;

#[test]
fn pallas_kernel_artifact_matches_rust_cast() {
    if !std::path::Path::new("artifacts/quantize.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::cpu().expect("cpu client");
    let kernel = engine.load_quantizer("artifacts").expect("quantize artifact");

    let mut rng = Rng::new(99);
    let xs: Vec<f32> = (0..kernel.n + 100).map(|_| nasty_f32(&mut rng)).collect();

    for (fe, eb, mb) in [(0, 5, 2), (7, 4, 3), (-11, 3, 0), (3, 8, 7), (0, 8, 23)] {
        let fmt = FpFormat::new(eb, mb);
        let got = kernel.run(&xs, fe, eb, mb).expect("kernel run");
        assert_eq!(got.len(), xs.len());
        let mut mismatches = 0;
        for (i, (&x, &g)) in xs.iter().zip(&got).enumerate() {
            let want = quantize_shifted(x, fe, fmt, Rounding::NearestEven);
            let ok = if want.is_nan() || g.is_nan() {
                want.is_nan() && g.is_nan()
            } else {
                want.to_bits() == g.to_bits()
            };
            if !ok {
                mismatches += 1;
                if mismatches < 5 {
                    eprintln!(
                        "fmt ({eb},{mb}) fe {fe} [{i}] x={x:e}: kernel {g:e} rust {want:e}"
                    );
                }
            }
        }
        assert_eq!(mismatches, 0, "fmt ({eb},{mb}) fe {fe}");
    }
}

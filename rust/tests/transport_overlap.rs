//! The overlapped-path equivalence suite: `SyncSession::step_overlapped`
//! must be **bit-identical** to the synchronous packed `step()` — reduced
//! gradients, reports, and measured `wire_moved` — for every shipped
//! codec, over every `Transport`, at every bucket size. The overlap only
//! reorders *which thread* encodes/folds a bucket and *when*; it never
//! changes any per-element fold chain (PR 7's schedule-independence
//! discipline), so equality here is exact, not approximate.
//!
//! Also pinned:
//! * **transport-level wire honesty** — for serializing transports
//!   (shared-mem, TCP) the octets measured on the channel equal the
//!   encode-side claimed bytes exactly, step after step; the in-process
//!   transport moves references, so both sides stay 0;
//! * **fault semantics** — a killed TCP peer turns the step into a clean
//!   `Err` naming the peer, with no partial fold applied: the reduced
//!   buffers come back empty, the report zeroed, `steps_done` unchanged;
//! * **bucket-plan laws** — every layer lands in exactly one bucket, in
//!   `ready_order`, for any bucket size.

use aps_cpd::cpd::FpFormat;
use aps_cpd::sync::{FaultKind, StrategySpec, SyncSession, SyncSessionBuilder, TransportSpec};

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// The same 11-codec roster the conformance suite pins.
fn codecs() -> Vec<(&'static str, StrategySpec)> {
    vec![
        ("fp32", StrategySpec::Fp32),
        ("naive/e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling/e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        ),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 42 }),
        ("topk@0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd b4/32", StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 }),
        ("ef:ternary", ef(StrategySpec::Ternary { seed: 42 })),
        ("ef:topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 })),
    ]
}

const WORLD: usize = 4;
const LAYERS: [usize; 5] = [33, 64, 128, 7, 256];

/// Deterministic mixed-scale gradients: signs, zeros, subnormal-ish and
/// large magnitudes, different per worker and per step.
fn grads(step: usize) -> Vec<Vec<Vec<f32>>> {
    (0..WORLD)
        .map(|w| {
            LAYERS
                .iter()
                .enumerate()
                .map(|(l, &n)| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 131 + l * 31 + i * 7 + step * 977) % 23;
                            let mag = match h % 4 {
                                0 => 1e-6,
                                1 => 0.125,
                                2 => 3.5,
                                _ => 96.0,
                            };
                            let sign = if h % 3 == 0 { -1.0 } else { 1.0 };
                            if h == 11 {
                                0.0
                            } else {
                                sign * mag * (1.0 + (h as f32) / 23.0)
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn sync_session(spec: &StrategySpec) -> SyncSession {
    SyncSessionBuilder::new(WORLD).spec(spec.clone()).build()
}

fn overlap_session(
    spec: &StrategySpec,
    transport: TransportSpec,
    bucket_bytes: usize,
) -> SyncSession {
    SyncSessionBuilder::new(WORLD)
        .spec(spec.clone())
        .with_transport(transport)
        .with_bucket_bytes(bucket_bytes)
        .build()
}

/// Backprop order: last layer's gradient is ready first.
fn backprop_order() -> Vec<usize> {
    (0..LAYERS.len()).rev().collect()
}

fn assert_bit_identical(label: &str, transport: TransportSpec, bucket_bytes: usize) {
    for (name, spec) in codecs() {
        let mut sync = sync_session(&spec);
        let mut over = overlap_session(&spec, transport, bucket_bytes);
        let order = backprop_order();
        for step in 0..2 {
            let g = grads(step);
            let (s_out, s_report) = sync.step(&g);
            let s_out: Vec<Vec<u32>> =
                s_out.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect();
            let s_report = s_report.clone();
            let s_moved = sync.wire_moved();

            let (o_out, o_report) = over
                .step_overlapped(&g, &order)
                .unwrap_or_else(|e| panic!("{label}/{name} step {step}: {e}"));
            assert_eq!(o_out.len(), s_out.len(), "{label}/{name} step {step}: layer count");
            for (l, (sl, ol)) in s_out.iter().zip(o_out.iter()).enumerate() {
                assert_eq!(sl.len(), ol.len(), "{label}/{name} step {step} layer {l}: len");
                for (i, (&sb, &o)) in sl.iter().zip(ol.iter()).enumerate() {
                    assert_eq!(
                        sb,
                        o.to_bits(),
                        "{label}/{name} step {step} layer {l} elem {i}: bits diverge"
                    );
                }
            }
            assert_eq!(&s_report, o_report, "{label}/{name} step {step}: report");
            let covered: usize = o_report.buckets.iter().map(|b| b.layers).sum();
            assert_eq!(covered, LAYERS.len(), "{label}/{name} step {step}: bucket coverage");
            assert_eq!(
                s_moved,
                over.wire_moved(),
                "{label}/{name} step {step}: measured wire"
            );
        }
        // Transport-level wire honesty, cumulative over both steps:
        // measured channel octets equal the encode-side claim exactly.
        let traffic = over
            .transport_traffic()
            .unwrap_or_else(|| panic!("{label}/{name}: overlap pool never spawned"));
        assert_eq!(
            traffic.octets, traffic.claimed_octets,
            "{label}/{name}: transport moved octets != claimed octets"
        );
        if transport == TransportSpec::InProcess {
            assert_eq!(traffic.octets, 0, "{label}/{name}: in-process moves references");
        } else {
            assert!(traffic.octets > 0, "{label}/{name}: serializing transport moved nothing");
        }
    }
}

#[test]
fn in_process_bit_identical_per_layer_buckets() {
    assert_bit_identical("in_process/bb=1", TransportSpec::InProcess, 1);
}

#[test]
fn in_process_bit_identical_auto_buckets() {
    assert_bit_identical("in_process/bb=auto", TransportSpec::InProcess, 0);
}

#[test]
fn in_process_bit_identical_whole_model_bucket() {
    assert_bit_identical("in_process/bb=max", TransportSpec::InProcess, 1 << 30);
}

#[test]
fn shared_mem_bit_identical_per_layer_buckets() {
    assert_bit_identical("shared_mem/bb=1", TransportSpec::SharedMem, 1);
}

#[test]
fn shared_mem_bit_identical_auto_buckets() {
    assert_bit_identical("shared_mem/bb=auto", TransportSpec::SharedMem, 0);
}

#[test]
fn shared_mem_bit_identical_whole_model_bucket() {
    assert_bit_identical("shared_mem/bb=max", TransportSpec::SharedMem, 1 << 30);
}

#[test]
fn tcp_bit_identical_per_layer_buckets() {
    assert_bit_identical("tcp/bb=1", TransportSpec::Tcp, 1);
}

#[test]
fn tcp_bit_identical_auto_buckets() {
    assert_bit_identical("tcp/bb=auto", TransportSpec::Tcp, 0);
}

#[test]
fn tcp_bit_identical_whole_model_bucket() {
    assert_bit_identical("tcp/bb=max", TransportSpec::Tcp, 1 << 30);
}

/// `ready_order` is the caller's claim about backprop completion order;
/// any permutation must give the same bits (the drain decodes in
/// ascending layer order regardless).
#[test]
fn ready_order_permutations_are_equivalent() {
    let spec = StrategySpec::Aps { fmt: FpFormat::E5M2 };
    let natural: Vec<usize> = (0..LAYERS.len()).collect();
    let twisted = [2usize, 0, 4, 1, 3];
    let g = grads(0);

    let mut a = overlap_session(&spec, TransportSpec::SharedMem, 96);
    let mut b = overlap_session(&spec, TransportSpec::SharedMem, 96);
    let (ao, ar) = a.step_overlapped(&g, &natural).expect("natural order");
    let ao: Vec<Vec<u32>> =
        ao.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect();
    let ar = ar.clone();
    let (bo, br) = b.step_overlapped(&g, &twisted).expect("twisted order");
    for (l, (al, bl)) in ao.iter().zip(bo.iter()).enumerate() {
        for (i, (&x, &y)) in al.iter().zip(bl.iter()).enumerate() {
            assert_eq!(x, y.to_bits(), "layer {l} elem {i}");
        }
    }
    assert_eq!(&ar, br);
}

#[test]
#[should_panic(expected = "ready_order")]
fn duplicate_ready_order_layer_panics() {
    let spec = StrategySpec::Fp32;
    let mut s = overlap_session(&spec, TransportSpec::InProcess, 0);
    let g = grads(0);
    let _ = s.step_overlapped(&g, &[0, 1, 2, 2, 4]);
}

/// A TCP peer dying mid-step must surface as a clean error naming the
/// peer, with no partial fold applied and the step not counted.
#[test]
fn tcp_peer_drop_yields_clean_error() {
    let spec = StrategySpec::Ternary { seed: 42 };
    let mut s = overlap_session(&spec, TransportSpec::Tcp, 0);
    let order = backprop_order();

    let g = grads(0);
    let (_, report) = s.step_overlapped(&g, &order).expect("healthy step");
    assert_eq!(report.layers.len(), LAYERS.len());
    assert_eq!(s.steps_done(), 1);

    assert!(s.kill_transport_peer(2), "overlap-capable session accepts the kill");
    let g = grads(1);
    let err = s.step_overlapped(&g, &order).expect_err("killed peer must fail the step");
    assert_eq!(err.transport, "tcp");
    assert_eq!(err.worker, 2, "the error names the dropped peer: {err}");
    assert_eq!(err.kind, FaultKind::Dead, "a reset peer is dead, not slow");

    // No partial fold escaped: outputs empty, report zeroed, the failed
    // step not counted.
    assert_eq!(s.steps_done(), 1);
    assert!(s.reduced().iter().all(|l| l.is_empty()), "reduced must be emptied");
    assert!(s.report().layers.is_empty());
    assert_eq!(s.report().messages, 0);
    assert_eq!(s.wire_moved(), None);
}

/// A model with zero layers must be a clean no-op on both paths: no
/// panic, no division by zero in the auto bucket sizing (total traffic
/// is 0), empty outputs, zero buckets — and the reports identical.
#[test]
fn zero_layer_model_is_a_clean_noop() {
    for bucket_bytes in [0usize, 1, 1 << 30] {
        let spec = StrategySpec::Aps { fmt: FpFormat::E5M2 };
        let mut sync = sync_session(&spec);
        let mut over = overlap_session(&spec, TransportSpec::SharedMem, bucket_bytes);
        let g: Vec<Vec<Vec<f32>>> = vec![Vec::new(); WORLD];
        let order: Vec<usize> = Vec::new();

        let (s_out, s_report) = sync.step(&g);
        assert!(s_out.is_empty(), "bb={bucket_bytes}: no layers, no outputs");
        let s_report = s_report.clone();

        let (o_out, o_report) =
            over.step_overlapped(&g, &order).expect("zero layers must not fail");
        assert!(o_out.is_empty(), "bb={bucket_bytes}: no layers, no outputs");
        assert_eq!(&s_report, o_report, "bb={bucket_bytes}: reports must match");
        assert!(o_report.buckets.is_empty(), "bb={bucket_bytes}: nothing to bucket");
        assert_eq!(over.steps_done(), 1, "bb={bucket_bytes}: the step still counts");
    }
}

/// Layers that all have zero elements: total dense traffic is 0 bytes
/// into `auto_bucket_bytes` (which must floor, not divide by zero), and
/// the overlapped fold must stay bit-identical with `step()` — trivially
/// empty per-layer outputs, but with every layer still covered by
/// exactly one bucket.
#[test]
fn all_empty_layers_fold_cleanly() {
    let spec = StrategySpec::Aps { fmt: FpFormat::E5M2 };
    let g: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); 3]; WORLD];
    let order = vec![2usize, 1, 0];

    let mut sync = sync_session(&spec);
    let (s_out, s_report) = sync.step(&g);
    assert_eq!(s_out.len(), 3);
    assert!(s_out.iter().all(|l| l.is_empty()));
    let s_report = s_report.clone();

    for bucket_bytes in [0usize, 1] {
        let mut over = overlap_session(&spec, TransportSpec::SharedMem, bucket_bytes);
        let (o_out, o_report) =
            over.step_overlapped(&g, &order).expect("empty layers must not fail");
        assert_eq!(o_out.len(), 3, "bb={bucket_bytes}");
        assert!(o_out.iter().all(|l| l.is_empty()), "bb={bucket_bytes}");
        assert_eq!(o_report.payload_bytes, s_report.payload_bytes, "bb={bucket_bytes}");
        assert_eq!(o_report.exponent_bytes, s_report.exponent_bytes, "bb={bucket_bytes}");
        assert_eq!(o_report.wire, s_report.wire, "bb={bucket_bytes}");
        let covered: usize = o_report.buckets.iter().map(|b| b.layers).sum();
        assert_eq!(covered, 3, "bb={bucket_bytes}: every empty layer in exactly one bucket");
    }
}

/// A bucket budget smaller than any single layer's wire bytes must
/// degenerate to one bucket per layer (every bucket holds at least one
/// layer — no empty buckets, no infinite loop) and stay bit-identical.
#[test]
fn bucket_smaller_than_any_layer_degenerates_to_per_layer() {
    let spec = StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 };
    let g = grads(0);
    let order = backprop_order();

    let mut sync = sync_session(&spec);
    let (s_out, _) = sync.step(&g);
    let s_bits: Vec<Vec<u32>> =
        s_out.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect();

    // 4 bytes < the smallest layer's 7 * 4 dense bytes.
    let mut over = overlap_session(&spec, TransportSpec::SharedMem, 4);
    let (o_out, o_report) = over.step_overlapped(&g, &order).expect("tiny bucket budget");
    assert_eq!(o_report.buckets.len(), LAYERS.len(), "one bucket per layer");
    assert!(o_report.buckets.iter().all(|b| b.layers == 1), "no bucket fuses layers");
    for (l, (sl, ol)) in s_bits.iter().zip(o_out.iter()).enumerate() {
        for (i, (&sb, &o)) in sl.iter().zip(ol.iter()).enumerate() {
            assert_eq!(sb, o.to_bits(), "layer {l} elem {i}: bits diverge");
        }
    }
}

/// Custom strategies cannot be twinned onto the pool; the overlapped
/// entry point must silently take the synchronous path and still honor
/// the `ready_order` contract.
#[test]
fn custom_strategy_falls_back_without_overlap() {
    let mut s = SyncSessionBuilder::new(WORLD)
        .strategy(StrategySpec::Ternary { seed: 42 }.build())
        .build();
    assert_eq!(s.overlap_transport(), None);
    let g = grads(0);
    let order = backprop_order();
    let (out, report) = s.step_overlapped(&g, &order).expect("fallback never fails");
    assert_eq!(out.len(), LAYERS.len());
    assert!(report.buckets.is_empty(), "fallback is the synchronous path");

    let mut twin = sync_session(&StrategySpec::Ternary { seed: 42 });
    let (t_out, _) = twin.step(&g);
    for (a, b) in out.iter().zip(t_out.iter()) {
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

//! Golden-vector cross-test: the Rust `cpd::cast` implementation must be
//! **bit-for-bit identical** to the Python oracle (`ref.quantize_ref`)
//! that also feeds the Pallas kernel. `aot.py` emits
//! `artifacts/quantize_golden.json` (inputs and expected outputs as u32
//! bit patterns across formats and shifts); this test pins all three
//! implementations together.

use aps_cpd::cpd::{quantize_shifted, FpFormat, Rounding};
use aps_cpd::util::json::Json;

fn load() -> Option<Json> {
    let text = std::fs::read_to_string("artifacts/quantize_golden.json").ok()?;
    Some(Json::parse(&text).expect("golden json parses"))
}

#[test]
fn rust_cast_matches_python_oracle_bit_for_bit() {
    let Some(doc) = load() else {
        eprintln!("skipping: artifacts/quantize_golden.json missing (run `make artifacts`)");
        return;
    };
    let in_bits: Vec<u32> = doc
        .get("in_bits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as u32)
        .collect();
    let xs: Vec<f32> = in_bits.iter().map(|&b| f32::from_bits(b)).collect();

    let cases = doc.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 30, "expected a full golden sweep");
    let mut checked = 0usize;
    for case in cases {
        let eb = case.get("exp_bits").unwrap().as_usize().unwrap() as u8;
        let mb = case.get("man_bits").unwrap().as_usize().unwrap() as u8;
        let fe = case.get("factor_exp").unwrap().as_f64().unwrap() as i32;
        let fmt = FpFormat::new(eb, mb);
        let want: Vec<u32> = case
            .get("out_bits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap() as u32)
            .collect();
        assert_eq!(want.len(), xs.len());
        for (i, (&x, &wb)) in xs.iter().zip(&want).enumerate() {
            let got = quantize_shifted(x, fe, fmt, Rounding::NearestEven);
            let w = f32::from_bits(wb);
            let ok = if got.is_nan() || w.is_nan() {
                got.is_nan() && w.is_nan()
            } else {
                got.to_bits() == wb
            };
            assert!(
                ok,
                "fmt {fmt} fe {fe} input[{i}] = {x:e} (bits {:08x}): rust {got:e} ({:08x}) vs python {w:e} ({wb:08x})",
                x.to_bits(),
                got.to_bits()
            );
            checked += 1;
        }
    }
    println!("golden cast: {checked} values bit-exact across {} cases", cases.len());
}

//! The parameter-server topology suite (`sync.topology = "ps"`).
//!
//! `PsCollective` buffers contributions in logical rounds (one reduce
//! call = one round; an `L`-layer model advances `L` rounds per step),
//! folds each round's due arrivals sorted by `(origin round, worker)`,
//! and serves the result back over the transport seam. Pinned here:
//!
//! * **bit-exact replay** — a fixed arrival schedule replays
//!   bit-identically across sessions for every shipped codec, reports
//!   included, and the server shard count never changes a single bit
//!   (shards only partition the element space; the per-element fold
//!   chain is the sorted arrival order);
//! * **wire-mode agreement** — at staleness 0 the packed wire and the
//!   legacy simulated wire produce identical bits (with staleness the
//!   modes legitimately diverge: the packed path decodes at push time
//!   under the origin round's ctx, the dense path folds raw wire values
//!   decoded under the fold round's ctx);
//! * **bounded-staleness convergence** — the heterogeneous quadratic
//!   from the error-feedback suite still trains under per-worker
//!   arrival delays within the staleness budget `K`;
//! * **fault taxonomy** — a straggler past the read-patience budget
//!   surfaces as `FaultKind::Slow`, a killed peer as `FaultKind::Dead`,
//!   both as a clean `Err` from `step_checked` with the
//!   `step_overlapped`-style rollback (reduced emptied, report zeroed,
//!   `steps_done` unchanged): a partial fold never escapes;
//! * **elastic membership** — dropping and rejoining a worker mid-run
//!   re-shards deterministically and keeps every surviving round a
//!   complete fold;
//! * **transport-level wire honesty** — measured channel octets equal
//!   the claimed `WireCost` on every transport (both 0 for in-process,
//!   which moves references).

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::data::Rng;
use aps_cpd::sync::{
    FaultKind, StrategySpec, SyncSession, SyncSessionBuilder, TransportSpec, WireMode,
};

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// The same 11-codec roster the conformance and overlap suites pin.
fn codecs() -> Vec<(&'static str, StrategySpec)> {
    vec![
        ("fp32", StrategySpec::Fp32),
        ("naive/e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling/e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        ),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 42 }),
        ("topk@0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd b4/32", StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 }),
        ("ef:ternary", ef(StrategySpec::Ternary { seed: 42 })),
        ("ef:topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 })),
    ]
}

const WORLD: usize = 4;
const LAYERS: [usize; 5] = [33, 64, 128, 7, 256];

/// Deterministic mixed-scale gradients, different per worker and step.
fn grads(step: usize) -> Vec<Vec<Vec<f32>>> {
    (0..WORLD)
        .map(|w| {
            LAYERS
                .iter()
                .enumerate()
                .map(|(l, &n)| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 131 + l * 31 + i * 7 + step * 977) % 23;
                            let mag = match h % 4 {
                                0 => 1e-6,
                                1 => 0.125,
                                2 => 3.5,
                                _ => 96.0,
                            };
                            let sign = if h % 3 == 0 { -1.0 } else { 1.0 };
                            if h == 11 {
                                0.0
                            } else {
                                sign * mag * (1.0 + (h as f32) / 23.0)
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn ps_session(spec: &StrategySpec, shards: usize, staleness: usize) -> SyncSession {
    SyncSessionBuilder::new(WORLD)
        .spec(spec.clone())
        .with_topology(Topology::Ps { shards, staleness })
        .build()
}

fn to_bits(out: &[Vec<f32>]) -> Vec<Vec<u32>> {
    out.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect()
}

/// One step of worker 1 is `L` rounds: the session makes one reduce
/// call per layer, so per-step delays must be whole multiples of the
/// layer count (the collective asserts this rather than folding one
/// layer's stale gradient into another).
const L: usize = LAYERS.len();

/// Apply the canonical straggler schedule: worker 1 one step late,
/// worker 3 two steps late (both within a staleness budget of `2·L`
/// rounds).
fn apply_schedule(s: &mut SyncSession) {
    assert!(s.set_arrival_delay(1, L), "ps sessions accept delay schedules");
    assert!(s.set_arrival_delay(3, 2 * L));
}

#[test]
fn fixed_arrival_schedule_replays_bit_identically() {
    for (name, spec) in codecs() {
        let mut a = ps_session(&spec, 2, 2 * L);
        let mut b = ps_session(&spec, 2, 2 * L);
        apply_schedule(&mut a);
        apply_schedule(&mut b);
        for step in 0..4 {
            let g = grads(step);
            let (a_out, a_report) = a
                .step_checked(&g)
                .unwrap_or_else(|e| panic!("{name} step {step}: in-process PS faulted: {e}"));
            let a_out = to_bits(a_out);
            let a_report = a_report.clone();
            let (b_out, b_report) = b
                .step_checked(&g)
                .unwrap_or_else(|e| panic!("{name} step {step}: in-process PS faulted: {e}"));
            for (l, (al, bl)) in a_out.iter().zip(b_out.iter()).enumerate() {
                assert_eq!(al.len(), bl.len(), "{name} step {step} layer {l}: len");
                for (i, (&x, &y)) in al.iter().zip(bl.iter()).enumerate() {
                    assert_eq!(
                        x,
                        y.to_bits(),
                        "{name} step {step} layer {l} elem {i}: replay diverged"
                    );
                }
            }
            assert_eq!(&a_report, b_report, "{name} step {step}: reports diverged");
        }
        assert_eq!(a.steps_done(), 4, "{name}: every checked step counted");
        // In-process moves references: both sides of the honesty check
        // stay zero.
        let t = a.collective_traffic().unwrap_or_else(|| panic!("{name}: PS owns a transport"));
        assert_eq!((t.octets, t.claimed_octets), (0, 0), "{name}: in-process octets");
    }
}

/// The server shard count partitions the element space; it must never
/// change a fold chain — even mid-staleness, where arrival order does
/// the reordering.
#[test]
fn re_sharding_preserves_bits_under_staleness() {
    for (name, spec) in
        [("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }), ("ternary", StrategySpec::Ternary { seed: 42 })]
    {
        let mut reference: Vec<Vec<Vec<u32>>> = Vec::new();
        for shards in [1usize, 2, 4, 16] {
            let mut s = ps_session(&spec, shards, 2 * L);
            apply_schedule(&mut s);
            let mut steps: Vec<Vec<Vec<u32>>> = Vec::new();
            for step in 0..3 {
                let g = grads(step);
                let (out, _) = s
                    .step_checked(&g)
                    .unwrap_or_else(|e| panic!("{name}/shards={shards}: {e}"));
                steps.push(to_bits(out));
            }
            if reference.is_empty() {
                reference = steps;
            } else {
                assert_eq!(steps, reference, "{name}: shards={shards} changed bits");
            }
        }
    }
}

/// At staleness 0 the PS is synchronous and the packed wire must agree
/// bit-for-bit with the legacy simulated wire — same bits, same report
/// (the collective's per-round stats are wire-mode independent by
/// construction).
#[test]
fn synchronous_ps_matches_across_wire_modes() {
    for (name, spec) in codecs() {
        let mut packed = SyncSessionBuilder::new(WORLD)
            .spec(spec.clone())
            .with_topology(Topology::Ps { shards: 2, staleness: 0 })
            .with_wire(WireMode::Packed)
            .build();
        let mut sim = SyncSessionBuilder::new(WORLD)
            .spec(spec.clone())
            .with_topology(Topology::Ps { shards: 2, staleness: 0 })
            .with_wire(WireMode::Simulated)
            .build();
        for step in 0..2 {
            let g = grads(step);
            let (p_out, p_report) = packed.step_checked(&g).expect("packed PS step");
            let p_out = to_bits(p_out);
            let p_report = p_report.clone();
            let (s_out, s_report) = sim.step_checked(&g).expect("simulated PS step");
            for (l, (pl, sl)) in p_out.iter().zip(s_out.iter()).enumerate() {
                for (i, (&x, &y)) in pl.iter().zip(sl.iter()).enumerate() {
                    assert_eq!(
                        x,
                        y.to_bits(),
                        "{name} step {step} layer {l} elem {i}: wire modes diverge"
                    );
                }
            }
            assert_eq!(&p_report, s_report, "{name} step {step}: reports diverge");
        }
    }
}

/// PS flavor of the conformance contract's zero-step check: after a
/// dense synchronous round, a zero-gradient round reduces to exactly
/// zero for every memoryless codec (no stale pending entry, no wire
/// buffer leak). Error-feedback codecs legitimately flush residuals.
#[test]
fn zero_gradient_round_after_dense_is_zero() {
    for (name, spec) in codecs() {
        if matches!(spec, StrategySpec::ErrorFeedback { .. }) {
            continue;
        }
        let mut s = ps_session(&spec, 2, 0);
        let _ = s.step_checked(&grads(0)).expect("dense round");
        let zeros: Vec<Vec<Vec<f32>>> =
            (0..WORLD).map(|_| LAYERS.iter().map(|&n| vec![0.0f32; n]).collect()).collect();
        let (out, _) = s.step_checked(&zeros).expect("zero round");
        for (l, layer) in out.iter().enumerate() {
            assert!(
                layer.iter().all(|&v| v == 0.0),
                "{name} layer {l}: zero gradients must reduce to zero"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Bounded-staleness convergence on the error-feedback suite's
// heterogeneous quadratic: per-worker least-squares shards with
// zero-sum target shifts, so per-worker gradients stay large at the
// consensus optimum and stale arrivals genuinely perturb the fold.
// ---------------------------------------------------------------------

const D: usize = 16;
const ROWS: usize = 8;

struct Quadratic {
    x: Vec<Vec<Vec<f32>>>,
    y: Vec<Vec<f32>>,
}

fn build_problem() -> Quadratic {
    let mut rng = Rng::new(4242);
    let w_true: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
    let x: Vec<Vec<Vec<f32>>> = (0..WORLD)
        .map(|_| (0..ROWS).map(|_| (0..D).map(|_| rng.normal()).collect()).collect())
        .collect();
    let deltas: Vec<Vec<f32>> =
        (0..WORLD).map(|_| (0..D).map(|_| rng.normal()).collect()).collect();
    let mean: Vec<f32> =
        (0..D).map(|i| deltas.iter().map(|d| d[i]).sum::<f32>() / WORLD as f32).collect();
    let y = (0..WORLD)
        .map(|w| {
            x[w].iter()
                .map(|row| {
                    row.iter()
                        .enumerate()
                        .map(|(i, &v)| v * (w_true[i] + (deltas[w][i] - mean[i])))
                        .sum()
                })
                .collect()
        })
        .collect();
    Quadratic { x, y }
}

fn worker_grad(q: &Quadratic, w: &[f32], k: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; D];
    for (row, &yk) in q.x[k].iter().zip(&q.y[k]) {
        let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
        let e = (pred - yk) / ROWS as f32;
        for (gi, &xi) in g.iter_mut().zip(row) {
            *gi += e * xi;
        }
    }
    g
}

fn loss(q: &Quadratic, w: &[f32]) -> f64 {
    let mut tot = 0.0f64;
    for k in 0..WORLD {
        for (row, &yk) in q.x[k].iter().zip(&q.y[k]) {
            let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
            tot += ((pred - yk) as f64).powi(2);
        }
    }
    tot / (WORLD * ROWS) as f64
}

/// Train the quadratic through a PS session with the given staleness
/// schedule (the model has one layer, so delays are whole steps).
fn train_ps_quadratic(
    spec: StrategySpec,
    staleness: usize,
    delays: &[(usize, usize)],
) -> f64 {
    const STEPS: usize = 400;
    const LR: f32 = 0.05;
    let q = build_problem();
    let mut w = vec![0.0f32; D];
    let mut session = SyncSessionBuilder::new(WORLD)
        .spec(spec)
        .with_topology(Topology::Ps { shards: 2, staleness })
        .build();
    for &(worker, rounds) in delays {
        assert!(session.set_arrival_delay(worker, rounds));
    }
    for _ in 0..STEPS {
        let grads: Vec<Vec<Vec<f32>>> =
            (0..WORLD).map(|k| vec![worker_grad(&q, &w, k)]).collect();
        let (reduced, _) = session.step_checked(&grads).expect("in-process PS never faults");
        for (wi, &gi) in w.iter_mut().zip(reduced[0].iter()) {
            *wi -= LR * gi;
        }
        assert!(w.iter().all(|v| v.is_finite()), "stale PS training diverged");
    }
    loss(&q, &w)
}

#[test]
fn bounded_staleness_converges_on_the_quadratic() {
    let q = build_problem();
    let initial = loss(&q, &vec![0.0f32; D]);
    // Worker 1 one step late, worker 3 two steps late, both within K=2.
    let schedule: &[(usize, usize)] = &[(1, 1), (3, 2)];
    for (name, spec) in [
        ("fp32", StrategySpec::Fp32),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
    ] {
        let synchronous = train_ps_quadratic(spec.clone(), 0, &[]);
        let stale = train_ps_quadratic(spec, 2, schedule);
        assert!(
            synchronous < 0.5 * initial,
            "{name}: synchronous PS failed to train ({initial:.3} -> {synchronous:.3})"
        );
        assert!(
            stale < 0.5 * initial,
            "{name}: staleness-2 PS failed to train ({initial:.3} -> {stale:.3})"
        );
    }
}

// ---------------------------------------------------------------------
// Fault taxonomy and elastic membership.
// ---------------------------------------------------------------------

/// A small single-layer model keeps the TCP fault tests fast and makes
/// arrival delays whole steps.
fn small_grads(step: usize) -> Vec<Vec<Vec<f32>>> {
    (0..WORLD)
        .map(|w| vec![(0..64).map(|i| ((w * 13 + i * 7 + step * 31) % 17) as f32 * 0.25 - 2.0).collect()])
        .collect()
}

fn ps_tcp_session() -> SyncSession {
    SyncSessionBuilder::new(WORLD)
        .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
        .with_topology(Topology::Ps { shards: 2, staleness: 0 })
        .with_transport(TransportSpec::Tcp)
        .build()
}

/// Rollback contract shared by both fault flavors: the failed step is
/// uncounted, outputs emptied, report zeroed — no partial fold escapes.
fn assert_rolled_back(s: &SyncSession, steps_before: u64) {
    assert_eq!(s.steps_done(), steps_before, "failed step must not count");
    assert!(s.reduced().iter().all(|l| l.is_empty()), "reduced must be emptied");
    assert!(s.report().layers.is_empty(), "report must be zeroed");
    assert_eq!(s.report().messages, 0);
    assert_eq!(s.wire_moved(), None);
}

/// A peer slower than the read-patience budget is a *straggler*: the
/// step fails cleanly with `FaultKind::Slow` naming the worker — the
/// caller can wait it out or drop the member, but it is not dead.
#[test]
fn straggler_past_patience_is_slow_not_dead() {
    let mut s = ps_tcp_session();
    let (_, report) = s.step_checked(&small_grads(0)).expect("healthy step");
    assert_eq!(report.layers.len(), 1);
    assert_eq!(s.steps_done(), 1);

    assert!(s.set_transport_patience(10, 2), "PS transport accepts a patience budget");
    assert!(s.inject_transport_delay(1, 500), "PS transport accepts send delays");
    let err = s.step_checked(&small_grads(1)).expect_err("straggler must fail the step");
    assert_eq!(err.kind, FaultKind::Slow, "a straggler is slow, not dead: {err}");
    assert_eq!(err.worker, 1, "the error names the straggler: {err}");
    assert_eq!(err.transport, "tcp");
    assert_rolled_back(&s, 1);
}

/// A straggler within the patience budget is absorbed: the step blocks
/// briefly and succeeds.
#[test]
fn sub_patience_straggler_is_absorbed() {
    let mut s = ps_tcp_session();
    assert!(s.set_transport_patience(250, 4));
    assert!(s.inject_transport_delay(1, 30));
    for step in 0..2 {
        let _ = s.step_checked(&small_grads(step)).expect("sub-patience delay must succeed");
    }
    assert_eq!(s.steps_done(), 2);
    let t = s.collective_traffic().expect("PS owns a transport");
    assert_eq!(t.octets, t.claimed_octets, "octets must match the claimed WireCost");
    assert!(t.octets > 0, "TCP serializes every frame");
}

/// A killed peer is *dead*: EOF/reset, not a timeout — and the same
/// clean rollback applies.
#[test]
fn dead_peer_is_dead_not_slow() {
    let mut s = ps_tcp_session();
    let _ = s.step_checked(&small_grads(0)).expect("healthy step");
    assert_eq!(s.steps_done(), 1);

    assert!(s.kill_transport_peer(2), "the session forwards the kill to the PS transport");
    let err = s.step_checked(&small_grads(1)).expect_err("killed peer must fail the step");
    assert_eq!(err.kind, FaultKind::Dead, "a reset peer is dead, not slow: {err}");
    assert_eq!(err.worker, 2, "the error names the dropped peer: {err}");
    assert_eq!(err.transport, "tcp");
    assert_rolled_back(&s, 1);
}

/// Elastic membership: dropping a worker mid-run excludes it from every
/// subsequent fold (a re-shard over the survivors), rejoin restores it,
/// and the whole schedule replays bit-identically — including across
/// different server shard counts, since membership changes only re-split
/// the element space.
#[test]
fn elastic_drop_and_rejoin_replays_deterministically() {
    for (name, spec) in [
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 42 })),
    ] {
        let mut reference: Vec<Vec<Vec<u32>>> = Vec::new();
        for shards in [2usize, 4] {
            let mut s = ps_session(&spec, shards, 0);
            let mut steps: Vec<Vec<Vec<u32>>> = Vec::new();
            for step in 0..4 {
                if step == 1 {
                    assert!(s.set_member_active(1, false), "drop worker 1 mid-run");
                }
                if step == 3 {
                    assert!(s.set_member_active(1, true), "rejoin worker 1");
                }
                let g = grads(step);
                let (out, _) = s
                    .step_checked(&g)
                    .unwrap_or_else(|e| panic!("{name}/shards={shards} step {step}: {e}"));
                // Never a partial fold: every layer comes back full-length.
                for (l, (layer, &n)) in out.iter().zip(LAYERS.iter()).enumerate() {
                    assert_eq!(layer.len(), n, "{name} step {step} layer {l}: truncated fold");
                }
                steps.push(to_bits(out));
            }
            if reference.is_empty() {
                reference = steps;
            } else {
                assert_eq!(steps, reference, "{name}: shards={shards} changed the replay");
            }
        }
    }
}

/// Transport-level wire honesty for the PS push/pull legs: on every
/// serializing transport the measured channel octets equal the
/// encode-side claimed bytes exactly, for every codec.
#[test]
fn octets_match_claimed_wire_cost_on_shared_mem() {
    for (name, spec) in codecs() {
        let mut s = SyncSessionBuilder::new(WORLD)
            .spec(spec.clone())
            .with_topology(Topology::Ps { shards: 2, staleness: 0 })
            .with_transport(TransportSpec::SharedMem)
            .build();
        for step in 0..2 {
            let _ = s.step_checked(&grads(step)).expect("shared-mem PS step");
        }
        let t = s.collective_traffic().unwrap_or_else(|| panic!("{name}: PS owns a transport"));
        assert_eq!(
            t.octets, t.claimed_octets,
            "{name}: transport moved octets != claimed octets"
        );
        assert!(t.octets > 0, "{name}: serializing transport moved nothing");
    }
}

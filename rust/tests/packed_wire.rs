//! The packed-wire suite: the bit-packed path must be a *perfect*
//! transcode of the simulated-f32 path.
//!
//! * **bit-identity** — for every conformance strategy (the same 11 the
//!   codec contract covers), a session on the packed wire produces
//!   bit-identical decoded gradients and `SyncReport`s to a session on
//!   the simulated wire, on hostile `nasty_f32` inputs, across worlds,
//!   topologies and multiple steps;
//! * **measured == claimed** — the packed buffers' `moved_cost` equals
//!   the codec's `wire_cost` field-for-field, and `packed_len` never
//!   exceeds `WireCost::total_bytes` (the honest figure rounded up to
//!   whole bytes) — including the raw-f32 escapes for non-finite layers;
//! * **BitWriter/BitReader** — round-trips at every width 1..=32 across
//!   word boundaries through the public API.

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::{FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::sync::{
    BitReader, BitWriter, LayerCtx, PackedWire, StrategySpec, SyncSessionBuilder, SyncStrategy,
    WireMode,
};
use aps_cpd::util::ptest::generators;

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// The same 11-codec family the conformance contract pins.
fn specs() -> Vec<(&'static str, StrategySpec)> {
    vec![
        ("fp32", StrategySpec::Fp32),
        ("naive/e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling/e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        ),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 9 }),
        ("topk@0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd b4/32", StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 }),
        ("ef:ternary", ef(StrategySpec::Ternary { seed: 9 })),
        ("ef:topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 })),
    ]
}

/// Hostile per-worker gradients: every worker/layer filled from the
/// shared `nasty_f32` stream (subnormals, huge magnitudes, ±0, exact
/// powers of two), equal shapes across workers.
fn nasty_grads(rng: &mut Rng, world: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|_| {
            layers
                .iter()
                .map(|&n| (0..n).map(|_| generators::nasty_f32(rng)).collect())
                .collect()
        })
        .collect()
}

#[test]
fn packed_path_is_bit_identical_to_simulated_for_every_strategy() {
    let layers = [33usize, 64, 9];
    for (label, spec) in &specs() {
        for (world, topo) in [
            (1usize, Topology::Ring),
            (4, Topology::Ring),
            (8, Topology::Ring),
            (8, Topology::Hierarchical { group_size: 4 }),
        ] {
            let mut rng = Rng::new(0xAB5EED ^ world as u64 ^ label.len() as u64);
            let mut packed = SyncSessionBuilder::new(world)
                .spec(spec.clone())
                .with_topology(topo)
                .build();
            let mut sim = SyncSessionBuilder::new(world)
                .spec(spec.clone())
                .with_topology(topo)
                .with_wire(WireMode::Simulated)
                .build();
            for step in 0..3 {
                let grads = nasty_grads(&mut rng, world, &layers);
                let (po, pr) = packed.step(&grads);
                let po = po.to_vec();
                let pr = pr.clone();
                let (so, sr) = sim.step(&grads);
                for (l, (a, b)) in po.iter().zip(so.iter()).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                             packed {x:e} vs simulated {y:e}"
                        );
                    }
                }
                assert_eq!(&pr, sr, "{label}/{topo:?} w{world} step {step}: reports diverged");
                // And the packed path's measured traffic equals the
                // honest accounting (nasty_f32 draws are all finite, so
                // no escape-representation slack applies).
                assert_eq!(
                    packed.wire_moved(),
                    Some(pr.wire),
                    "{label}/{topo:?} w{world} step {step}: moved != claimed"
                );
            }
        }
    }
}

fn encode_ctx(fmt: FpFormat, world: usize) -> LayerCtx {
    LayerCtx {
        layer: 0,
        num_layers: 1,
        worker: 0,
        world,
        factor_exp: 0,
        fmt,
        fp32_passthrough: false,
        rounding: Rounding::NearestEven,
        average: true,
        step: 0,
    }
}

/// Direct encode → pack → unpack for one strategy on one input: packed
/// buffers must reproduce the f32 wire values bit-for-bit (full range and
/// sub-ranges), match `wire_cost` exactly, and never exceed its byte
/// figure.
fn check_transcode(label: &str, spec: &StrategySpec, xs: &[f32]) {
    let mut strategy = spec.build();
    let ctx = encode_ctx(strategy.wire_format(), 2);
    let n = xs.len();
    let mut encoded = vec![f32::NAN; n];
    strategy.encode(xs, &ctx, &mut encoded);
    let cost = strategy.wire_cost(&encoded, &ctx);
    let mut pw = PackedWire::default();
    strategy.encode_packed(&encoded, &ctx, &mut pw);

    assert_eq!(pw.moved_cost(), cost, "{label}: packed buffer diverges from wire_cost");
    assert!(
        pw.packed_len() <= cost.total_bytes(),
        "{label}: packed_len {} exceeds WireCost bytes {}",
        pw.packed_len(),
        cost.total_bytes()
    );

    let mut dec = vec![0.0f32; n];
    strategy.decode_packed(&pw, &ctx, 0..n, &mut dec);
    for (i, (a, b)) in encoded.iter().zip(&dec).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{label} elem {i}: encoded {a:e} vs unpacked {b:e}"
        );
    }
    // arbitrary sub-ranges (cache-blocked consumption pattern)
    let mut rng = Rng::new(n as u64 + label.len() as u64);
    for _ in 0..8 {
        let lo = rng.below(n);
        let hi = lo + 1 + rng.below(n - lo);
        let mut seg = vec![f32::NAN; hi - lo];
        strategy.decode_packed(&pw, &ctx, lo..hi, &mut seg);
        for (k, b) in seg.iter().enumerate() {
            assert_eq!(
                encoded[lo + k].to_bits(),
                b.to_bits(),
                "{label} range {lo}..{hi} offset {k}"
            );
        }
    }
}

#[test]
fn transcode_matches_wire_cost_on_hostile_inputs() {
    let mut rng = Rng::new(0xBEEF);
    for (label, spec) in &specs() {
        for case in 0..40 {
            let xs = generators::nasty_vec(&mut rng, 96);
            check_transcode(&format!("{label} case {case}"), spec, &xs);
        }
    }
}

#[test]
fn non_finite_layers_escape_to_raw_f32_with_matching_cost() {
    // Divergent gradients have no 2-bit/`bits`-wide code; those layers
    // ship raw f32 and the cost accounting reports the same dense FP32
    // figure — `moved == wire_cost` stays exact even here.
    let mut xs: Vec<f32> = (0..40).map(|i| (i as f32 - 20.0) * 0.3).collect();
    xs[3] = f32::NAN;
    xs[17] = f32::INFINITY;
    xs[31] = f32::NEG_INFINITY;
    for (label, spec) in &specs() {
        check_transcode(&format!("{label} non-finite"), spec, &xs);
    }
}

#[test]
fn fp32_passthrough_layers_ship_dense_on_the_packed_wire() {
    // Under the fp32-last-layer policy the protected layer must ride the
    // packed wire as raw f32 — and the session paths must still agree.
    let world = 4;
    let grads = nasty_grads(&mut Rng::new(77), world, &[24, 16]);
    for spec in [
        StrategySpec::Ternary { seed: 3 },
        StrategySpec::Qsgd { bits: 4, bucket: 8, seed: 3 },
        StrategySpec::TopK { frac: 0.5 },
        StrategySpec::Naive { fmt: FpFormat::E5M2 },
    ] {
        let mut packed = SyncSessionBuilder::new(world)
            .spec(spec.clone())
            .with_fp32_last_layer(true)
            .build();
        let mut sim = SyncSessionBuilder::new(world)
            .spec(spec.clone())
            .with_fp32_last_layer(true)
            .with_wire(WireMode::Simulated)
            .build();
        let (po, pr) = packed.step(&grads);
        let po = po.to_vec();
        let pr = pr.clone();
        let (so, sr) = sim.step(&grads);
        for (l, (a, b)) in po.iter().zip(so.iter()).enumerate() {
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{spec:?} layer {l} elem {i}");
            }
        }
        assert_eq!(&pr, sr, "{spec:?} report");
        assert_eq!(packed.wire_moved(), Some(pr.wire), "{spec:?} moved != claimed");
        // the protected 16-element layer pays dense FP32 per worker
        assert!(pr.wire.value_bits >= 16 * 32, "{spec:?}: {:?}", pr.wire);
    }
}

#[test]
fn bit_writer_reader_roundtrip_widths_1_to_32_across_word_boundaries() {
    let mut rng = Rng::new(0x817);
    // Fixed-width streams at every width…
    for width in 1..=32u32 {
        let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let vals: Vec<u32> = (0..131).map(|_| rng.next_u64() as u32 & mask).collect();
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        for &v in &vals {
            w.put(v, width);
        }
        let bits = w.finish();
        assert_eq!(bits, vals.len() as u64 * width as u64);
        let mut r = BitReader::new(&buf);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(r.read(width), v, "width {width} elem {i}");
        }
    }
    // …and one mixed-width stream, re-read from random offsets.
    let mut buf = Vec::new();
    let mut w = BitWriter::new(&mut buf);
    let mut entries = Vec::new();
    let mut off = 0u64;
    for _ in 0..1000 {
        let width = 1 + rng.below(32) as u32;
        let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let v = rng.next_u64() as u32 & mask;
        w.put(v, width);
        entries.push((off, width, v));
        off += width as u64;
    }
    w.finish();
    for &(off, width, v) in &entries {
        let mut r = BitReader::at(&buf, off);
        assert_eq!(r.read(width), v, "offset {off} width {width}");
    }
}

//! Error-feedback convergence suite.
//!
//! The point of `sync::ErrorFeedback` is that residual memory turns lossy
//! codecs into convergent ones. This suite pins that claim on a
//! deterministic quadratic toy problem with *heterogeneous* workers:
//! each worker's least-squares shard pulls toward a different optimum
//! (zero-sum shifts), so per-worker gradients stay large at the consensus
//! optimum and codec noise cannot vanish on its own — exactly the regime
//! where memoryless compression plateaus and error feedback keeps
//! converging. The metric is *excess* loss over the FP32 floor of the
//! same trajectory length.
//!
//! Thresholds were calibrated across 10 codec seeds; every asserted
//! ratio sits ≥ 1.6× above the worst observed case (and ≥ 2× above the
//! seed actually used, post seed-domain-separation).

use aps_cpd::cpd::{FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::sync::{ErrorFeedback, Fp32Strategy, LayerCtx, StrategySpec, SyncSessionBuilder};

const WORLD: usize = 4;
const D: usize = 16;
const ROWS: usize = 8;

/// Per-worker least-squares shards `(X_w, y_w)` with zero-sum target
/// heterogeneity: `y_w = X_w (w* + δ_w)`, `Σ δ_w = 0`.
struct Quadratic {
    x: Vec<Vec<Vec<f32>>>,
    y: Vec<Vec<f32>>,
}

fn build_problem() -> Quadratic {
    let mut rng = Rng::new(4242);
    let w_true: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
    let x: Vec<Vec<Vec<f32>>> = (0..WORLD)
        .map(|_| (0..ROWS).map(|_| (0..D).map(|_| rng.normal()).collect()).collect())
        .collect();
    let deltas: Vec<Vec<f32>> = (0..WORLD)
        .map(|_| (0..D).map(|_| rng.normal()).collect())
        .collect();
    let mean: Vec<f32> =
        (0..D).map(|i| deltas.iter().map(|d| d[i]).sum::<f32>() / WORLD as f32).collect();
    let y = (0..WORLD)
        .map(|w| {
            x[w]
                .iter()
                .map(|row| {
                    row.iter()
                        .enumerate()
                        .map(|(i, &v)| v * (w_true[i] + (deltas[w][i] - mean[i])))
                        .sum()
                })
                .collect()
        })
        .collect();
    Quadratic { x, y }
}

/// Worker `k`'s full-batch gradient of ½‖X_k w − y_k‖²/ROWS.
fn worker_grad(q: &Quadratic, w: &[f32], k: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; D];
    for (row, &yk) in q.x[k].iter().zip(&q.y[k]) {
        let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
        let e = (pred - yk) / ROWS as f32;
        for (gi, &xi) in g.iter_mut().zip(row) {
            *gi += e * xi;
        }
    }
    g
}

/// Mean squared residual over every worker's shard.
fn loss(q: &Quadratic, w: &[f32]) -> f64 {
    let mut tot = 0.0f64;
    for k in 0..WORLD {
        for (row, &yk) in q.x[k].iter().zip(&q.y[k]) {
            let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
            tot += ((pred - yk) as f64).powi(2);
        }
    }
    tot / (WORLD * ROWS) as f64
}

/// Train the quadratic through a session with `spec`; returns final loss.
fn train_quadratic(spec: StrategySpec, steps: usize, lr: f32) -> f64 {
    let q = build_problem();
    let mut w = vec![0.0f32; D];
    let mut session = SyncSessionBuilder::new(WORLD).spec(spec).build();
    for _ in 0..steps {
        let grads: Vec<Vec<Vec<f32>>> =
            (0..WORLD).map(|k| vec![worker_grad(&q, &w, k)]).collect();
        let (reduced, _) = session.step(&grads);
        for (wi, &gi) in w.iter_mut().zip(reduced[0].iter()) {
            *wi -= lr * gi;
        }
        assert!(w.iter().all(|v| v.is_finite()), "{} diverged", session.strategy_name());
    }
    loss(&q, &w)
}

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// Shared comparison: EF-wrapped `spec` must land at a fraction of the
/// memoryless codec's excess loss over the FP32 floor.
fn assert_ef_out_converges(spec: StrategySpec, max_ratio: f64) {
    const STEPS: usize = 400;
    const LR: f32 = 0.05;
    let label = spec.label();
    let q = build_problem();
    let initial = loss(&q, &vec![0.0f32; D]);
    let floor = train_quadratic(StrategySpec::Fp32, STEPS, LR);
    let plain = train_quadratic(spec.clone(), STEPS, LR);
    let with_ef = train_quadratic(ef(spec), STEPS, LR);
    assert!(
        plain < 0.8 * initial,
        "{label}: memoryless run failed to make progress ({initial:.3} -> {plain:.3})"
    );
    let plain_excess = plain - floor;
    let ef_excess = with_ef - floor;
    assert!(
        plain_excess > 0.01,
        "{label}: memoryless codec shows no plateau (excess {plain_excess:.4}) — \
         comparison is meaningless"
    );
    assert!(
        ef_excess < max_ratio * plain_excess,
        "{label}: error feedback should cut the excess loss to < {max_ratio} of \
         memoryless (floor {floor:.4}, plain +{plain_excess:.4}, ef +{ef_excess:.4})"
    );
}

#[test]
fn ef_ternary_out_converges_memoryless_ternary() {
    // calibrated worst observed ratio: 0.24
    assert_ef_out_converges(StrategySpec::Ternary { seed: 42 }, 0.8);
}

#[test]
fn ef_topk_out_converges_memoryless_topk() {
    // memoryless top-k@0.125 plateaus an order of magnitude above the
    // floor here; calibrated worst observed ratio: 0.01
    assert_ef_out_converges(StrategySpec::TopK { frac: 0.125 }, 0.2);
}

#[test]
fn ef_qsgd_out_converges_memoryless_qsgd() {
    // 2-bit, tiny buckets — coarse enough to plateau without memory;
    // calibrated worst observed ratio: 0.48
    assert_ef_out_converges(StrategySpec::Qsgd { bits: 2, bucket: 8, seed: 42 }, 0.8);
}

#[test]
fn fp32_under_error_feedback_keeps_residuals_exactly_zero() {
    // Lossless codec ⇒ nothing is ever dropped ⇒ residual memory stays
    // identically zero, driven straight through the strategy API on
    // hostile inputs.
    let mut strat = ErrorFeedback::new(Fp32Strategy);
    let mut rng = Rng::new(99);
    for step in 0..10u64 {
        for worker in 0..3usize {
            let xs: Vec<f32> = (0..57)
                .map(|_| {
                    let e = rng.range(-30.0, 30.0);
                    (rng.uniform() - 0.5) * e.exp2()
                })
                .collect();
            let ctx = LayerCtx {
                layer: 0,
                num_layers: 1,
                worker,
                world: 3,
                factor_exp: 0,
                fmt: FpFormat::FP32,
                fp32_passthrough: false,
                rounding: Rounding::NearestEven,
                average: true,
                step,
            };
            let mut out = vec![0.0f32; xs.len()];
            use aps_cpd::sync::SyncStrategy;
            strat.encode(&xs, &ctx, &mut out);
            assert_eq!(out, xs, "lossless wire must be the identity");
            assert!(
                strat.residual(worker, 0).iter().all(|&r| r == 0.0),
                "step {step} worker {worker}: nonzero residual under a lossless codec"
            );
        }
    }
    assert_eq!(strat.residual_l1(), 0.0);
}

#[test]
fn ef_session_reports_match_inner_codec_accounting() {
    // Wrapping must not change what goes on the wire when residuals are
    // zero — including the WireCost accounting the report carries.
    let grads: Vec<Vec<Vec<f32>>> = (0..WORLD)
        .map(|w| vec![(0..40).map(|i| ((w * 13 + i * 7) % 11) as f32 * 0.1 - 0.5).collect()])
        .collect();
    let mut plain = SyncSessionBuilder::new(WORLD)
        .spec(StrategySpec::Qsgd { bits: 4, bucket: 16, seed: 3 })
        .build();
    let mut wrapped = SyncSessionBuilder::new(WORLD)
        .spec(ef(StrategySpec::Qsgd { bits: 4, bucket: 16, seed: 3 }))
        .build();
    let (_, pr) = plain.step(&grads);
    let pr = pr.clone();
    let (_, wr) = wrapped.step(&grads);
    assert_eq!(pr.wire, wr.wire, "first-step wire accounting must match");
    assert_eq!(pr.payload_bytes, wr.payload_bytes);
}

//! The cross-strategy codec conformance suite.
//!
//! Every shipped codec — the four paper methods, the net-new ternary /
//! top-k / QSGD codecs, and their error-feedback-wrapped variants — must
//! satisfy one shared contract, checked here by a single generic harness
//! (`assert_codec_contract`). A codec added tomorrow gets pinned by
//! adding one line to `CODECS`. The contract:
//!
//! 1. **encode writes every element** — no stale wire-buffer reuse can
//!    leak a previous step's values;
//! 2. **wire costs never under-report** — `value_bits + index_bits` is at
//!    least one bit per transmitted nonzero. This is a floor, not an
//!    exactness proof: each codec's precise cost formula (top-k's
//!    nnz·(32+⌈log2 n⌉), QSGD's n·bits + 4B/bucket, ternary's 2n bits)
//!    is pinned value-for-value by its own unit tests in
//!    `sync::strategies`;
//! 3. **round-trips stay bounded** on hostile inputs (subnormals, huge
//!    magnitudes, exact powers of two): every world-1 decoded element is
//!    either within `2·max|g|` (the worst any magnitude-preserving codec
//!    can round up to) or non-finite *with the overflow reported*;
//! 4. **determinism** — identically-built sessions replay bit-identically,
//!    reports included (stochastic codecs are keyed by seed + step);
//! 5. **ragged inputs panic** — shape errors fail loudly before any codec
//!    sees a buffer, for every strategy.

use std::panic::{catch_unwind, AssertUnwindSafe};

use aps_cpd::cpd::{FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::sync::{
    LayerCtx, StrategySpec, SyncSession, SyncSessionBuilder, SyncStrategy, WireMode,
};
use aps_cpd::util::ptest::generators;

/// One conformance subject: a label, a fresh-strategy factory, and
/// whether the codec carries cross-step memory (error feedback), which
/// legitimately couples one step's output to earlier inputs.
struct Codec {
    label: &'static str,
    has_memory: bool,
    spec: fn() -> StrategySpec,
}

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

fn codecs() -> Vec<Codec> {
    vec![
        Codec { label: "fp32", has_memory: false, spec: || StrategySpec::Fp32 },
        Codec {
            label: "naive/e5m2",
            has_memory: false,
            spec: || StrategySpec::Naive { fmt: FpFormat::E5M2 },
        },
        Codec {
            label: "loss_scaling/e5m2",
            has_memory: false,
            spec: || StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        },
        Codec {
            label: "aps/e5m2",
            has_memory: false,
            spec: || StrategySpec::Aps { fmt: FpFormat::E5M2 },
        },
        Codec {
            label: "aps/e4m3",
            has_memory: false,
            spec: || StrategySpec::Aps { fmt: FpFormat::E4M3 },
        },
        Codec { label: "ternary", has_memory: false, spec: || StrategySpec::Ternary { seed: 9 } },
        Codec {
            label: "topk@0.25",
            has_memory: false,
            spec: || StrategySpec::TopK { frac: 0.25 },
        },
        Codec {
            label: "qsgd b4/32",
            has_memory: false,
            spec: || StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 },
        },
        Codec {
            label: "ef:ternary",
            has_memory: true,
            spec: || ef(StrategySpec::Ternary { seed: 9 }),
        },
        Codec {
            label: "ef:topk",
            has_memory: true,
            spec: || ef(StrategySpec::TopK { frac: 0.25 }),
        },
        Codec {
            label: "ef:qsgd",
            has_memory: true,
            spec: || ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 }),
        },
    ]
}

fn session(codec: &Codec, world: usize, mode: WireMode) -> SyncSession {
    SyncSessionBuilder::new(world).spec((codec.spec)()).with_wire(mode).build()
}

/// Deterministic mixed-scale per-worker gradients.
fn scaled_grads(world: usize, salt: usize, layers: &[(usize, f32)]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &(n, scale))| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 2654435761 + l * 97 + i * 131 + salt * 7919) % 2003;
                            (h as f32 / 2003.0 - 0.5) * scale
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn encode_ctx(fmt: FpFormat, n_layers: usize) -> LayerCtx {
    LayerCtx {
        layer: 0,
        num_layers: n_layers,
        worker: 0,
        world: 2,
        factor_exp: 0,
        fmt,
        fp32_passthrough: false,
        rounding: Rounding::NearestEven,
        average: true,
        step: 0,
    }
}

/// Contract 1+2: direct encode on hostile inputs writes every element,
/// and the codec's claimed wire cost covers what it actually shipped.
fn check_encode_and_wire_cost(codec: &Codec) {
    let mut rng = Rng::new(0xC0DEC);
    for case in 0..120 {
        let xs = generators::nasty_vec(&mut rng, 96);
        let mut strategy = (codec.spec)().build();
        let ctx = encode_ctx(strategy.wire_format(), 1);
        let mut out = vec![f32::NAN; xs.len()];
        strategy.encode(&xs, &ctx, &mut out);
        assert!(
            out.iter().all(|v| !v.is_nan()),
            "{} case {case}: encode left unwritten (NaN) wire elements for finite input",
            codec.label
        );
        let cost = strategy.wire_cost(&out, &ctx);
        let nnz = out.iter().filter(|&&v| v != 0.0).count() as u64;
        assert!(
            cost.value_bits + cost.index_bits >= nnz,
            "{} case {case}: wire cost {cost:?} under-reports {nnz} transmitted values",
            codec.label
        );
    }
}

/// Contract 3: a world-1 no-averaging round trip through the full
/// session keeps every element bounded by 2·max|g| — or reports the
/// overflow that produced a non-finite value.
fn check_roundtrip_bound(codec: &Codec, mode: WireMode) {
    let mut rng = Rng::new(0xB0DE ^ codec.label.len() as u64);
    for case in 0..80 {
        let xs = generators::nasty_vec(&mut rng, 64);
        let max_abs = xs.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64));
        let mut s = SyncSessionBuilder::new(1)
            .spec((codec.spec)())
            .with_average(false)
            .with_wire(mode)
            .build();
        let grads = vec![vec![xs.clone()]];
        let (out, report) = s.step(&grads);
        // 2·max|g| is the worst any magnitude-preserving codec can round
        // up to; the 2^-128 floor covers scale exponents pinned at the
        // bottom of their i8 agreement range (ternary on all-subnormal
        // layers).
        let bound = (2.0 * max_abs).max(2f64.powi(-128)) * (1.0 + 1e-5);
        for (i, &v) in out[0].iter().enumerate() {
            if v.is_finite() {
                assert!(
                    (v.abs() as f64) <= bound,
                    "{} case {case} elem {i}: |{v:e}| escapes the 2·max bound {bound:e} \
                     (input {:e})",
                    codec.label,
                    xs[i]
                );
            } else {
                assert!(
                    report.any_overflow(),
                    "{} case {case} elem {i}: non-finite output {v} with no overflow reported",
                    codec.label
                );
            }
        }
    }
}

/// Contract 4: identically-built sessions replay bit-identically across
/// multiple steps — outputs and reports.
fn check_determinism(codec: &Codec, mode: WireMode) {
    let world = 4;
    let mut a = session(codec, world, mode);
    let mut b = session(codec, world, mode);
    for step in 0..3 {
        let grads = scaled_grads(world, step, &[(33, 1.0), (8, 1e-5)]);
        let (oa, ra) = a.step(&grads);
        let oa = oa.to_vec();
        let ra = ra.clone();
        let (ob, rb) = b.step(&grads);
        for (l, (x, y)) in oa.iter().zip(ob.iter()).enumerate() {
            for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "{} step {step} layer {l} elem {i}: replay diverged",
                    codec.label
                );
            }
        }
        assert_eq!(&ra, rb, "{} step {step}: reports diverged", codec.label);
    }
}

/// Contract 5: ragged inputs panic before any codec work happens.
fn check_ragged_panics(codec: &Codec, mode: WireMode) {
    let ragged_lengths = vec![vec![vec![1.0f32; 4]], vec![vec![1.0f32; 5]]];
    let r = catch_unwind(AssertUnwindSafe(|| {
        let mut s = session(codec, 2, mode);
        let _ = s.step(&ragged_lengths);
    }));
    assert!(r.is_err(), "{}: ragged layer lengths must panic", codec.label);

    let ragged_counts = vec![vec![vec![1.0f32; 4]], vec![]];
    let r = catch_unwind(AssertUnwindSafe(|| {
        let mut s = session(codec, 2, mode);
        let _ = s.step(&ragged_counts);
    }));
    assert!(r.is_err(), "{}: ragged layer counts must panic", codec.label);
}

/// Memoryless codecs only: a zero-gradient step right after a dense step
/// must produce an all-zero reduction (stale wire buffers overwritten,
/// no hidden state).
fn check_zero_step_after_dense(codec: &Codec, mode: WireMode) {
    let world = 2;
    let mut s = session(codec, world, mode);
    let dense = scaled_grads(world, 1, &[(24, 1.0)]);
    let _ = s.step(&dense);
    let zeros = vec![vec![vec![0.0f32; 24]]; world];
    let (out, _) = s.step(&zeros);
    assert!(
        out[0].iter().all(|&v| v == 0.0),
        "{}: zero gradients must reduce to zero (stale buffer leak?)",
        codec.label
    );
}

/// The session-level contract for one codec under one wire mode (the
/// ragged-input probe runs in its own test so the intentional panics can
/// be hook-silenced in one place; the direct-encode checks are
/// mode-independent and run once per codec in the test below).
fn assert_codec_contract(codec: &Codec, mode: WireMode) {
    check_roundtrip_bound(codec, mode);
    check_determinism(codec, mode);
    if !codec.has_memory {
        check_zero_step_after_dense(codec, mode);
    }
}

#[test]
fn every_strategy_satisfies_the_codec_contract() {
    // The packed leg: the session contract holds on the default packed
    // wire AND on the legacy simulated wire (bit-identity between the
    // two is pinned separately by rust/tests/packed_wire.rs); the
    // direct-encode wire-cost check bypasses the session, so once is
    // enough.
    for codec in &codecs() {
        check_encode_and_wire_cost(codec);
        assert_codec_contract(codec, WireMode::Packed);
        assert_codec_contract(codec, WireMode::Simulated);
    }
}

#[test]
fn ragged_inputs_panic_for_every_strategy() {
    // The probes panic on purpose; libtest captures per-test output, so
    // the intentional panic messages stay out of passing-run output and
    // no global panic-hook games (which would race parallel tests) are
    // needed.
    for codec in &codecs() {
        check_ragged_panics(codec, WireMode::Packed);
        check_ragged_panics(codec, WireMode::Simulated);
    }
}

#[test]
fn error_feedback_memory_is_the_only_contract_exemption() {
    // ef:topk deliberately fails the zero-step check — the residual is
    // real signal being flushed. Pin that behaviour so the exemption in
    // the harness stays honest.
    let world = 2;
    let mut s = SyncSessionBuilder::new(world)
        .spec(ef(StrategySpec::TopK { frac: 0.25 }))
        .build();
    let dense = scaled_grads(world, 1, &[(24, 1.0)]);
    let _ = s.step(&dense);
    let zeros = vec![vec![vec![0.0f32; 24]]; world];
    let (out, _) = s.step(&zeros);
    assert!(
        out[0].iter().any(|&v| v != 0.0),
        "ef:topk should flush residual signal on a zero-gradient step"
    );
}

#[test]
fn conformance_covers_at_least_seven_strategies() {
    assert!(codecs().len() >= 7, "contract must span the whole codec family");
}

//! End-to-end integration: train real models through the full stack
//! (PJRT-executed JAX HLO → simulated cluster → APS → optimizer) and
//! assert the paper's qualitative claims hold on the synthetic workloads:
//!
//! * FP32 training converges (loss decreases, accuracy ≫ chance);
//! * APS-8bit matches FP32 closely;
//! * aggressive loss scaling overflows where APS does not;
//! * the hybrid schedule switches methods at the right epoch.

use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::FpFormat;
use aps_cpd::optim::LrSchedule;
use aps_cpd::runtime::{Engine, Model};
use aps_cpd::sync::{StrategySpec, TransportSpec};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/mlp.json").exists()
}

fn load(engine: &Engine, name: &str) -> Model {
    engine.load_model("artifacts", name).expect("load model")
}

fn quick_setup(world: usize, method: SyncMethod) -> TrainerSetup {
    let mut s = TrainerSetup::new(world, SyncOptions::new(method));
    s.epochs = 2;
    s.steps_per_epoch = 12;
    s.eval_examples = 256;
    s.schedule = LrSchedule::Constant { lr: 0.08 };
    s
}

#[test]
fn mlp_fp32_training_converges() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");
    let mut t = Trainer::new(&model, quick_setup(4, SyncMethod::Fp32)).unwrap();
    let out = t.train("it-mlp-fp32").unwrap();
    assert!(!out.diverged);
    let first = out.loss.points.first().unwrap().1;
    let last = out.loss.tail_mean(5);
    assert!(last < first * 0.8, "loss {first} → {last}");
    assert!(out.final_metric > 0.3, "accuracy {}", out.final_metric); // chance = 0.1
}

#[test]
fn aps_8bit_tracks_fp32_and_naive_4bit_does_not() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");

    let fp32 = Trainer::new(&model, quick_setup(4, SyncMethod::Fp32))
        .unwrap()
        .train("fp32")
        .unwrap();
    let aps = Trainer::new(
        &model,
        quick_setup(4, SyncMethod::Aps { fmt: FpFormat::E5M2 }),
    )
    .unwrap()
    .train("aps-e5m2")
    .unwrap();

    assert!(!aps.diverged);
    assert!(
        aps.final_metric > fp32.final_metric - 0.12,
        "APS {} vs FP32 {}",
        aps.final_metric,
        fp32.final_metric
    );
    // APS wire traffic is ~4× smaller than FP32.
    assert!(aps.comm_payload_bytes * 3 < fp32.comm_payload_bytes);
    // Its exponent phase is a rounding error of the payload.
    assert!(aps.comm_exponent_bytes * 50 < aps.comm_payload_bytes);
}

/// Routing the trainer through the overlapped path (shared-memory
/// transport, bucketed backprop-order sync) must leave the final
/// parameters bit-identical to the synchronous in-process run — the
/// transport and the bucketing change *when* and *where* bytes move,
/// never the arithmetic.
#[test]
fn overlapped_transport_training_matches_synchronous() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");

    let mut base = quick_setup(4, SyncMethod::Aps { fmt: FpFormat::E5M2 });
    base.epochs = 1;
    base.steps_per_epoch = 6;
    let mut over = base.clone();
    over.transport = TransportSpec::SharedMem;

    let mut t_sync = Trainer::new(&model, base).unwrap();
    let sync_out = t_sync.train("it-sync").unwrap();
    let mut t_over = Trainer::new(&model, over).unwrap();
    let over_out = t_over.train("it-overlap").unwrap();

    assert!(!over_out.diverged);
    assert_eq!(sync_out.comm_honest_bytes, over_out.comm_honest_bytes);
    assert_eq!(sync_out.steps_run, over_out.steps_run);
    for (l, (a, b)) in t_sync.params.iter().zip(t_over.params.iter()).enumerate() {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "param tensor {l} elem {i}: overlapped training diverged"
            );
        }
    }
}

#[test]
fn overscaled_loss_scaling_overflows_aps_does_not() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");

    // Factor 2^24 pushes E5M2 (max 2^15) into overflow immediately.
    let mut s = quick_setup(4, SyncMethod::LossScaling { fmt: FpFormat::E5M2, factor_exp: 24 });
    s.epochs = 1;
    s.steps_per_epoch = 3;
    let mut t = Trainer::new(&model, s).unwrap();
    let mut out = Default::default();
    t.step(0, 0, &mut out).unwrap();
    let overflowed = out.underflow.points.len() == 1; // step ran
    assert!(overflowed);

    let mut s2 = quick_setup(4, SyncMethod::Aps { fmt: FpFormat::E5M2 });
    s2.epochs = 1;
    s2.steps_per_epoch = 3;
    let mut t2 = Trainer::new(&model, s2).unwrap();
    let out2 = t2.train("aps-safe").unwrap();
    assert!(!out2.diverged);
    assert!(out2.final_metric > 0.15);
}

#[test]
fn hybrid_schedule_switches_precision() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");
    let mut s = quick_setup(4, SyncMethod::Aps { fmt: FpFormat::E4M3 });
    s.hybrid = Some(aps_cpd::aps::HybridSchedule {
        fp32_epochs: 1,
        low: SyncMethod::Aps { fmt: FpFormat::E4M3 },
    });
    s.epochs = 2;
    s.steps_per_epoch = 6;
    let mut t = Trainer::new(&model, s).unwrap();
    let out = t.train("hybrid").unwrap();
    assert!(!out.diverged);
    // Epoch 0 ran FP32 (zero underflow); epoch 1 ran E4M3.
    let e0_underflow: f64 = out.underflow.points[..6].iter().map(|p| p.1).sum();
    assert_eq!(e0_underflow, 0.0, "FP32 phase must not underflow");
}

#[test]
fn segmentation_and_lm_workloads_run() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();

    let fcn = load(&engine, "fcn");
    let mut s = quick_setup(2, SyncMethod::Aps { fmt: FpFormat::E4M3 });
    s.epochs = 1;
    s.steps_per_epoch = 6;
    s.eval_examples = 32;
    let mut t = Trainer::new(&fcn, s).unwrap();
    let out = t.train("it-fcn").unwrap();
    assert!(!out.diverged);
    assert!(out.final_metric > 0.0 && out.final_metric <= 1.0);
    assert!(out.final_macc.is_some());

    let lm = load(&engine, "transformer");
    let mut s = quick_setup(2, SyncMethod::Aps { fmt: FpFormat::E5M2 });
    s.epochs = 1;
    s.steps_per_epoch = 4;
    s.eval_examples = 16;
    s.schedule = LrSchedule::Constant { lr: 0.02 };
    let mut t = Trainer::new(&lm, s).unwrap();
    let out = t.train("it-lm").unwrap();
    assert!(!out.diverged);
    // LM metric is eval loss; it should be below uniform-vocab entropy.
    assert!(out.final_metric < (512f64).ln() * 1.1, "loss {}", out.final_metric);
}

#[test]
fn ternary_codec_trains_mlp_without_divergence() {
    // The net-new TernGrad-style strategy (outside the closed SyncMethod
    // enum, reached via the TrainerSetup strategy override) must train
    // the same workload the paper methods do without diverging.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp");
    let mut s = quick_setup(4, SyncMethod::Fp32);
    s.strategy = Some(StrategySpec::Ternary { seed: 7 });
    let mut t = Trainer::new(&model, s).unwrap();
    let out = t.train("it-ternary").unwrap();
    assert!(!out.diverged);
    let first = out.loss.points.first().unwrap().1;
    assert!(out.loss.tail_mean(5) < first, "ternary loss should decrease");
    assert!(out.final_metric > 0.15, "accuracy {}", out.final_metric); // chance = 0.1
}

#[test]
fn qat_model_with_embedded_pallas_kernel_trains() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let model = load(&engine, "mlp_qat");
    let mut s = quick_setup(2, SyncMethod::Fp32);
    s.epochs = 1;
    s.steps_per_epoch = 8;
    let mut t = Trainer::new(&model, s).unwrap();
    let out = t.train("it-qat").unwrap();
    assert!(!out.diverged);
    let first = out.loss.points.first().unwrap().1;
    assert!(out.loss.tail_mean(3) < first, "QAT loss should decrease");
}

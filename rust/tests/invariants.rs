//! Property-based invariants (util::ptest) over the numeric substrate and
//! the coordinator-state layer — the repository's proptest suite.

use aps_cpd::aps::{self, SyncMethod, SyncOptions, SyncReport};
use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::cpd::{
    avg_roundoff_error, quantize, quantize_shifted, FpFormat, Rounding,
};
use aps_cpd::data::Rng;
use aps_cpd::util::ptest::{check, check_msg, generators};

const RNE: Rounding = Rounding::NearestEven;

/// One-shot sync through a throwaway session (the removed
/// `aps::synchronize` shim's behaviour, inlined).
fn synchronize(
    cluster: &SimCluster,
    grads: &[Vec<Vec<f32>>],
    opts: &SyncOptions,
) -> (Vec<Vec<f32>>, SyncReport) {
    let mut session =
        aps_cpd::sync::SyncSessionBuilder::from_sync_options(cluster.world_size, opts).build();
    let (reduced, report) = session.step(grads);
    (reduced.to_vec(), report.clone())
}

#[test]
fn prop_cast_idempotent() {
    check_msg(
        "quantize(quantize(x)) == quantize(x)",
        11,
        2000,
        |rng| (generators::nasty_f32(rng), generators::format(rng)),
        |&(x, fmt)| {
            let q1 = quantize(x, fmt, RNE);
            let q2 = quantize(q1, fmt, RNE);
            if q1.is_nan() && q2.is_nan() {
                return Ok(());
            }
            if q1.to_bits() == q2.to_bits() {
                Ok(())
            } else {
                Err(format!("q1={q1:e} q2={q2:e}"))
            }
        },
    );
}

#[test]
fn prop_cast_monotone() {
    check_msg(
        "x <= y implies q(x) <= q(y)",
        12,
        2000,
        |rng| {
            let a = generators::nasty_f32(rng);
            let b = generators::nasty_f32(rng);
            (a.min(b), a.max(b), generators::format(rng))
        },
        |&(x, y, fmt)| {
            if x.is_nan() || y.is_nan() {
                return Ok(());
            }
            let qx = quantize(x, fmt, RNE);
            let qy = quantize(y, fmt, RNE);
            if qx <= qy {
                Ok(())
            } else {
                Err(format!("q({x:e})={qx:e} > q({y:e})={qy:e}"))
            }
        },
    );
}

#[test]
fn prop_cast_bounded_relative_error_in_normal_range() {
    // For values inside the format's normal range, RNE error ≤ ε/2·|x|.
    check_msg(
        "relative error ≤ 2^-(man+1) in normal range",
        13,
        2000,
        |rng| {
            let fmt = generators::format(rng);
            // Sample x = ±m·2^e with integer e ∈ [e_min, e_max-1] and
            // m ∈ [1,2): then |x| < 2^e_max ≤ max_value, safely inside
            // the normal range (degenerate formats like E2M0 included).
            let span = (fmt.max_exponent() - fmt.min_normal_exponent()) as usize;
            let e = fmt.min_normal_exponent() + rng.below(span.max(1)) as i32;
            let m = 1.0 + rng.uniform() * 0.999;
            let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            (s * m * (e as f32).exp2(), fmt)
        },
        |&(x, fmt)| {
            let q = quantize(x, fmt, RNE);
            let rel = ((q - x) / x).abs() as f64;
            let bound = fmt.epsilon() / 2.0 * 1.0001;
            if rel <= bound {
                Ok(())
            } else {
                Err(format!("rel {rel} > bound {bound}, q={q:e}"))
            }
        },
    );
}

#[test]
fn prop_shift_of_representable_is_lossless_within_range() {
    // Fig 4 as a property: for representable v and shift k that keeps
    // v·2^k inside the normal range, quantize_shifted is exactly v·2^k.
    check_msg(
        "power-of-two shifts are lossless",
        14,
        500,
        |rng| {
            // cap man_bits: enumerate_magnitudes is exponential in it
            let fmt = aps_cpd::cpd::FpFormat::new(
                2 + rng.below(7) as u8,
                rng.below(7) as u8,
            );
            let vals = fmt.enumerate_magnitudes();
            let v = vals[rng.below(vals.len())];
            let k = rng.below(9) as i32 - 4;
            (v, k, fmt)
        },
        |&(v, k, fmt)| {
            if v == 0.0 {
                return Ok(());
            }
            let shifted = v as f64 * (k as f64).exp2();
            if shifted < fmt.min_normal() || shifted > fmt.max_value() {
                return Ok(()); // outside: rounding may legally occur
            }
            let q = quantize_shifted(v, k, fmt, RNE) as f64;
            if q == shifted {
                Ok(())
            } else {
                Err(format!("{v:e}·2^{k} → {q:e}, want {shifted:e}"))
            }
        },
    );
}

#[test]
fn prop_fp32_allreduce_topology_invariant_to_1ulp() {
    check_msg(
        "fp32 ring vs hierarchical agree to ~1 ulp",
        15,
        60,
        |rng| {
            let p = [4usize, 8, 16][rng.below(3)];
            let n = 1 + rng.below(64);
            let grads: Vec<Vec<f32>> = (0..p)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            grads
        },
        |grads| {
            let p = grads.len();
            let cluster = SimCluster::new(p);
            let (r, _) = cluster.all_reduce_sum(grads, Topology::Ring, ReduceOptions::fp32());
            let (h, _) = cluster.all_reduce_sum(
                grads,
                Topology::Hierarchical { group_size: if p % 4 == 0 { 4 } else { 2 } },
                ReduceOptions::fp32(),
            );
            for (a, b) in r.iter().zip(&h) {
                let tol = 1e-5 * a.abs().max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aps_never_overflows() {
    // Eq. 1–4: for any gradients and any format, APS's chosen factor must
    // keep every wire value and every partial sum finite.
    check_msg(
        "APS wire values never overflow",
        16,
        80,
        |rng| {
            let p = 2 + rng.below(7);
            let layers = 1 + rng.below(3);
            let scale = (rng.range(-30.0, 30.0)).exp2();
            let grads: Vec<Vec<Vec<f32>>> = (0..p)
                .map(|_| {
                    (0..layers)
                        .map(|_| (0..16).map(|_| rng.normal() * scale).collect())
                        .collect()
                })
                .collect();
            let fmt = generators::format(rng);
            (grads, fmt)
        },
        |(grads, fmt)| {
            let cluster = SimCluster::new(grads.len());
            let opts = SyncOptions::new(SyncMethod::Aps { fmt: *fmt });
            let (out, report) = synchronize(&cluster, grads, &opts);
            if report.any_overflow() {
                return Err("overflow on the wire".into());
            }
            for l in &out {
                for v in l {
                    if v.is_infinite() {
                        return Err(format!("INF in output"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aps_rescues_underflowing_gradients() {
    // In the regime APS exists for — gradients below the wire format's
    // subnormal floor — the naive cast loses (almost) everything while
    // APS's shift keeps the Eq.-5 error at the mantissa-rounding level.
    // (Outside that regime APS and naive differ only by which end of the
    // range absorbs rounding, so no pointwise ordering holds; see the
    // table4/table9 benches for the aggregate picture.)
    check_msg(
        "APS ≪ naive when gradients underflow",
        17,
        60,
        |rng| {
            let p = 4;
            // E5M2 subnormal floor is 2^-16; sample well below it.
            let scale = (rng.range(-36.0, -22.0)).exp2();
            let grads: Vec<Vec<Vec<f32>>> = (0..p)
                .map(|_| {
                    vec![(0..64).map(|_| rng.normal() * scale).collect()]
                })
                .collect();
            grads
        },
        |grads| {
            let cluster = SimCluster::new(grads.len());
            let fmt = FpFormat::E5M2;
            let exact = aps::reduce_exact(grads, true);
            let (aps_out, _) = synchronize(
                &cluster,
                grads,
                &SyncOptions::new(SyncMethod::Aps { fmt }),
            );
            let (naive_out, _) = synchronize(
                &cluster,
                grads,
                &SyncOptions::new(SyncMethod::Naive { fmt }),
            );
            let e_aps = avg_roundoff_error(&exact[0], &aps_out[0]);
            let e_naive = avg_roundoff_error(&exact[0], &naive_out[0]);
            if e_naive > 0.9 && e_aps < 0.5 * e_naive {
                Ok(())
            } else {
                Err(format!("aps {e_aps} vs naive {e_naive}"))
            }
        },
    );
}

#[test]
fn prop_kahan_better_than_plain_in_aggregate() {
    // Kahan is not pointwise-better (compensation can round unluckily on
    // any single element), but over many random reductions its mean Eq.-5
    // error must beat the plain fold — the §5.1.1 claim.
    let mut rng = Rng::new(18);
    let mut sum_plain = 0.0f64;
    let mut sum_kahan = 0.0f64;
    let cases = 40;
    for _ in 0..cases {
        let p = 16;
        let n = 32;
        let grads: Vec<Vec<f32>> = (0..p)
            .map(|_| {
                (0..n)
                    .map(|_| rng.normal() * (rng.range(-3.0, 3.0)).exp2())
                    .collect()
            })
            .collect();
        let cluster = SimCluster::new(p);
        let exact: Vec<f32> = (0..n)
            .map(|i| grads.iter().map(|g| g[i] as f64).sum::<f64>() as f32)
            .collect();
        let fmt = FpFormat::E4M3;
        let plain = cluster
            .all_reduce_sum(&grads, Topology::Ring, ReduceOptions::low_precision(fmt))
            .0;
        let kahan = cluster
            .all_reduce_sum(
                &grads,
                Topology::Ring,
                ReduceOptions { fmt, mode: RNE, kahan: true },
            )
            .0;
        sum_plain += avg_roundoff_error(&exact, &plain);
        sum_kahan += avg_roundoff_error(&exact, &kahan);
    }
    let mp = sum_plain / cases as f64;
    let mk = sum_kahan / cases as f64;
    assert!(mk < mp, "mean kahan {mk} >= mean plain {mp}");
    println!("mean Eq.5 error: plain {mp:.4}, kahan {mk:.4}");
}

#[test]
fn prop_stochastic_rounding_brackets() {
    check(
        "stochastic rounding returns a bracketing representable",
        19,
        2000,
        |rng: &mut Rng| {
            (
                generators::nasty_f32(rng),
                generators::format(rng),
                rng.next_u64(),
            )
        },
        |&(x, fmt, seed)| {
            if !x.is_finite() {
                return true;
            }
            let s = quantize(x, fmt, Rounding::Stochastic(seed));
            let down = quantize(x, fmt, Rounding::TowardZero);
            // s must be either the truncation or its outward neighbor
            if s.is_nan() {
                return false;
            }
            if s == down {
                return true;
            }
            // outward neighbor: |s| >= |x| and s is representable
            let q = quantize(s, fmt, RNE);
            (q.is_nan() && s.is_nan() || q.to_bits() == s.to_bits()) && s.abs() >= x.abs().min(fmt.max_value() as f32)
        },
    );
}

//! The parallel packed-fold suite: parallelism may repartition the
//! iteration space, never the arithmetic.
//!
//! * **schedule independence** — for every conformance codec (the same
//!   11 the codec contract covers), sessions running the packed fold at
//!   1/2/4/8 fold threads (and the auto setting) produce bit-identical
//!   reduced gradients, `SyncReport`s and measured wire traffic to the
//!   single-threaded packed path and the simulated path, on hostile
//!   `nasty_f32` inputs, across worlds 1/2/4/8 and both collectives.
//!   Explicit `with_fold_threads(k > 1)` forces a k-way split even on
//!   layers below the parallel threshold, so the permutation coverage is
//!   real on every layer shape here, including 9-element tails.
//! * **multi-word bit kernels** — deterministic property/fuzz tests
//!   (SplitMix64-seeded `data::Rng` width sequences over 1..=32, offsets
//!   straddling word boundaries) pinning `BitWriter::put_many`,
//!   `BitReader::read_many`, `PackedWire::read_bits_at_many` and the
//!   free `unpack_bits_into` kernel to their scalar `put`/`read`/
//!   `read_bits_at` equivalents, byte-for-byte and bit-for-bit —
//!   including reads past the end of the stream, which yield zeros
//!   exactly like the scalar reader.
//!
//! The `nondeterminism` waivers on the auto thread-count arms in
//! `collectives/ring.rs` and `collectives/hierarchical.rs` cite this
//! suite as their evidence.

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::data::Rng;
use aps_cpd::sync::{
    unpack_bits_into, BitReader, BitWriter, PackedWire, StrategySpec, SyncSessionBuilder,
    WireMode,
};
use aps_cpd::util::ptest::generators;

fn ef(inner: StrategySpec) -> StrategySpec {
    StrategySpec::ErrorFeedback { inner: Box::new(inner) }
}

/// The same 11-codec family the conformance contract pins.
fn specs() -> Vec<(&'static str, StrategySpec)> {
    vec![
        ("fp32", StrategySpec::Fp32),
        ("naive/e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling/e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 4 },
        ),
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps/e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 9 }),
        ("topk@0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd b4/32", StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 }),
        ("ef:ternary", ef(StrategySpec::Ternary { seed: 9 })),
        ("ef:topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef:qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 32, seed: 9 })),
    ]
}

/// Hostile per-worker gradients from the shared `nasty_f32` stream.
fn nasty_grads(rng: &mut Rng, world: usize, layers: &[usize]) -> Vec<Vec<Vec<f32>>> {
    (0..world)
        .map(|_| {
            layers
                .iter()
                .map(|&n| (0..n).map(|_| generators::nasty_f32(rng)).collect())
                .collect()
        })
        .collect()
}

/// One (world, topology) cell of the schedule-permutation matrix: run
/// the single-threaded packed session, the simulated session, and one
/// packed session per fold-thread setting in lockstep, asserting every
/// step's reduced gradients, reports and measured traffic agree
/// bit-for-bit.
fn check_schedule_cell(label: &str, spec: &StrategySpec, world: usize, topo: Topology) {
    // One layer large enough that every world size splits it across
    // multiple ring chunks per thread, plus small and odd tails.
    let layers = [33usize, 4096, 9];
    let mut rng = Rng::new(0x9A11E1 ^ world as u64 ^ label.len() as u64);
    let build = |threads: Option<usize>, wire: WireMode| {
        let mut b = SyncSessionBuilder::new(world).spec(spec.clone()).with_topology(topo);
        if let Some(k) = threads {
            b = b.with_fold_threads(k);
        }
        b.with_wire(wire).build()
    };
    let mut base = build(Some(1), WireMode::Packed);
    let mut sim = build(None, WireMode::Simulated);
    // 0 = auto sizing; 2/4/8 = forced splits (distinct schedules even on
    // the 9-element layer).
    let fold_threads = [0usize, 2, 4, 8];
    let mut par: Vec<_> =
        fold_threads.iter().map(|&k| build(Some(k), WireMode::Packed)).collect();
    for step in 0..2 {
        let grads = nasty_grads(&mut rng, world, &layers);
        let (bo, br) = base.step(&grads);
        let bo = bo.to_vec();
        let br = br.clone();
        let bm = base.wire_moved();
        let (so, sr) = sim.step(&grads);
        for (l, (a, b)) in bo.iter().zip(so.iter()).enumerate() {
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                     packed(1 thread) {x:e} vs simulated {y:e}"
                );
            }
        }
        assert_eq!(&br, sr, "{label}/{topo:?} w{world} step {step}: packed vs simulated report");
        for (session, &k) in par.iter_mut().zip(fold_threads.iter()) {
            let (po, pr) = session.step(&grads);
            let po = po.to_vec();
            let pr = pr.clone();
            for (l, (a, b)) in po.iter().zip(bo.iter()).enumerate() {
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{label}/{topo:?} w{world} step {step} layer {l} elem {i}: \
                         {k} fold threads {x:e} vs single-threaded {y:e}"
                    );
                }
            }
            assert_eq!(
                pr, br,
                "{label}/{topo:?} w{world} step {step}: report diverged at {k} fold threads"
            );
            assert_eq!(
                session.wire_moved(),
                bm,
                "{label}/{topo:?} w{world} step {step}: moved traffic diverged at {k} fold threads"
            );
        }
    }
}

#[test]
fn parallel_ring_fold_is_schedule_independent_for_every_strategy() {
    for (label, spec) in &specs() {
        for world in [1usize, 2, 4, 8] {
            check_schedule_cell(label, spec, world, Topology::Ring);
        }
    }
}

#[test]
fn parallel_hierarchical_fold_is_schedule_independent_for_every_strategy() {
    for (label, spec) in &specs() {
        for (world, group_size) in [(2usize, 2usize), (4, 2), (8, 4), (8, 2)] {
            check_schedule_cell(label, spec, world, Topology::Hierarchical { group_size });
        }
    }
}

/// Random (width, values) blocks for the bit-kernel fuzz tests.
fn random_blocks(rng: &mut Rng, blocks: usize, max_len: usize) -> Vec<(u32, Vec<u32>)> {
    (0..blocks)
        .map(|_| {
            let width = 1 + rng.below(32) as u32;
            let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let len = rng.below(max_len + 1);
            let vals = (0..len).map(|_| rng.next_u64() as u32 & mask).collect();
            (width, vals)
        })
        .collect()
}

#[test]
fn put_many_is_bytewise_identical_to_scalar_put_on_random_width_sequences() {
    let mut rng = Rng::new(0x5EED_B175);
    for case in 0..40 {
        let blocks = random_blocks(&mut rng, 12, 67);
        // Reference stream: every value written with scalar `put`.
        let mut scalar_buf = Vec::new();
        let mut w = BitWriter::new(&mut scalar_buf);
        for (width, vals) in &blocks {
            for &v in vals {
                w.put(v, *width);
            }
        }
        let scalar_bits = w.finish();
        // Bulk stream: each block split at a random point — scalar
        // prefix, `put_many` suffix — so bulk writes start at arbitrary
        // pending-bit phases, straddling word boundaries.
        let mut bulk_buf = Vec::new();
        let mut w = BitWriter::new(&mut bulk_buf);
        for (width, vals) in &blocks {
            let split = rng.below(vals.len() + 1);
            for &v in &vals[..split] {
                w.put(v, *width);
            }
            w.put_many(&vals[split..], *width);
        }
        let bulk_bits = w.finish();
        assert_eq!(bulk_bits, scalar_bits, "case {case}: bit counts diverged");
        assert_eq!(bulk_buf, scalar_buf, "case {case}: byte streams diverged");

        // Read the stream back with `read_many`, each block again split
        // between scalar reads and one bulk read, staying in sync with
        // the scalar cursor.
        let mut r = BitReader::new(&bulk_buf);
        for (bi, (width, vals)) in blocks.iter().enumerate() {
            let split = rng.below(vals.len() + 1);
            for (i, &v) in vals[..split].iter().enumerate() {
                assert_eq!(r.read(*width), v, "case {case} block {bi} scalar elem {i}");
            }
            let mut out = vec![0u32; vals.len() - split];
            r.read_many(*width, &mut out);
            assert_eq!(out[..], vals[split..], "case {case} block {bi} bulk tail");
        }
    }
}

#[test]
fn unpack_bits_into_matches_scalar_reads_at_word_straddling_offsets() {
    let mut rng = Rng::new(0x0FF_5E75);
    for width in 1..=32u32 {
        let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let vals: Vec<u32> = (0..157).map(|_| rng.next_u64() as u32 & mask).collect();
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        for &v in &vals {
            w.put(v, width);
        }
        let total_bits = w.finish();
        // Bit offsets chosen to straddle byte and 32-bit word boundaries,
        // plus element-aligned starts including one fully past the end.
        let raw_offsets = [0u64, 1, 7, 8, 15, 31, 32, 33, 63, 64, 65, 127, 129];
        let elem_offsets =
            [0u64, 1, 57, 150, 157].map(|e| e * width as u64);
        for &off in raw_offsets.iter().chain(elem_offsets.iter()) {
            if off > total_bits + 64 {
                continue;
            }
            for take in [0usize, 1, 40, 157] {
                let mut bulk = vec![0xDEAD_BEEFu32; take];
                unpack_bits_into(&buf, off, width, &mut bulk);
                let mut r = BitReader::at(&buf, off);
                for (i, &b) in bulk.iter().enumerate() {
                    let s = r.read(width);
                    assert_eq!(
                        b, s,
                        "width {width} offset {off} elem {i}: bulk {b:#x} vs scalar {s:#x}"
                    );
                }
            }
        }
        // Fully past the end: the kernel reads zeros, like the scalar
        // reader.
        let mut past = vec![u32::MAX; 8];
        unpack_bits_into(&buf, total_bits, width, &mut past);
        let tail_bits = (buf.len() as u64 * 8).saturating_sub(total_bits);
        let mut r = BitReader::at(&buf, total_bits);
        for (i, &b) in past.iter().enumerate() {
            assert_eq!(b, r.read(width), "width {width} past-end elem {i}");
            if i as u64 * width as u64 >= tail_bits {
                assert_eq!(b, 0, "width {width} past-end elem {i} must be zero");
            }
        }
    }
}

#[test]
fn packed_wire_bulk_ranged_unpack_matches_scalar_read_bits_at() {
    let mut rng = Rng::new(0xCAB1E);
    for width in [2u32, 3, 5, 8, 13, 19, 32] {
        let mask = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
        let vals: Vec<u32> = (0..311).map(|_| rng.next_u64() as u32 & mask).collect();
        let mut pw = PackedWire::default();
        pw.reset(16, vals.len());
        {
            let mut w = BitWriter::new(pw.bytes_mut());
            for &v in &vals {
                w.put(v, width);
            }
            w.finish();
        }
        for start in [0usize, 1, 17, 128, 310, 311] {
            let take = (vals.len() - start).min(97);
            let off = start as u64 * width as u64;
            let mut bulk = vec![0u32; take];
            pw.read_bits_at_many(off, width, &mut bulk);
            for (i, &b) in bulk.iter().enumerate() {
                let s = pw.read_bits_at(off + i as u64 * width as u64, width);
                assert_eq!(
                    b, s,
                    "width {width} start {start} elem {i}: bulk {b:#x} vs read_bits_at {s:#x}"
                );
                assert_eq!(b, vals[start + i], "width {width} start {start} elem {i} roundtrip");
            }
        }
    }
}

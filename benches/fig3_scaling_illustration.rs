//! Fig 3 — loss scaling vs APS on two layers with different scales.
//!
//! Two synthetic "layers" whose gradient distributions sit at different
//! exponents (the blue/green curves of Fig 3). A single global loss-scale
//! must compromise; APS shifts each layer with its own largest-safe
//! power of two.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::local_max_exp;
use aps_cpd::cpd::FpFormat;
use aps_cpd::data::Rng;
use aps_cpd::metrics::under_overflow_fracs;
use aps_cpd::util::table::Table;

fn lognormal_layer(rng: &mut Rng, n: usize, center_exp: f32, sigma: f32) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let e = center_exp + sigma * rng.normal();
            let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            s * e.exp2()
        })
        .collect()
}

fn main() {
    support::header(
        "Fig 3 — global loss scaling vs layer-wise APS in (5,2)",
        "paper §3.2, Fig 3",
    );
    let fmt = FpFormat::E5M2; // representable exponents [-16, 15]
    let mut rng = Rng::new(42);
    // "blue" layer: tiny gradients around 2^-25; "green": large, near 2^5.
    let blue = lognormal_layer(&mut rng, 50_000, -25.0, 2.0);
    let green = lognormal_layer(&mut rng, 50_000, 5.0, 2.0);

    // Global loss scaling must avoid overflow on the *largest* layer →
    // factor chosen from green's max (as the paper's hand-tuning would).
    let world = 1;
    let green_max = local_max_exp(&green, world).unwrap();
    let global_factor = fmt.max_exponent() - green_max;

    // APS: each layer gets its own factor.
    let blue_factor = fmt.max_exponent() - local_max_exp(&blue, world).unwrap();
    let green_factor = fmt.max_exponent() - green_max;

    let mut t = Table::new(&[
        "configuration",
        "factor (blue)",
        "factor (green)",
        "blue underflow",
        "blue overflow",
        "green underflow",
        "green overflow",
    ]);
    for (name, fb, fg) in [
        ("no scaling", 0, 0),
        ("global loss scaling", global_factor, global_factor),
        ("APS (layer-wise)", blue_factor, green_factor),
    ] {
        let (bu, bo) = under_overflow_fracs(&blue, fmt, fb);
        let (gu, go) = under_overflow_fracs(&green, fmt, fg);
        t.row(&[
            name.to_string(),
            format!("2^{fb}"),
            format!("2^{fg}"),
            format!("{:.1}%", 100.0 * bu),
            format!("{:.1}%", 100.0 * bo),
            format!("{:.1}%", 100.0 * gu),
            format!("{:.1}%", 100.0 * go),
        ]);
    }
    t.print();

    let (bu_none, _) = under_overflow_fracs(&blue, fmt, 0);
    let (bu_global, _) = under_overflow_fracs(&blue, fmt, global_factor);
    let (bu_aps, bo_aps) = under_overflow_fracs(&blue, fmt, blue_factor);
    let (gu_aps, go_aps) = under_overflow_fracs(&green, fmt, green_factor);
    assert!(bu_none > 0.9, "unscaled tiny layer must underflow");
    assert!(bu_global > 0.5, "a green-safe global factor still loses the blue layer");
    assert!(bu_aps < 0.02 && bo_aps == 0.0, "APS rescues the blue layer");
    assert!(gu_aps < 0.02 && go_aps == 0.0, "APS keeps the green layer safe");
    println!(
        "\nglobal scaling (picked for the large layer) leaves the small layer\nunderwater; APS's per-layer factors rescue both — the Fig 3 picture ✔"
    );
}

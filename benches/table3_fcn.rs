//! Table 3 + Fig 7/8 — FCN segmentation under low-precision gradients.
//!
//! Paper (cityscapes, batch 16, 8 nodes, 40K iters):
//!   fp32: mIoU 75.16 / mAcc 82.84
//!   (4,3) aps: 75.88 / 84.34    (4,3) no: 74.60 / 82.55
//!   (5,2) aps: 74.76 / 82.62    (5,2) no: 74.41 / 82.30
//!
//! Shape claims: APS ≥ no-APS for both formats; 8-bit APS ≈ FP32.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::SyncMethod;
use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::util::table::Table;
use support::{train, BenchEnv, RunShape};

fn main() {
    support::header("Table 3 / Fig 7 — FCN segmentation", "paper §4.1, Table 3");
    let env = BenchEnv::new();
    let model = env.model("fcn");
    let mut shape = RunShape::standard(8);
    shape.eval_examples = 64;
    shape.lr = 0.1;

    let rows: &[(&str, &str, SyncMethod, &str, &str)] = &[
        ("(8,23): 32bits", "/", SyncMethod::Fp32, "75.16", "82.84"),
        ("(4,3): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E4M3 }, "75.88", "84.34"),
        ("(4,3): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E4M3 }, "74.60", "82.55"),
        ("(5,2): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E5M2 }, "74.76", "82.62"),
        ("(5,2): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E5M2 }, "74.41", "82.30"),
    ];

    let mut t = Table::new(&[
        "precision",
        "APS",
        "mIoU %",
        "mAcc %",
        "paper mIoU",
        "paper mAcc",
    ]);
    let mut results = Vec::new();
    for (prec, aps, method, p_miou, p_macc) in rows {
        let out = train(
            &model,
            shape,
            *method,
            Topology::Ring,
            false,
            false,
            None,
            None,
            &format!("t3-fcn-{prec}-aps{aps}"),
        );
        t.row(&[
            prec.to_string(),
            aps.to_string(),
            format!("{:.2}", 100.0 * out.final_metric),
            format!("{:.2}", 100.0 * out.final_macc.unwrap_or(f64::NAN)),
            p_miou.to_string(),
            p_macc.to_string(),
        ]);
        results.push(out);
    }
    t.print();
    support::shape_note();

    let fp32 = results[0].final_metric;
    let e4m3_aps = results[1].final_metric;
    let e5m2_aps = results[3].final_metric;
    let e4m3_naive = results[2].final_metric;
    let e5m2_naive = results[4].final_metric;
    assert!(fp32 > 0.3, "fp32 mIoU too weak: {fp32}");
    assert!(e4m3_aps > fp32 - 0.08, "e4m3 APS should track fp32 mIoU");
    assert!(e5m2_aps > fp32 - 0.08, "e5m2 APS should track fp32 mIoU");
    assert!(e4m3_aps + 0.02 >= e4m3_naive, "APS ≥ naive for (4,3)");
    assert!(e5m2_aps + 0.02 >= e5m2_naive, "APS ≥ naive for (5,2)");
    println!("\nshape ✔  APS ≥ no-APS for both 8-bit formats; APS ≈ FP32 mIoU");
}

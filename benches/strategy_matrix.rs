//! Strategy × collective matrix through the `SyncSession` hot path.
//!
//! Sweeps every built-in `SyncStrategy` (including error-feedback-wrapped
//! codecs) over every built-in `Collective` on a synthetic multi-scale
//! gradient set (no artifacts needed) and reports simulated wire
//! bytes/step, the codec's honest packed wire cost (`WireCost`: value +
//! index bits, metadata), exponent-phase bytes, latency steps, mean wire
//! underflow, and wall time per step. New codecs added through
//! `StrategySpec` (or plugged straight into `SyncSessionBuilder`) get
//! perf numbers here for free.
//!
//! Payload KiB is the dense schedule accounting (ternary rides a BF16
//! wire, top-k/QSGD dense FP32); `wire KiB` is the codec's honest packed
//! claim — 2-bit ternary symbols, top-k (index, value) pairs, QSGD
//! `bits`/elt plus bucket scales — and `moved KiB` is what the packed
//! reduction (the session default) *measurably* moved. The two are
//! asserted equal on every cell: bytes-moved == `SyncReport::honest_bytes`
//! minus the exponent side channel.
//!
//! Run with `--test` (CI does) for a single-iteration smoke pass that
//! also asserts the codec-accounting invariants, so a regression in any
//! codec's traffic numbers fails the workflow rather than silently
//! skewing EXPERIMENTS.md.
//!
//! A trailing section sweeps the parallel packed fold at 1/2/4/8 fold
//! threads (ternary @ ring) for wall-clock scaling numbers, asserting
//! the reduced gradients stay bit-identical across thread counts — the
//! bench-side echo of `rust/tests/packed_parallel.rs`.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::sync::{StrategySpec, SyncSessionBuilder, WireCost};
use aps_cpd::util::bench::{fmt_secs, Bench};
use aps_cpd::util::table::Table;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    support::header(
        "strategy × collective matrix (SyncSession hot path)",
        "sync module; paper Tables 2/4 methods + net-new codecs",
    );

    let world = 8;
    // ResNet-ish spread: a big conv block, a medium layer, a tiny bias —
    // with the Fig-2 scale disparity APS exists for.
    let layers: &[(usize, f32)] = &[(1 << 16, 1e-4), (1 << 13, 1.0), (256, 1e-6)];
    let grads: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &(n, scale))| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 2654435761 + l * 97 + i * 131) % 4001;
                            (h as f32 / 4001.0 - 0.5) * scale
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let ef = |inner: StrategySpec| StrategySpec::ErrorFeedback { inner: Box::new(inner) };
    let strategies = [
        StrategySpec::Fp32,
        StrategySpec::Naive { fmt: FpFormat::E5M2 },
        StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 8 },
        StrategySpec::Aps { fmt: FpFormat::E5M2 },
        StrategySpec::Aps { fmt: FpFormat::E4M3 },
        StrategySpec::Ternary { seed: 42 },
        StrategySpec::TopK { frac: 0.25 },
        StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 42 },
        ef(StrategySpec::Ternary { seed: 42 }),
        ef(StrategySpec::TopK { frac: 0.25 }),
        ef(StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 42 }),
    ];
    let collectives = [Topology::Ring, Topology::Hierarchical { group_size: 4 }];

    let bench = if smoke {
        Bench { warmup_iters: 0, samples: 1, iters_per_sample: 1 }
    } else {
        Bench::quick()
    };
    let total_elems: u64 = layers.iter().map(|&(n, _)| n as u64).sum();
    // apslint: allow(lossy_cast) -- total_elems is the sum of the fixed bench layer sizes (a few million), far below usize::MAX
    let dense_fp32_wire = WireCost::dense(total_elems as usize, FpFormat::FP32);

    let mut t = Table::new(&[
        "strategy",
        "collective",
        "payload KiB/step",
        "wire KiB",
        "moved KiB",
        "idx KiB",
        "meta B",
        "exp B",
        "steps",
        "underflow",
        "wall/step",
    ]);
    for spec in &strategies {
        for topo in collectives {
            let mut session = SyncSessionBuilder::new(world)
                .spec(spec.clone())
                .with_topology(topo)
                .build();
            let m = bench.run("step", || {
                let (reduced, report) = session.step(&grads);
                (reduced[0][0], report.payload_bytes)
            });
            let report = session.report().clone();
            // The packed path (the default) measures what it moves; that
            // measurement must equal the codec's honest claim — the
            // tentpole acceptance criterion, asserted on every cell.
            let moved = session.wire_moved().expect("packed sessions measure moved traffic");
            assert_eq!(
                moved,
                report.wire,
                "{}/{topo:?}: bytes-moved diverge from claimed wire cost",
                spec.label()
            );
            t.row(&[
                spec.label(),
                format!("{topo:?}"),
                format!("{}", report.payload_bytes / 1024),
                format!("{}", report.wire.total_bytes() / 1024),
                format!("{}", moved.total_bytes() / 1024),
                format!("{}", report.wire.index_bits / 8 / 1024),
                format!("{}", report.wire.metadata_bytes),
                format!("{}", report.exponent_bytes),
                format!("{}", report.steps),
                format!("{:.4}", report.underflow_frac()),
                fmt_secs(m.median()),
            ]);

            // Codec-accounting invariants — cheap enough to check always;
            // under `--test` a violation fails the CI workflow.
            assert!(report.wire.value_bits > 0, "{}: empty wire cost", spec.label());
            assert!(
                report.steps > 0 && report.payload_bytes > 0,
                "{}: degenerate report",
                spec.label()
            );
            match spec {
                StrategySpec::Fp32 => assert_eq!(report.wire, dense_fp32_wire),
                StrategySpec::TopK { .. } => {
                    assert!(report.wire.index_bits > 0, "top-k must account index traffic");
                    assert!(
                        report.wire.total_bytes() < dense_fp32_wire.total_bytes() / 2,
                        "top-k@0.25 honest wire should be far below dense FP32"
                    );
                }
                StrategySpec::Qsgd { .. } => {
                    assert!(report.wire.metadata_bytes > 0, "qsgd must account bucket scales");
                    assert!(
                        report.wire.total_bytes() < dense_fp32_wire.total_bytes() / 4,
                        "qsgd b4 honest wire should beat dense FP32 by ≥4x"
                    );
                }
                StrategySpec::Ternary { .. } => {
                    assert_eq!(report.wire.value_bits, 2 * total_elems);
                }
                _ => {}
            }
        }
    }
    t.print();

    // ---- parallel packed fold scaling ------------------------------------
    // Same hot path, explicit fold-thread caps: the split only regroups
    // ring chunks onto threads, so outputs must not move by one bit while
    // wall/step drops on multi-core hosts.
    println!("\nparallel packed fold scaling (ternary @ ring):");
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for k in [1usize, 2, 4, 8] {
        let mut session = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 42 })
            .with_fold_threads(k)
            .build();
        let m = bench.run("fold", || {
            let (reduced, report) = session.step(&grads);
            (reduced[0][0], report.payload_bytes)
        });
        let reduced = session.reduced().to_vec();
        match &baseline {
            None => baseline = Some(reduced),
            Some(base) => {
                for (l, (a, b)) in base.iter().zip(reduced.iter()).enumerate() {
                    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "{k} fold threads layer {l} elem {i}: schedule-dependent result"
                        );
                    }
                }
            }
        }
        println!("  {k} fold thread(s): {} /step", fmt_secs(m.median()));
    }

    support::shape_note();
    println!(
        "\n(bytes are per worker per step; fp32 baseline payload = {} KiB, packed wire = {} KiB)",
        (total_elems * 4 * 2 * (world as u64 - 1) / world as u64) / 1024,
        dense_fp32_wire.total_bytes() / 1024,
    );
    if smoke {
        println!("[smoke] strategy-matrix invariants OK");
    }
}

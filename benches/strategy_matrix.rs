//! Strategy × collective matrix through the `SyncSession` hot path.
//!
//! Sweeps every built-in `SyncStrategy` over every built-in `Collective`
//! on a synthetic multi-scale gradient set (no artifacts needed) and
//! reports wire bytes/step, exponent-phase bytes, latency steps, mean
//! wire underflow, and wall time per step. New codecs added through
//! `StrategySpec` (or plugged straight into `SyncSessionBuilder`) get
//! perf numbers here for free.
//!
//! Byte columns are as-simulated: ternary symbols ride a BF16 wire (a
//! packed deployment ships 2 bits/elt) and top-k rides dense FP32 (a real
//! deployment ships k (index, value) pairs).

#[path = "support/mod.rs"]
mod support;

use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::sync::{StrategySpec, SyncSessionBuilder};
use aps_cpd::util::bench::{fmt_secs, Bench};
use aps_cpd::util::table::Table;

fn main() {
    support::header(
        "strategy × collective matrix (SyncSession hot path)",
        "sync module; paper Tables 2/4 methods + net-new codecs",
    );

    let world = 8;
    // ResNet-ish spread: a big conv block, a medium layer, a tiny bias —
    // with the Fig-2 scale disparity APS exists for.
    let layers: &[(usize, f32)] = &[(1 << 16, 1e-4), (1 << 13, 1.0), (256, 1e-6)];
    let grads: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &(n, scale))| {
                    (0..n)
                        .map(|i| {
                            let h = (w * 2654435761 + l * 97 + i * 131) % 4001;
                            (h as f32 / 4001.0 - 0.5) * scale
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let strategies = [
        StrategySpec::Fp32,
        StrategySpec::Naive { fmt: FpFormat::E5M2 },
        StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 8 },
        StrategySpec::Aps { fmt: FpFormat::E5M2 },
        StrategySpec::Aps { fmt: FpFormat::E4M3 },
        StrategySpec::Ternary { seed: 42 },
        StrategySpec::TopK { frac: 0.25 },
    ];
    let collectives = [Topology::Ring, Topology::Hierarchical { group_size: 4 }];

    let bench = Bench::quick();
    let mut t = Table::new(&[
        "strategy",
        "collective",
        "payload KiB/step",
        "exp B",
        "steps",
        "underflow",
        "wall/step",
    ]);
    for spec in strategies {
        for topo in collectives {
            let mut session = SyncSessionBuilder::new(world)
                .spec(spec)
                .with_topology(topo)
                .build();
            let m = bench.run("step", || {
                let (reduced, report) = session.step(&grads);
                (reduced[0][0], report.payload_bytes)
            });
            let report = session.report().clone();
            t.row(&[
                format!("{spec:?}"),
                format!("{topo:?}"),
                format!("{}", report.payload_bytes / 1024),
                format!("{}", report.exponent_bytes),
                format!("{}", report.steps),
                format!("{:.4}", report.underflow_frac()),
                fmt_secs(m.median()),
            ]);
        }
    }
    t.print();
    support::shape_note();
    println!(
        "\n(bytes are per worker per step; fp32 baseline payload = {} KiB)",
        (layers.iter().map(|&(n, _)| n as u64).sum::<u64>() * 4 * 2 * (world as u64 - 1)
            / world as u64)
            / 1024
    );
}

//! Table 1 — representable ranges of floating-point formats.
//!
//! Paper values: FP32 [2^-149, 2^127], FP16 [2^-24, 2^15],
//! BF16 [2^-133, 2^127], Wang-FP16 (6,9) [2^-39, 2^31], FP8 (5,2)
//! [2^-16, 2^15]. These are *exact* reproductions (pure arithmetic).

#[path = "support/mod.rs"]
mod support;

use aps_cpd::cpd::FpFormat;
use aps_cpd::util::table::Table;

fn main() {
    support::header("Table 1 — floating-point format ranges", "paper §2.2, Table 1");
    let rows: &[(&str, FpFormat, (i32, i32))] = &[
        ("IEEE 754 FP32", FpFormat::FP32, (-149, 127)),
        ("IEEE 754 FP16", FpFormat::FP16, (-24, 15)),
        ("BFloat16", FpFormat::BF16, (-133, 127)),
        ("FP16 in [27] (6,9)", FpFormat::E6M9, (-39, 31)),
        ("FP8 in [27] (5,2)", FpFormat::E5M2, (-16, 15)),
    ];
    let mut t = Table::new(&["format", "exp bits", "man bits", "measured range", "paper range"]);
    for (name, f, paper) in rows {
        let (lo, hi) = f.exponent_range();
        assert_eq!((lo, hi), *paper, "{name} range mismatch vs paper");
        t.row(&[
            name.to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            format!("[2^{lo}, 2^{hi}]"),
            format!("[2^{}, 2^{}]", paper.0, paper.1),
        ]);
    }
    // Extra formats this repo uses (not in the paper's table):
    for (name, f) in [("(4,3) 8-bit", FpFormat::E4M3), ("(3,0) 4-bit", FpFormat::E3M0)] {
        let (lo, hi) = f.exponent_range();
        t.row(&[
            name.to_string(),
            f.exp_bits.to_string(),
            f.man_bits.to_string(),
            format!("[2^{lo}, 2^{hi}]"),
            "-".to_string(),
        ]);
    }
    t.print();
    println!("\nall paper ranges match exactly ✔");
}

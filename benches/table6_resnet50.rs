//! Table 6 + Fig 10 — ResNet-50-class training on a 256-worker cluster
//! with hierarchical all-reduce (k=16), including hybrid precision.
//!
//! Paper (ImageNet, 8K batch, 256 nodes): fp32 76.02 | (5,2) aps 75.98 /
//! no 71.00 | (4,3) aps 75.93 / no 0.1 | hybrid (8,23)+(4,3) 76.09.
//!
//! Shape claims: 8-bit APS ≈ FP32 (naive falls behind or collapses);
//! hybrid recovers ≥ pure-8-bit accuracy. World size is a real 256
//! simulated workers (set APS_BENCH_WORLD to shrink for smoke runs).

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{HybridSchedule, SyncMethod};
use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::util::table::Table;
use support::{acc_cell, env_usize, train, BenchEnv, RunShape};

fn main() {
    support::header(
        "Table 6 / Fig 10 — 256-worker training, hierarchical all-reduce",
        "paper §4.2, Table 6",
    );
    let env = BenchEnv::new();
    // ResNet-50 is the paper's model; the default stand-in here is the
    // fast-learning classifier so a full 256-worker sweep stays within a
    // bench budget. Set APS_BENCH_MODEL=resnet for the conv stand-in
    // (same code path, ~10× wall time). See DESIGN.md §3.
    let model_name =
        std::env::var("APS_BENCH_MODEL").unwrap_or_else(|_| "mlp".to_string());
    let model = env.model(&model_name);
    let world = env_usize("APS_BENCH_WORLD", 256);
    let k = if world % 16 == 0 { 16 } else { world.min(4) };
    let topo = Topology::Hierarchical { group_size: k };
    let shape = RunShape::large_cluster(world);
    println!("world = {world}, hierarchical k = {k}, global batch = {}\n", world * model.spec.batch);

    // Paper uses FP32 for the last classification layer (per [27]).
    let rows: &[(&str, &str, SyncMethod, Option<usize>, &str)] = &[
        ("(8,23): 32bits", "/", SyncMethod::Fp32, None, "76.02"),
        ("(5,2): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E5M2 }, None, "75.98"),
        ("(5,2): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E5M2 }, None, "71.00"),
        ("(4,3): 8bits", "yes", SyncMethod::Aps { fmt: FpFormat::E4M3 }, None, "75.93"),
        ("(4,3): 8bits", "no", SyncMethod::Naive { fmt: FpFormat::E4M3 }, None, "0.1"),
        ("(8,23)+(4,3) hybrid", "yes", SyncMethod::Aps { fmt: FpFormat::E4M3 }, Some(1), "76.09"),
    ];

    let mut t = Table::new(&["precision", "APS", "measured acc %", "paper acc %"]);
    let mut results = Vec::new();
    for (prec, aps, method, hybrid_epochs, paper_acc) in rows {
        let hybrid = hybrid_epochs.map(|e| HybridSchedule { fp32_epochs: e, low: *method });
        let out = train(
            &model,
            shape,
            *method,
            topo,
            false,
            true, // fp32 last layer, as in the paper's protocol
            hybrid,
            None,
            &format!("t6-{prec}-aps{aps}"),
        );
        t.row(&[
            prec.to_string(),
            aps.to_string(),
            acc_cell(&out),
            paper_acc.to_string(),
        ]);
        results.push(out);
    }
    t.print();
    support::shape_note();

    let fp32 = results[0].final_metric;
    let e5m2_aps = results[1].final_metric;
    let e4m3_aps = results[3].final_metric;
    let hybrid = results[5].final_metric;
    assert!(fp32 > 0.35, "fp32 baseline too weak at {world} workers: {fp32}");
    assert!(e5m2_aps > fp32 - 0.1, "(5,2)+APS should track fp32");
    assert!(e4m3_aps > fp32 - 0.1, "(4,3)+APS should track fp32");
    assert!(hybrid > e4m3_aps - 0.05, "hybrid should be ≥ pure 8-bit");
    println!("\nshape ✔  8-bit APS ≈ FP32 at {world} workers; hybrid ≥ pure 8-bit");
}

//! Fig 11 — gradient-synchronization time: FP16 all-reduce vs APS 8-bit
//! (two-phase), per layer and lazily fused, on 32 workers.
//!
//! Two complementary measurements:
//! 1. the α–β analytic model calibrated to the paper's V100/NCCL testbed
//!    (reproduces the figure's absolute scale and the 1.33× fused win);
//! 2. measured wall-clock of this repository's actual simulated pipeline
//!    (quantize + emulated all-reduce) for the same tensors, to show the
//!    emulation cost structure.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::cpd::{quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::perfmodel::{fig11_layers, fig11_table, NetworkModel};
use aps_cpd::util::bench::Bench;
use aps_cpd::util::table::Table;

fn main() {
    support::header("Fig 11 — all-reduce time, FP16 vs APS-8bit", "paper §4.3, Fig 11");

    // ---- (1) analytic model -------------------------------------------
    println!("α–β model (32 workers, V100/NCCL calibration):\n");
    let rows = fig11_table(&NetworkModel::v100_nccl(), 32);
    let mut t = Table::new(&[
        "layer",
        "fp16 ms",
        "APS exp-phase ms",
        "APS payload ms",
        "APS total ms",
        "speedup",
    ]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.3}", r.fp16_ms),
            format!("{:.4}", r.aps_exp_phase_ms),
            format!("{:.3}", r.aps_payload_ms),
            format!("{:.3}", r.aps_total_ms),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t.print();
    for r in &rows {
        assert!(r.speedup > 1.0, "{} should beat fp16", r.label);
    }
    let fused = rows.last().unwrap();
    assert!(
        fused.speedup > 1.2,
        "fused speedup {:.2} should approach the paper's 1.33×",
        fused.speedup
    );
    println!(
        "\npaper reports ≈1.33× for the fused row; model gives {:.2}× ✔\n",
        fused.speedup
    );

    // ---- (2) measured emulation wall-clock ----------------------------
    println!("measured simulator wall-clock (8 sim workers on this host):\n");
    let world = 8;
    let cluster = SimCluster::new(world);
    let bench = Bench { warmup_iters: 1, samples: 7, iters_per_sample: 1 };
    let mut t = Table::new(&["layer", "quantize ms", "low-prec all-reduce ms", "fp32 all-reduce ms"]);
    for l in fig11_layers() {
        let n = l.elements as usize;
        let grads: Vec<Vec<f32>> = (0..world)
            .map(|w| (0..n).map(|i| ((w * 31 + i) % 1000) as f32 * 1e-6 - 5e-4).collect())
            .collect();
        let q = bench.run("quantize", || {
            quantize_shifted_slice(&grads[0], 10, FpFormat::E5M2, Rounding::NearestEven)
        });
        let contribs: Vec<Vec<f32>> = grads
            .iter()
            .map(|g| quantize_shifted_slice(g, 10, FpFormat::E5M2, Rounding::NearestEven))
            .collect();
        let r8 = bench.run("reduce8", || {
            cluster.all_reduce_sum(
                &contribs,
                Topology::Ring,
                ReduceOptions::low_precision(FpFormat::E5M2),
            )
        });
        let r32 = bench.run("reduce32", || {
            cluster.all_reduce_sum(&grads, Topology::Ring, ReduceOptions::fp32())
        });
        t.row(&[
            l.name.to_string(),
            format!("{:.3}", q.median() * 1e3),
            format!("{:.3}", r8.median() * 1e3),
            format!("{:.3}", r32.median() * 1e3),
        ]);
    }
    t.print();
    println!("\n(the emulated low-precision reduction pays the per-element cast —\n a real wire would pay bandwidth instead; see perfmodel for that side)");
}

//! Fig 11 — gradient-synchronization time: FP16 all-reduce vs APS 8-bit
//! (two-phase), per layer and lazily fused, on 32 workers.
//!
//! Three complementary measurements:
//! 1. the α–β analytic model calibrated to the paper's V100/NCCL testbed
//!    (reproduces the figure's absolute scale and the 1.33× fused win);
//! 2. measured wall-clock of this repository's actual simulated pipeline
//!    (quantize + emulated all-reduce) for the same tensors, to show the
//!    emulation cost structure;
//! 3. the bucketed overlapped pipeline (`step_overlapped`): α–β predicted
//!    time for the honest bytes each bucket ships vs measured wall-clock,
//!    per codec × transport × bucket size. The emulation pays compute
//!    where a real wire pays bandwidth, so the two columns are printed
//!    side by side as evidence, not gated against each other; what *is*
//!    asserted is that honest bytes and reduced bits are invariant to the
//!    transport and the bucketing;
//! 4. the parameter-server column (`sync.topology = "ps"`): α–β predicted
//!    push/pull time per shard count vs the ring, plus a smoke check that
//!    the PS session replays bit-identically and keeps its transport
//!    octets equal to the claimed `WireCost`.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::cpd::{quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::perfmodel::{fig11_layers, fig11_table, NetworkModel};
use aps_cpd::sync::{StrategySpec, SyncSessionBuilder, TransportSpec};
use aps_cpd::util::bench::Bench;
use aps_cpd::util::table::Table;

fn main() {
    support::header("Fig 11 — all-reduce time, FP16 vs APS-8bit", "paper §4.3, Fig 11");

    // ---- (1) analytic model -------------------------------------------
    println!("α–β model (32 workers, V100/NCCL calibration):\n");
    let rows = fig11_table(&NetworkModel::v100_nccl(), 32);
    let mut t = Table::new(&[
        "layer",
        "fp16 ms",
        "APS exp-phase ms",
        "APS payload ms",
        "APS total ms",
        "speedup",
    ]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.3}", r.fp16_ms),
            format!("{:.4}", r.aps_exp_phase_ms),
            format!("{:.3}", r.aps_payload_ms),
            format!("{:.3}", r.aps_total_ms),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t.print();
    for r in &rows {
        assert!(r.speedup > 1.0, "{} should beat fp16", r.label);
    }
    let fused = rows.last().unwrap();
    assert!(
        fused.speedup > 1.2,
        "fused speedup {:.2} should approach the paper's 1.33×",
        fused.speedup
    );
    println!(
        "\npaper reports ≈1.33× for the fused row; model gives {:.2}× ✔\n",
        fused.speedup
    );

    // ---- (2) measured emulation wall-clock ----------------------------
    println!("measured simulator wall-clock (8 sim workers on this host):\n");
    let world = 8;
    let cluster = SimCluster::new(world);
    let bench = Bench { warmup_iters: 1, samples: 7, iters_per_sample: 1 };
    let mut t = Table::new(&["layer", "quantize ms", "low-prec all-reduce ms", "fp32 all-reduce ms"]);
    for l in fig11_layers() {
        let n = l.elements as usize;
        let grads: Vec<Vec<f32>> = (0..world)
            .map(|w| (0..n).map(|i| ((w * 31 + i) % 1000) as f32 * 1e-6 - 5e-4).collect())
            .collect();
        let q = bench.run("quantize", || {
            quantize_shifted_slice(&grads[0], 10, FpFormat::E5M2, Rounding::NearestEven)
        });
        let contribs: Vec<Vec<f32>> = grads
            .iter()
            .map(|g| quantize_shifted_slice(g, 10, FpFormat::E5M2, Rounding::NearestEven))
            .collect();
        let r8 = bench.run("reduce8", || {
            cluster.all_reduce_sum(
                &contribs,
                Topology::Ring,
                ReduceOptions::low_precision(FpFormat::E5M2),
            )
        });
        let r32 = bench.run("reduce32", || {
            cluster.all_reduce_sum(&grads, Topology::Ring, ReduceOptions::fp32())
        });
        t.row(&[
            l.name.to_string(),
            format!("{:.3}", q.median() * 1e3),
            format!("{:.3}", r8.median() * 1e3),
            format!("{:.3}", r32.median() * 1e3),
        ]);
    }
    t.print();
    println!("\n(the emulated low-precision reduction pays the per-element cast —\n a real wire would pay bandwidth instead; see perfmodel for that side)");

    // ---- (3) overlapped pipeline: predicted vs measured ---------------
    println!("\noverlapped sync (step_overlapped): α–β predicted vs measured wall-clock");
    println!("(4 sim workers, fig11 layers at 1/64 scale; predicted prices each");
    println!(" bucket's honest bytes on the v100 ring — side-by-side evidence,");
    println!(" not a gated ratio, since the emulation pays compute not bandwidth):\n");

    let world = 4usize;
    let layers: Vec<usize> =
        fig11_layers().iter().map(|l| (l.elements / 64) as usize).collect();
    let grads: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|w| {
            layers
                .iter()
                .enumerate()
                .map(|(l, &n)| {
                    (0..n)
                        .map(|i| ((w * 131 + l * 31 + i) % 19) as f32 * 0.25 - 2.0)
                        .collect()
                })
                .collect()
        })
        .collect();
    // Backprop completion order: last layer's gradient lands first.
    let ready_order: Vec<usize> = (0..layers.len()).rev().collect();
    let codecs: [(&str, StrategySpec); 2] = [
        ("aps/e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("ternary", StrategySpec::Ternary { seed: 42 }),
    ];
    let transports =
        [TransportSpec::InProcess, TransportSpec::SharedMem, TransportSpec::Tcp];
    let bucket_cfgs: [(&str, usize); 3] =
        [("per-layer", 1), ("auto", 0), ("whole-model", 1 << 30)];
    let model = NetworkModel::v100_nccl();
    let ob = Bench { warmup_iters: 1, samples: 5, iters_per_sample: 1 };

    let mut t = Table::new(&[
        "codec",
        "transport",
        "bucketing",
        "buckets",
        "honest KB/wkr",
        "α–β pred ms",
        "measured ms",
    ]);
    for (cname, spec) in &codecs {
        // Synchronous reference: the bits and honest bytes every
        // overlapped configuration must reproduce exactly.
        let mut sync = SyncSessionBuilder::new(world).spec(spec.clone()).build();
        let (ref_out, ref_report) = sync.step(&grads);
        let ref_bits: Vec<Vec<u32>> =
            ref_out.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect();
        let ref_honest = ref_report.honest_bytes();

        for &transport in &transports {
            for &(bname, bucket_bytes) in &bucket_cfgs {
                let mut s = SyncSessionBuilder::new(world)
                    .spec(spec.clone())
                    .with_transport(transport)
                    .with_bucket_bytes(bucket_bytes)
                    .build();
                let (out, report) =
                    s.step_overlapped(&grads, &ready_order).expect("overlapped step");
                for (l, (rl, ol)) in ref_bits.iter().zip(out.iter()).enumerate() {
                    for (i, (&rb, &o)) in rl.iter().zip(ol.iter()).enumerate() {
                        assert_eq!(
                            rb,
                            o.to_bits(),
                            "{cname}@{}/{bname} layer {l} elem {i}: overlapped bits diverge",
                            transport.name()
                        );
                    }
                }
                assert_eq!(
                    report.honest_bytes(),
                    ref_honest,
                    "{cname}@{}/{bname}: honest bytes must not depend on transport or bucketing",
                    transport.name()
                );
                let covered: usize = report.buckets.iter().map(|b| b.layers).sum();
                assert_eq!(covered, layers.len(), "{cname}: every layer in exactly one bucket");
                // Price each bucket's per-worker share of its honest
                // octets on the calibrated ring, plus the producer-side
                // encode/pack pass over its elements; buckets are summed
                // (the α terms are what fusing amortizes away).
                let predicted_ms: f64 = report
                    .buckets
                    .iter()
                    .map(|b| {
                        model.encode_time(b.elements as u64)
                            + model.allreduce_time(Topology::Ring, world, b.bytes / world as u64)
                    })
                    .sum::<f64>()
                    * 1e3;
                let n_buckets = report.buckets.len();
                let honest_kb = report.honest_bytes() as f64 / 1024.0;
                let m = ob.run("overlap", || {
                    s.step_overlapped(&grads, &ready_order).expect("overlapped step");
                });
                t.row(&[
                    cname.to_string(),
                    transport.name().to_string(),
                    bname.to_string(),
                    format!("{n_buckets}"),
                    format!("{honest_kb:.1}"),
                    format!("{predicted_ms:.3}"),
                    format!("{:.3}", m.median() * 1e3),
                ]);
            }
        }
    }
    t.print();
    println!("\n(honest bytes and reduced bits verified invariant across all\n transport × bucket-size configurations ✔)");

    // ---- (4) parameter-server column ----------------------------------
    println!("\nparameter-server topology (sync.topology = \"ps\"): α–β predicted");
    println!("push/pull vs ring for the fused fig11 payload, and measured");
    println!("wall-clock of one PS session step (4 sim workers, 1/64 scale):\n");

    let total_bytes: u64 = layers.iter().map(|&n| n as u64).sum::<u64>() * 2; // fp16-width payload
    let ring_ms = model.allreduce_time(Topology::Ring, world, total_bytes) * 1e3;
    let mut t = Table::new(&[
        "codec",
        "shards",
        "α–β ring ms",
        "α–β PS ms",
        "measured ms",
    ]);
    for (cname, spec) in &codecs {
        for shards in [2usize, 4] {
            let topo = Topology::Ps { shards, staleness: 0 };
            let ps_ms = model.allreduce_time(topo, world, total_bytes) * 1e3;
            let mut s = SyncSessionBuilder::new(world)
                .spec(spec.clone())
                .with_topology(topo)
                .with_transport(TransportSpec::SharedMem)
                .build();
            let m = ob.run("ps", || {
                s.step_checked(&grads).expect("shared-mem PS step");
            });
            t.row(&[
                cname.to_string(),
                format!("{shards}"),
                format!("{ring_ms:.3}"),
                format!("{ps_ms:.3}"),
                format!("{:.3}", m.median() * 1e3),
            ]);
            let traffic = s.collective_traffic().expect("PS owns a transport");
            assert_eq!(
                traffic.octets, traffic.claimed_octets,
                "{cname}/shards={shards}: PS octets must match the claimed WireCost"
            );
        }
    }
    t.print();

    // Smoke: two identically-built PS sessions replay bit-identically.
    let mut a = SyncSessionBuilder::new(world)
        .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
        .with_topology(Topology::Ps { shards: 2, staleness: 0 })
        .build();
    let mut b = SyncSessionBuilder::new(world)
        .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
        .with_topology(Topology::Ps { shards: 2, staleness: 0 })
        .build();
    let (ao, _) = a.step_checked(&grads).expect("in-process PS step");
    let ao: Vec<Vec<u32>> =
        ao.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect();
    let (bo, _) = b.step_checked(&grads).expect("in-process PS step");
    for (l, (al, bl)) in ao.iter().zip(bo.iter()).enumerate() {
        for (i, (&x, &y)) in al.iter().zip(bl.iter()).enumerate() {
            assert_eq!(x, y.to_bits(), "ps smoke layer {l} elem {i}: replay diverged");
        }
    }
    println!("\n(PS replay bit-identical and wire-honest across shard counts ✔)");
}

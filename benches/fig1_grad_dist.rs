//! Fig 1 — gradient distributions for different neural networks.
//!
//! The paper's point: different models' gradients live at very different
//! scales, so one global loss-scaling factor cannot fit all. We train
//! each model a few steps and print the exponent histogram of all its
//! gradients, plus the p5/p50/p95 exponents.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::metrics::ExpHistogram;
use aps_cpd::util::table::Table;
use support::BenchEnv;

fn main() {
    support::header("Fig 1 — gradient distributions across models", "paper §3.1, Fig 1");
    let env = BenchEnv::new();

    let mut t = Table::new(&["model", "p5 exp", "p50 exp", "p95 exp", "spread (octaves)"]);
    let mut medians = Vec::new();
    for name in ["mlp", "davidnet", "resnet", "fcn", "transformer"] {
        let model = env.model(name);
        let mut setup = TrainerSetup::new(4, SyncOptions::new(SyncMethod::Fp32));
        setup.epochs = 1;
        setup.steps_per_epoch = 5;
        let mut trainer = Trainer::new(&model, setup).expect("trainer");
        let mut out = Default::default();
        for s in 0..5 {
            trainer.step(0, s, &mut out).expect("step");
        }
        let grads = trainer.snapshot_gradients(5).expect("grads");
        let mut h = ExpHistogram::gradient_window();
        for g in &grads {
            h.add_all(g);
        }
        let (p5, p50, p95) =
            (h.percentile_exp(5.0), h.percentile_exp(50.0), h.percentile_exp(95.0));
        medians.push((name, p50));
        t.row(&[
            name.to_string(),
            format!("2^{p5}"),
            format!("2^{p50}"),
            format!("2^{p95}"),
            format!("{}", p95 - p5),
        ]);
        println!("--- {name} ---");
        print!("{}", h.ascii(40));
        println!();
    }
    t.print();

    // Shape claim: the median gradient exponent differs across models.
    let min = medians.iter().map(|s| s.1).min().unwrap();
    let max = medians.iter().map(|s| s.1).max().unwrap();
    assert!(
        max - min >= 2,
        "models' median gradient scales should differ by ≥ 2 octaves (got {min}..{max})"
    );
    println!("\nmedian gradient exponent spans 2^{min}..2^{max} across models —");
    println!("no single loss-scaling constant fits all (the paper's Fig 1 argument) ✔");
}

//! Table 9 — average round-off error (Eq. 5) of the first conv layer's
//! gradient vs all-reduce group size, in (5,2) on 256 workers.
//!
//! Paper: k=4 55%, k=8 44.21%, k=16 41.83%, k=32 49.62%, k=64 58.21%,
//! ring(256) 85.22% — a U-shape with the minimum around k=16, and the
//! flat ring far worse.
//!
//! We reduce the *real* first-layer gradients of the ResNet model across
//! 256 simulated workers (each with its own data shard) under each
//! topology and evaluate Eq. 5 against the f64-exact reduction.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::cpd::{avg_roundoff_error, quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::util::table::Table;
use support::{env_usize, BenchEnv};

fn main() {
    support::header(
        "Table 9 — round-off error vs group size (first conv layer, (5,2))",
        "paper §4.2, Table 9",
    );
    let env = BenchEnv::new();
    let model = env.model("resnet");
    let world = env_usize("APS_BENCH_WORLD", 256);
    let fmt = FpFormat::E5M2;

    // Gather real per-worker gradients for the first conv layer after a
    // few warmup steps.
    let mut setup = TrainerSetup::new(world, SyncOptions::new(SyncMethod::Fp32));
    setup.epochs = 1;
    setup.steps_per_epoch = 3;
    let mut trainer = Trainer::new(&model, setup).expect("trainer");
    let mut scratch = Default::default();
    for s in 0..2 {
        trainer.step(0, s, &mut scratch).expect("warm step");
    }
    let (_, worker_grads) = trainer.worker_grads(2).expect("grads");
    let layer = 0usize; // stem conv weight
    println!(
        "layer `{}` ({} elements) across {world} workers\n",
        model.spec.params[layer].name,
        worker_grads[0][layer].len()
    );

    // APS-style shift shared by all topologies (the paper measures the
    // wire round-off of the 8-bit payload).
    let me = worker_grads
        .iter()
        .filter_map(|wg| aps_cpd::aps::local_max_exp(&wg[layer], world))
        .max()
        .unwrap();
    let fe = fmt.max_exponent() - me;
    let contribs: Vec<Vec<f32>> = worker_grads
        .iter()
        .map(|wg| quantize_shifted_slice(&wg[layer], fe, fmt, Rounding::NearestEven))
        .collect();
    let exact: Vec<f32> = (0..contribs[0].len())
        .map(|i| worker_grads.iter().map(|wg| wg[layer][i] as f64).sum::<f64>() as f32)
        .collect();
    // Scale the exact reduction to wire scale for a like-for-like Eq. 5.
    let exact_scaled: Vec<f32> =
        exact.iter().map(|&x| (x as f64 * (fe as f64).exp2()) as f32).collect();

    let cluster = SimCluster::new(world);
    let paper: &[(usize, f64)] =
        &[(4, 55.0), (8, 44.21), (16, 41.83), (32, 49.62), (64, 58.21)];

    let mut t = Table::new(&["group size", "measured Eq.5 %", "paper Eq.5 %"]);
    let mut errs = Vec::new();
    for (k, paper_pct) in paper {
        if world % k != 0 {
            continue;
        }
        let (out, _) = cluster.all_reduce_sum(
            &contribs,
            Topology::Hierarchical { group_size: *k },
            ReduceOptions::low_precision(fmt),
        );
        let e = avg_roundoff_error(&exact_scaled, &out);
        errs.push((*k, e));
        t.row(&[
            k.to_string(),
            format!("{:.2}", 100.0 * e),
            format!("{:.2}", paper_pct),
        ]);
    }
    let (ring_out, _) =
        cluster.all_reduce_sum(&contribs, Topology::Ring, ReduceOptions::low_precision(fmt));
    let ring_err = avg_roundoff_error(&exact_scaled, &ring_out);
    t.row(&[
        format!("{world} (ring all-reduce)"),
        format!("{:.2}", 100.0 * ring_err),
        "85.22".to_string(),
    ]);
    t.print();
    support::shape_note();

    // Shape: ring is the worst; mid-size groups beat both extremes.
    let worst_hier = errs.iter().map(|e| e.1).fold(0.0, f64::max);
    let best = errs.iter().cloned().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    assert!(
        ring_err > worst_hier,
        "ring ({ring_err:.3}) must exceed every hierarchical error ({worst_hier:.3})"
    );
    assert!(
        (8..=32).contains(&best.0),
        "minimum round-off should sit at a mid group size (got k={})",
        best.0
    );
    println!(
        "\nshape ✔  ring all-reduce is worst; the U-shape bottoms out at k={}\n(paper: k=16)",
        best.0
    );
}

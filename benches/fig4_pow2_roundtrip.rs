//! Fig 4 — power-of-two vs non-power-of-two scaling factors.
//!
//! Scaling by 8 touches only the exponent field, so the wire value
//! `Q(x·8)` is *exactly* `x·8` for every representable `x` — nothing is
//! lost in the scaled communication. Scaling by 10 disturbs the mantissa:
//! `Q(x·10) ≠ x·10`, i.e. the gradient that actually travels is wrong by
//! up to half an ulp before the reduction even starts.
//!
//! We sweep factors 2..16 over every representable (5,2) magnitude (whose
//! scaled value stays in range) and report the mean relative *wire* error
//! `|Q(x·f) − x·f| / (x·f)`, plus the fraction of values represented
//! inexactly.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::cpd::{quantize, FpFormat, Rounding};
use aps_cpd::util::table::Table;

const RNE: Rounding = Rounding::NearestEven;

fn main() {
    support::header("Fig 4 — power-of-two scaling is lossless on the wire", "paper §3.3.1, Fig 4");
    let fmt = FpFormat::E5M2;
    // Representable magnitudes whose ×16 stays finite and whose value is
    // normal (subnormals lose mantissa bits by construction).
    let vals: Vec<f32> = fmt
        .enumerate_magnitudes()
        .into_iter()
        .filter(|&v| {
            v >= fmt.min_normal() as f32 && (v as f64) * 16.0 <= fmt.max_value()
        })
        .collect();
    assert!(vals.len() > 20);

    let mut t = Table::new(&["factor", "inexact wire values", "mean |wire rel err|"]);
    let mut pow2_clean = true;
    let mut non_pow2_dirty = 0usize;
    for factor in 2..=16u32 {
        let f = factor as f32;
        let mut inexact = 0usize;
        let mut err = 0.0f64;
        for &v in &vals {
            let scaled = v as f64 * f as f64; // exact in f64
            let wire = quantize(v * f, fmt, RNE) as f64;
            if wire != scaled {
                inexact += 1;
                err += ((wire - scaled) / scaled).abs();
            }
        }
        let is_pow2 = factor.is_power_of_two();
        if is_pow2 && inexact > 0 {
            pow2_clean = false;
        }
        if !is_pow2 && inexact > 0 {
            non_pow2_dirty += 1;
        }
        t.row(&[
            format!("{factor}{}", if is_pow2 { "  (2^k)" } else { "" }),
            format!("{}/{}", inexact, vals.len()),
            format!("{:.4}", err / vals.len() as f64),
        ]);
    }
    t.print();

    assert!(pow2_clean, "power-of-two factors must put exact values on the wire");
    assert_eq!(non_pow2_dirty, 11, "every non-power factor must corrupt some values");
    println!(
        "\npower-of-two factors put the exact scaled value on the wire;\nevery non-power factor corrupts mantissas — the paper's Fig 4 argument ✔"
    );

    // The paper's concrete example: 8 is clean, 10 is not.
    let x = 1.25f32;
    println!("\nconcrete (5,2) example: x = {x}");
    println!("  Q(x·8)  = {}   (= x·8 exactly)", quantize(x * 8.0, fmt, RNE));
    println!("  Q(x·10) = {}   (x·10 = 12.5 is not representable)", quantize(x * 10.0, fmt, RNE));
    assert_eq!(quantize(x * 8.0, fmt, RNE), 10.0);
    assert_ne!(quantize(x * 10.0, fmt, RNE) as f64, 12.5);
}

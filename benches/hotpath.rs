//! Hot-path micro-benchmarks (§Perf): quantize throughput, all-reduce
//! emulation throughput, APS end-to-end sync (one-shot throwaway session
//! vs. the buffer-reusing SyncSession), and the packed-wire strategy
//! sweep whose bytes-moved column must equal `SyncReport::honest_bytes`.
//! Used by the performance pass in EXPERIMENTS.md §Perf.
//!
//! Run with `--test` (CI does) for a single-iteration smoke pass on a
//! small tensor that asserts the packed-traffic invariants — packed
//! ternary must move ≤ 1/10th the bytes of the FP32 wire, with the
//! parallel packed fold it must also sustain ≥ the dense simulated FP32
//! wire in elements/sec, and the parallel encode fan-out must sustain ≥
//! the serial encode loop in encode-phase elements/sec at world 8 — and
//! emits `BENCH_packed.json` (elements/sec + bytes moved for every
//! conformance codec × both collectives, the dense fp32 baseline, the
//! serial/parallel encode rows, and the overlap rows' per-phase
//! encode/transit/fold/wait breakdown), the perf trajectory record.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{SyncMethod, SyncOptions};
use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::cpd::{quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::sync::{StrategySpec, SyncSessionBuilder, TransportSpec, WireMode};
use aps_cpd::util::bench::Bench;
use aps_cpd::util::json::Json;
use std::collections::BTreeMap;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    support::header("hot-path microbenchmarks", "EXPERIMENTS.md §Perf");
    let bench = if smoke {
        Bench { warmup_iters: 1, samples: 1, iters_per_sample: 1 }
    } else {
        Bench { warmup_iters: 2, samples: 9, iters_per_sample: 1 }
    };
    // 4 Mi elements ≈ ResNet-50-scale layer block; the smoke pass shrinks
    // it so CI stays fast.
    let n = if smoke { 1 << 14 } else { 4 << 20 };
    let xs: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin() * 1e-3).collect();

    // quantize (downcast) throughput
    let m = bench.run("quantize_shifted_slice e5m2", || {
        quantize_shifted_slice(&xs, 12, FpFormat::E5M2, Rounding::NearestEven)
    });
    println!("{}", m.report_throughput(4 * n as u64));

    // ring all-reduce emulation, 8 workers
    let world = 8;
    let grads: Vec<Vec<f32>> = (0..world)
        .map(|w| xs.iter().map(|&x| x * (1.0 + w as f32 * 0.01)).collect())
        .collect();
    let cluster = SimCluster::new(world);
    for (label, fmt, kahan) in [
        ("ring all-reduce fp32 (8w)", FpFormat::FP32, false),
        ("ring all-reduce e5m2 (8w)", FpFormat::E5M2, false),
        ("ring all-reduce e5m2+kahan (8w)", FpFormat::E5M2, true),
    ] {
        let m = bench.run(label, || {
            cluster.all_reduce_sum(
                &grads,
                Topology::Ring,
                ReduceOptions { fmt, mode: Rounding::NearestEven, kahan },
            )
        });
        println!("{}", m.report_throughput(4 * (n as u64) * world as u64));
    }

    // full APS sync (quantize + exponent phase + reduce + unscale):
    // a throwaway session per call (what the removed `aps::synchronize`
    // shim did — re-allocates every buffer)…
    let layered: Vec<Vec<Vec<f32>>> = grads.iter().map(|g| vec![g.clone()]).collect();
    let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
    let m = bench.run("one-shot session aps e5m2 (8w, alloc/call)", || {
        let mut s = SyncSessionBuilder::from_sync_options(world, &opts).build();
        let (reduced, report) = s.step(&layered);
        (reduced[0][0], report.payload_bytes)
    });
    println!("{}", m.report_throughput(4 * (n as u64) * world as u64));

    // …vs. the SyncSession, which owns wire/output buffers across steps.
    let mut session = SyncSessionBuilder::from_sync_options(world, &opts).build();
    let m = bench.run("SyncSession::step aps e5m2 (8w, reused buffers)", || {
        let (reduced, report) = session.step(&layered);
        (reduced[0][0], report.payload_bytes)
    });
    println!("{}", m.report_throughput(4 * (n as u64) * world as u64));

    // ---- packed wire: bytes actually moved per strategy ---------------
    // The tentpole claim, measured: on the packed path the bytes the
    // simulator moves equal the codec's honest wire accounting
    // (`SyncReport::honest_bytes`), so 2-bit ternary moves ~1/16th of
    // the FP32 wire instead of the same dense f32 lanes.
    println!("\npacked wire (bytes moved per worker per step == honest_bytes):");
    let ef = |inner: StrategySpec| StrategySpec::ErrorFeedback { inner: Box::new(inner) };
    // The full conformance codec family (bench parameterization), so the
    // perf-trajectory record covers every codec the contract pins.
    let strategies: Vec<(&str, StrategySpec)> = vec![
        ("fp32", StrategySpec::Fp32),
        ("naive_e5m2", StrategySpec::Naive { fmt: FpFormat::E5M2 }),
        (
            "loss_scaling_e5m2",
            StrategySpec::LossScaling { fmt: FpFormat::E5M2, factor_exp: 8 },
        ),
        ("aps_e5m2", StrategySpec::Aps { fmt: FpFormat::E5M2 }),
        ("aps_e4m3", StrategySpec::Aps { fmt: FpFormat::E4M3 }),
        ("ternary", StrategySpec::Ternary { seed: 42 }),
        ("topk_0.25", StrategySpec::TopK { frac: 0.25 }),
        ("qsgd_b4", StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 42 }),
        ("ef_ternary", ef(StrategySpec::Ternary { seed: 42 })),
        ("ef_topk", ef(StrategySpec::TopK { frac: 0.25 })),
        ("ef_qsgd", ef(StrategySpec::Qsgd { bits: 4, bucket: 256, seed: 42 })),
    ];
    let collectives: [(&str, Topology); 2] =
        [("ring", Topology::Ring), ("hier4", Topology::Hierarchical { group_size: 4 })];
    let mut rows: BTreeMap<String, Json> = BTreeMap::new();
    let mut moved_bytes: BTreeMap<String, u64> = BTreeMap::new();
    let mut elems_rate: BTreeMap<String, f64> = BTreeMap::new();
    for (cname, topo) in collectives {
        for (name, spec) in &strategies {
            let key = format!("{name}@{cname}");
            let mut packed = SyncSessionBuilder::new(world)
                .spec(spec.clone())
                .with_topology(topo)
                .build();
            let m = bench.run(&format!("packed step {key} (8w)"), || {
                let (reduced, report) = packed.step(&layered);
                (reduced[0][0], report.payload_bytes)
            });
            let report = packed.report().clone();
            let moved = packed
                .wire_moved()
                .expect("packed sessions measure moved traffic");
            // Measured packed traffic (+ the exponent side channel) must be
            // exactly the codec's honest accounting.
            assert_eq!(
                moved,
                report.wire,
                "{key}: bytes moved diverge from the claimed wire cost"
            );
            let measured_total = moved.total_bytes() + report.exponent_bytes;
            assert_eq!(
                measured_total,
                report.honest_bytes(),
                "{key}: measured bytes-moved != SyncReport::honest_bytes"
            );
            let elems_per_sec = n as f64 / m.median();
            println!(
                "{}  [moved {} KiB/worker, {:.1} Melem/s]",
                m.report(),
                measured_total / 1024,
                elems_per_sec / 1e6
            );
            moved_bytes.insert(key.clone(), measured_total);
            elems_rate.insert(key.clone(), elems_per_sec);
            let mut row = BTreeMap::new();
            row.insert("bytes_moved".to_string(), Json::Num(measured_total as f64));
            row.insert("elems_per_sec".to_string(), Json::Num(elems_per_sec));
            row.insert("encode_ns".to_string(), Json::Num(report.encode_ns as f64));
            rows.insert(key, Json::Obj(row));
        }
    }

    // Dense fp32 baseline: the simulated wire moves full f32 lanes
    // through the same session hot path — the elems/sec yardstick the
    // parallel packed fold is gated against.
    let mut dense = SyncSessionBuilder::new(world)
        .spec(StrategySpec::Fp32)
        .with_wire(WireMode::Simulated)
        .build();
    let m = bench.run("dense step fp32_sim (8w)", || {
        let (reduced, report) = dense.step(&layered);
        (reduced[0][0], report.payload_bytes)
    });
    let dense_elems_per_sec = n as f64 / m.median();
    let dense_bytes = dense.report().honest_bytes();
    println!(
        "{}  [honest {} KiB/worker, {:.1} Melem/s]",
        m.report(),
        dense_bytes / 1024,
        dense_elems_per_sec / 1e6
    );
    {
        let mut row = BTreeMap::new();
        row.insert("bytes_moved".to_string(), Json::Num(dense_bytes as f64));
        row.insert("elems_per_sec".to_string(), Json::Num(dense_elems_per_sec));
        rows.insert("dense_fp32@sim".to_string(), Json::Obj(row));
    }

    // The headline ratio: packed ternary vs the FP32 wire.
    let fp32_moved = moved_bytes["fp32@ring"];
    let ternary_moved = moved_bytes["ternary@ring"];
    assert!(
        ternary_moved <= fp32_moved / 10,
        "packed ternary must move ≤ 1/10th of the fp32 wire \
         (ternary {ternary_moved} B vs fp32 {fp32_moved} B)"
    );
    println!(
        "\npacked ternary moves {ternary_moved} B vs fp32 {fp32_moved} B \
         ({:.1}x reduction)",
        fp32_moved as f64 / ternary_moved as f64
    );
    // …and, with the parallel packed fold, the byte win is no longer a
    // wall-clock loss: packed ternary must match the dense fp32 wire in
    // elements/sec. Timing gates are CI-pinned in the smoke pass only
    // (single-iteration, same machine for both rows); full runs report
    // the ratio without gating.
    let ternary_rate = elems_rate["ternary@ring"];
    println!(
        "packed ternary {:.1} Melem/s vs dense fp32 {:.1} Melem/s ({:.2}x)",
        ternary_rate / 1e6,
        dense_elems_per_sec / 1e6,
        ternary_rate / dense_elems_per_sec
    );
    if smoke {
        assert!(
            ternary_rate >= dense_elems_per_sec,
            "packed ternary must sustain ≥ dense fp32 elems/sec \
             (ternary {ternary_rate:.0} vs dense {dense_elems_per_sec:.0})"
        );
    }

    // ---- producer-side encode: parallel twin fan-out vs serial loop ----
    // The phase `SyncReport::encode_ns` measures — quantize → pack for
    // all 8 workers — on one reduction-threshold-clearing layer, APS
    // e5m2. Rates are encode-phase only (the fold is identical in both
    // sessions), medians over several steps so the smoke gate does not
    // ride on one-shot spawn noise. Outputs must be bit-identical: the
    // fan-out only moves whole per-worker encode chains onto twin lanes.
    println!("\nparallel encode (per-worker twin lanes) vs serial encode loop:");
    let en = if smoke { 1 << 17 } else { 4 << 20 };
    let enc_grads: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|w| {
            vec![(0..en).map(|i| ((w * 131 + i) % 23) as f32 * 0.0625 - 0.7).collect()]
        })
        .collect();
    let enc_elems = (en * world) as u64;
    let enc_steps = if smoke { 5 } else { 9 };
    let mut enc_rates: BTreeMap<&str, f64> = BTreeMap::new();
    let mut enc_outs: BTreeMap<&str, Vec<u32>> = BTreeMap::new();
    for (label, threads) in [("encode_serial", 1usize), ("encode_parallel", 8)] {
        let mut s = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Aps { fmt: FpFormat::E5M2 })
            .with_encode_threads(threads)
            .build();
        let _ = s.step(&enc_grads); // warm the session buffers
        let mut ns: Vec<u64> = Vec::new();
        for _ in 0..enc_steps {
            let (_, rep) = s.step(&enc_grads);
            ns.push(rep.encode_ns);
        }
        ns.sort_unstable();
        let med_ns = ns[ns.len() / 2].max(1);
        let rate = enc_elems as f64 / (med_ns as f64 * 1e-9);
        let report = s.report().clone();
        let moved = s.wire_moved().expect("packed sessions measure moved traffic");
        let bytes = moved.total_bytes() + report.exponent_bytes;
        println!(
            "  {label} ({threads} thr): encode {:.3} ms/step, {:.1} Melem/s \
             [{} KiB/worker honest]",
            med_ns as f64 * 1e-6,
            rate / 1e6,
            bytes / 1024
        );
        enc_rates.insert(label, rate);
        enc_outs.insert(label, s.reduced()[0].iter().map(|x| x.to_bits()).collect());
        let mut row = BTreeMap::new();
        row.insert("bytes_moved".to_string(), Json::Num(bytes as f64));
        row.insert("elems_per_sec".to_string(), Json::Num(rate));
        row.insert("encode_ns".to_string(), Json::Num(med_ns as f64));
        row.insert("encode_threads".to_string(), Json::Num(threads as f64));
        rows.insert(format!("{label}@world8"), Json::Obj(row));
    }
    assert_eq!(
        enc_outs["encode_serial"], enc_outs["encode_parallel"],
        "parallel encode fan-out must be bit-identical to the serial loop"
    );
    println!(
        "  parallel/serial encode throughput: {:.2}x",
        enc_rates["encode_parallel"] / enc_rates["encode_serial"]
    );
    if smoke {
        assert!(
            enc_rates["encode_parallel"] >= enc_rates["encode_serial"],
            "parallel encode must sustain ≥ serial encode elems/sec at world 8 \
             (parallel {:.0} vs serial {:.0})",
            enc_rates["encode_parallel"],
            enc_rates["encode_serial"]
        );
    }

    // ---- overlapped bucket pipeline vs the synchronous packed path ----
    // A 16-layer model with every layer below the parallel-fold
    // threshold: the synchronous path folds each layer single-threaded,
    // so shipping ready buckets to the overlap pool (encode of bucket
    // k+1 overlapping transit+fold of bucket k) is where wall-clock is
    // genuinely won. Outputs stay bit-identical to `step()` — pinned by
    // rust/tests/transport_overlap.rs and cross-checked below.
    println!("\noverlapped step (bucketed async all-reduce, ternary, 16 layers):");
    let ol_layers = 16usize;
    let ol_n = if smoke { 8192 } else { 1 << 16 };
    let ol_grads: Vec<Vec<Vec<f32>>> = (0..world)
        .map(|w| {
            (0..ol_layers)
                .map(|l| {
                    (0..ol_n)
                        .map(|i| ((w * 131 + l * 31 + i) % 17) as f32 * 0.125 - 1.0)
                        .collect()
                })
                .collect()
        })
        .collect();
    let ready_order: Vec<usize> = (0..ol_layers).rev().collect();
    let total_elems = (ol_layers * ol_n) as u64;
    // Medians over several samples: the overlap gate compares two timed
    // rows, so single-iteration noise would gate on luck.
    let ob = Bench { warmup_iters: 1, samples: 5, iters_per_sample: 1 };

    let mut sync_sess =
        SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 42 }).build();
    let m = ob.run("sync packed ternary 16-layer (8w)", || {
        let (r, rep) = sync_sess.step(&ol_grads);
        (r[0][0], rep.payload_bytes)
    });
    let sync_rate = total_elems as f64 / m.median();
    println!("{}  [{:.1} Melem/s]", m.report(), sync_rate / 1e6);

    let mut overlap_rate_in_process = 0.0f64;
    for (tname, tspec) in [
        ("in_process", TransportSpec::InProcess),
        ("shared_mem", TransportSpec::SharedMem),
        ("tcp", TransportSpec::Tcp),
    ] {
        // bucket_bytes stays 0 = auto (the gated configuration).
        let mut os = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 42 })
            .with_transport(tspec)
            .build();
        let m = ob.run(&format!("overlap ternary@{tname} bb=auto (8w)"), || {
            let (r, rep) =
                os.step_overlapped(&ol_grads, &ready_order).expect("overlapped step");
            (r[0][0], rep.payload_bytes)
        });
        let rate = total_elems as f64 / m.median();
        let report = os.report().clone();
        let moved =
            os.wire_moved().expect("overlapped sessions measure moved traffic");
        assert_eq!(
            moved, report.wire,
            "overlap@{tname}: bytes moved diverge from the claimed wire cost"
        );
        let measured_total = moved.total_bytes() + report.exponent_bytes;
        println!(
            "{}  [{} buckets, moved {} KiB/worker, {:.1} Melem/s]",
            m.report(),
            report.buckets.len(),
            measured_total / 1024,
            rate / 1e6
        );
        if let Some(traffic) = os.transport_traffic() {
            assert_eq!(
                traffic.octets, traffic.claimed_octets,
                "overlap@{tname}: transport octets diverge from the encode-side claim"
            );
        }
        if tname == "in_process" {
            overlap_rate_in_process = rate;
        }
        // Transport/bucket columns + per-bucket stats for the
        // perf-trajectory record.
        let buckets: Vec<Json> = report
            .buckets
            .iter()
            .map(|b| {
                let mut o = BTreeMap::new();
                o.insert("bucket".to_string(), Json::Num(b.bucket as f64));
                o.insert("layers".to_string(), Json::Num(b.layers as f64));
                o.insert("elements".to_string(), Json::Num(b.elements as f64));
                o.insert("bytes".to_string(), Json::Num(b.bytes as f64));
                o.insert("encode_ns".to_string(), Json::Num(b.encode_ns as f64));
                o.insert("transit_ns".to_string(), Json::Num(b.transit_ns as f64));
                o.insert("fold_ns".to_string(), Json::Num(b.fold_ns as f64));
                o.insert("wait_ns".to_string(), Json::Num(b.wait_ns as f64));
                Json::Obj(o)
            })
            .collect();
        // Per-phase breakdown summed over buckets: the encode (producer)
        // vs exchange (transit+wait) vs fold split of the last step.
        let (mut transit_ns, mut fold_ns, mut wait_ns) = (0u64, 0u64, 0u64);
        for b in &report.buckets {
            transit_ns += b.transit_ns;
            fold_ns += b.fold_ns;
            wait_ns += b.wait_ns;
        }
        let mut row = BTreeMap::new();
        row.insert("bytes_moved".to_string(), Json::Num(measured_total as f64));
        row.insert("elems_per_sec".to_string(), Json::Num(rate));
        row.insert("transport".to_string(), Json::Str(tname.to_string()));
        row.insert("bucket_bytes".to_string(), Json::Str("auto".to_string()));
        row.insert("encode_ns".to_string(), Json::Num(report.encode_ns as f64));
        row.insert("transit_ns".to_string(), Json::Num(transit_ns as f64));
        row.insert("fold_ns".to_string(), Json::Num(fold_ns as f64));
        row.insert("wait_ns".to_string(), Json::Num(wait_ns as f64));
        row.insert("buckets".to_string(), Json::Arr(buckets));
        rows.insert(format!("overlap_ternary@{tname}"), Json::Obj(row));
    }
    println!(
        "overlapped (in_process) {:.1} Melem/s vs synchronous packed {:.1} Melem/s ({:.2}x)",
        overlap_rate_in_process / 1e6,
        sync_rate / 1e6,
        overlap_rate_in_process / sync_rate
    );
    if smoke {
        // The overlap-efficiency gate: at bucket_bytes=auto the
        // overlapped path must at least match the synchronous packed
        // path (same machine, same workload, medians of 5).
        assert!(
            overlap_rate_in_process >= sync_rate,
            "step_overlapped must sustain >= the synchronous packed path \
             (overlapped {overlap_rate_in_process:.0} vs sync {sync_rate:.0} elems/s)"
        );
        // Bit-identity cross-check on fresh sessions (same step counter).
        let mut a =
            SyncSessionBuilder::new(world).spec(StrategySpec::Ternary { seed: 42 }).build();
        let mut b = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 42 })
            .with_transport(TransportSpec::SharedMem)
            .build();
        let (ao, _) = a.step(&ol_grads);
        let ao: Vec<Vec<f32>> = ao.to_vec();
        let (bo, _) = b.step_overlapped(&ol_grads, &ready_order).expect("overlapped step");
        for (al, bl) in ao.iter().zip(bo.iter()) {
            for (x, y) in al.iter().zip(bl.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "overlapped/synchronous divergence");
            }
        }
    }

    if smoke {
        // Cross-check against the simulated wire: bit-identical outputs.
        let mut sim = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 42 })
            .with_wire(WireMode::Simulated)
            .build();
        let mut pk = SyncSessionBuilder::new(world)
            .spec(StrategySpec::Ternary { seed: 42 })
            .build();
        let (so, _) = sim.step(&layered);
        let so = so.to_vec();
        let (po, _) = pk.step(&layered);
        for (a, b) in so[0].iter().zip(po[0].iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "packed/simulated divergence");
        }

        // Emit the perf-trajectory record.
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("hotpath-packed".to_string()));
        doc.insert("world".to_string(), Json::Num(world as f64));
        doc.insert("elements".to_string(), Json::Num(n as f64));
        doc.insert("strategies".to_string(), Json::Obj(rows));
        std::fs::write("BENCH_packed.json", Json::Obj(doc).to_string())
            .expect("write BENCH_packed.json");
        println!("[smoke] packed-wire invariants OK, BENCH_packed.json written");
    }

    // PJRT train step, if artifacts are present
    if !smoke && std::path::Path::new("artifacts/.stamp").exists() {
        let engine = aps_cpd::runtime::Engine::cpu().expect("engine");
        let model = engine.load_model("artifacts", "resnet").expect("model");
        let params = model.initial_params().expect("init");
        let b = model.spec.batch;
        let x = vec![0.1f32; b * model.spec.x_elems_per_example()];
        let y = vec![1i32; b];
        let m = bench.run("PJRT train_step resnet (batch 16)", || {
            model.train_step(&params, Some(&x), None, &y).expect("step")
        });
        println!("{}", m.report());
    }
}

//! Hot-path micro-benchmarks (§Perf): quantize throughput, all-reduce
//! emulation throughput, APS end-to-end sync (one-shot shim vs. the
//! buffer-reusing SyncSession), and the PJRT train-step.
//! Used by the performance pass in EXPERIMENTS.md §Perf.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{self, SyncMethod, SyncOptions};
use aps_cpd::collectives::{ReduceOptions, SimCluster, Topology};
use aps_cpd::cpd::{quantize_shifted_slice, FpFormat, Rounding};
use aps_cpd::sync::SyncSessionBuilder;
use aps_cpd::util::bench::Bench;

fn main() {
    support::header("hot-path microbenchmarks", "EXPERIMENTS.md §Perf");
    let bench = Bench { warmup_iters: 2, samples: 9, iters_per_sample: 1 };
    let n = 4 << 20; // 4 Mi elements ≈ ResNet-50-scale layer block
    let xs: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin() * 1e-3).collect();

    // quantize (downcast) throughput
    let m = bench.run("quantize_shifted_slice e5m2, 4Mi f32", || {
        quantize_shifted_slice(&xs, 12, FpFormat::E5M2, Rounding::NearestEven)
    });
    println!("{}", m.report_throughput(4 * n as u64));

    // ring all-reduce emulation, 8 workers
    let world = 8;
    let grads: Vec<Vec<f32>> = (0..world)
        .map(|w| xs.iter().map(|&x| x * (1.0 + w as f32 * 0.01)).collect())
        .collect();
    let cluster = SimCluster::new(world);
    for (label, fmt, kahan) in [
        ("ring all-reduce fp32 (8w, 4Mi)", FpFormat::FP32, false),
        ("ring all-reduce e5m2 (8w, 4Mi)", FpFormat::E5M2, false),
        ("ring all-reduce e5m2+kahan (8w, 4Mi)", FpFormat::E5M2, true),
    ] {
        let m = bench.run(label, || {
            cluster.all_reduce_sum(
                &grads,
                Topology::Ring,
                ReduceOptions { fmt, mode: Rounding::NearestEven, kahan },
            )
        });
        println!("{}", m.report_throughput(4 * (n as u64) * world as u64));
    }

    // full APS sync (quantize + exponent phase + reduce + unscale):
    // the deprecated one-shot shim (re-allocates every buffer per call)…
    let layered: Vec<Vec<Vec<f32>>> = grads.iter().map(|g| vec![g.clone()]).collect();
    let opts = SyncOptions::new(SyncMethod::Aps { fmt: FpFormat::E5M2 });
    #[allow(deprecated)]
    let m = bench.run("aps::synchronize e5m2 (8w, 1 layer × 4Mi)", || {
        aps::synchronize(&cluster, &layered, &opts)
    });
    println!("{}", m.report_throughput(4 * (n as u64) * world as u64));

    // …vs. the SyncSession, which owns wire/output buffers across steps.
    let mut session = SyncSessionBuilder::from_sync_options(world, &opts).build();
    let m = bench.run("SyncSession::step aps e5m2 (8w, reused buffers)", || {
        let (reduced, report) = session.step(&layered);
        (reduced[0][0], report.payload_bytes)
    });
    println!("{}", m.report_throughput(4 * (n as u64) * world as u64));

    // PJRT train step, if artifacts are present
    if std::path::Path::new("artifacts/.stamp").exists() {
        let engine = aps_cpd::runtime::Engine::cpu().expect("engine");
        let model = engine.load_model("artifacts", "resnet").expect("model");
        let params = model.initial_params().expect("init");
        let b = model.spec.batch;
        let x = vec![0.1f32; b * model.spec.x_elems_per_example()];
        let y = vec![1i32; b];
        let m = bench.run("PJRT train_step resnet (batch 16)", || {
            model.train_step(&params, Some(&x), None, &y).expect("step")
        });
        println!("{}", m.report());
    }
}

//! §5.1.1 — Kahan summation for low-precision accumulation: error of
//! naive vs Kahan accumulation and GEMM across formats and lengths.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::cpd::accum::{sum_kahan, sum_low_precision};
use aps_cpd::cpd::gemm::{dot, AccumStrategy};
use aps_cpd::cpd::{FpFormat, Rounding};
use aps_cpd::data::Rng;
use aps_cpd::util::table::Table;

const RNE: Rounding = Rounding::NearestEven;

fn main() {
    support::header("Kahan low-precision accumulation study", "paper §5.1.1");
    let mut rng = Rng::new(3);

    println!("running sums of n uniform(0,1) terms (relative error vs exact):\n");
    let mut t = Table::new(&["format", "n", "naive err %", "kahan err %"]);
    let mut aggregate = Vec::new();
    for fmt in [FpFormat::E5M2, FpFormat::E4M3, FpFormat::new(5, 10), FpFormat::BF16] {
        for n in [64usize, 512, 4096] {
            // Scale terms so the exact sum sits near max/8 — inside the
            // format's range (otherwise Kahan tracks the true sum so well
            // it *overflows* where the stalled naive sum does not).
            let scale = (fmt.max_value() as f32) / (8.0 * n as f32);
            let xs: Vec<f32> = (0..n).map(|_| rng.uniform() * scale).collect();
            let exact: f64 = xs.iter().map(|&x| x as f64).sum();
            let naive = (sum_low_precision(&xs, fmt, RNE) as f64 - exact).abs() / exact;
            let kahan = (sum_kahan(&xs, fmt, RNE) as f64 - exact).abs() / exact;
            aggregate.push((naive, kahan));
            t.row(&[
                format!("{fmt}"),
                n.to_string(),
                format!("{:.3}", 100.0 * naive),
                format!("{:.3}", 100.0 * kahan),
            ]);
        }
    }
    t.print();
    let mean_naive: f64 =
        aggregate.iter().map(|a| a.0).sum::<f64>() / aggregate.len() as f64;
    let mean_kahan: f64 =
        aggregate.iter().map(|a| a.1).sum::<f64>() / aggregate.len() as f64;
    assert!(
        mean_kahan < mean_naive * 0.8,
        "kahan mean {mean_kahan} should be well below naive {mean_naive}"
    );
    println!(
        "\nmean error: naive {:.2}%, kahan {:.2}% — Kahan recovers most of the\naccumulation loss ✔",
        100.0 * mean_naive,
        100.0 * mean_kahan
    );

    println!("\ndot products (k terms in (4,3), inputs ~ U(-1,1)):\n");
    let mut t = Table::new(&["k", "wide-then-cast", "low-precision", "low-prec + Kahan", "exact"]);
    for k in [64usize, 256, 1024] {
        let a: Vec<f32> = (0..k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k).map(|_| rng.range(-1.0, 1.0)).collect();
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let fmt = FpFormat::E4M3;
        t.row(&[
            k.to_string(),
            format!("{:.3}", dot(&a, &b, fmt, RNE, AccumStrategy::WideThenCast)),
            format!("{:.3}", dot(&a, &b, fmt, RNE, AccumStrategy::LowPrecision)),
            format!("{:.3}", dot(&a, &b, fmt, RNE, AccumStrategy::Kahan)),
            format!("{:.3}", exact),
        ]);
    }
    t.print();
    println!("\n(Fig 12's point: the wide-accumulator result hides the error a real\n low-precision accumulator would make; CPD exposes and Kahan repairs it)");
}

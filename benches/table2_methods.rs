//! Table 2 — method comparison: hyper-parameter compatibility and
//! communication cost for a gradient of L elements.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::perfmodel::table2_cost;
use aps_cpd::util::table::Table;

fn main() {
    support::header("Table 2 — APS vs related methods", "paper §2.1.2, Table 2");
    let l = 1_000_000u64; // 1M-element gradient

    let mut t = Table::new(&[
        "method",
        "same hyper-params as FP32",
        "comm cost (gradient size L)",
        "extra hyper-parameter",
    ]);
    t.row_str(&[
        "APS (this work)",
        "yes",
        "allreduce(8 bits) + allreduce(8L bits)",
        "no",
    ]);
    t.row_str(&[
        "loss scaling [21]",
        "yes",
        "allreduce(16L bits)",
        "scaling factor",
    ]);
    t.row_str(&[
        "TernGrad [28]",
        "no",
        "uses special distributed system",
        "no",
    ]);
    t.row_str(&["QSGD [3]", "no", "depends on coding algorithm", "bucket size"]);
    t.row_str(&[
        "flex16+5 [17]",
        "yes",
        "single node; gradients (16L+5) bits",
        "no",
    ]);
    t.print();

    println!("\nconcrete bit counts at L = {l} elements:\n");
    let mut t = Table::new(&["method", "total bits on wire", "vs FP32"]);
    let (fp32_bits, _) = table2_cost("FP32", l);
    for m in ["FP32", "loss-scaling", "APS"] {
        let (bits, _desc) = table2_cost(m, l);
        t.row(&[
            m.to_string(),
            bits.to_string(),
            format!("{:.2}x", fp32_bits as f64 / bits as f64),
        ]);
    }
    t.print();

    let (aps_bits, _) = table2_cost("APS", l);
    let (ls_bits, _) = table2_cost("loss-scaling", l);
    assert!(aps_bits * 2 <= ls_bits + 16, "APS must halve loss-scaling's traffic");
    println!("\nAPS cost = 8L + 8 bits ≈ half of FP16 loss scaling, quarter of FP32 ✔");
}

//! Shared plumbing for the table/figure reproduction benches.
//!
//! Every bench prints (a) the paper's reported numbers and (b) this
//! repository's measured numbers side by side, then asserts the *shape*
//! claims (who wins, roughly by how much) — see DESIGN.md §3 on why
//! absolute values differ (synthetic workloads, scaled-down models).

#![allow(dead_code)]

use aps_cpd::aps::{HybridSchedule, SyncMethod, SyncOptions};
use aps_cpd::collectives::Topology;
use aps_cpd::coordinator::{TrainOutcome, Trainer, TrainerSetup};
use aps_cpd::optim::{LrSchedule, OptimizerKind};
use aps_cpd::runtime::{Engine, Model};

pub struct BenchEnv {
    pub engine: Engine,
}

impl BenchEnv {
    pub fn new() -> Self {
        if !std::path::Path::new("artifacts/.stamp").exists() {
            eprintln!("ERROR: artifacts missing — run `make artifacts` first");
            std::process::exit(0); // treat as skip under `cargo bench`
        }
        let engine = Engine::cpu().expect("PJRT cpu client");
        BenchEnv { engine }
    }

    pub fn model(&self, name: &str) -> Model {
        self.engine.load_model("artifacts", name).expect("load model")
    }
}

/// Standard training-run shape used by the accuracy tables. Scale knobs
/// come from env (`APS_BENCH_EPOCHS`, `APS_BENCH_STEPS`) so `make bench`
/// can run a longer calibration pass.
#[derive(Clone, Copy)]
pub struct RunShape {
    pub world: usize,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub eval_examples: usize,
    pub lr: f32,
    pub seed: u64,
}

impl RunShape {
    pub fn standard(world: usize) -> Self {
        let epochs = env_usize("APS_BENCH_EPOCHS", 4);
        let steps = env_usize("APS_BENCH_STEPS", 20);
        RunShape {
            world,
            epochs,
            steps_per_epoch: steps,
            eval_examples: 512,
            lr: 0.05,
            seed: 42,
        }
    }

    /// Smaller shape for the 256-worker experiments (fewer, larger steps).
    pub fn large_cluster(world: usize) -> Self {
        let epochs = env_usize("APS_BENCH_EPOCHS", 2);
        let steps = env_usize("APS_BENCH_STEPS", 20);
        RunShape {
            world,
            epochs,
            steps_per_epoch: steps,
            eval_examples: 256,
            lr: 0.05,
            seed: 42,
        }
    }
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run one training configuration and return its outcome.
#[allow(clippy::too_many_arguments)]
pub fn train(
    model: &Model,
    shape: RunShape,
    method: SyncMethod,
    topo: Topology,
    kahan: bool,
    fp32_last_layer: bool,
    hybrid: Option<HybridSchedule>,
    optimizer: Option<OptimizerKind>,
    label: &str,
) -> TrainOutcome {
    let sync = SyncOptions::new(method)
        .with_topology(topo)
        .with_kahan(kahan)
        .with_fp32_last_layer(fp32_last_layer);
    let mut setup = TrainerSetup::new(shape.world, sync);
    setup.epochs = shape.epochs;
    setup.steps_per_epoch = shape.steps_per_epoch;
    setup.eval_examples = shape.eval_examples;
    setup.schedule = LrSchedule::Constant { lr: shape.lr };
    setup.seed = shape.seed;
    if let Some(o) = optimizer {
        setup.optimizer = o;
    }
    setup.hybrid = hybrid;
    let mut trainer = Trainer::new(model, setup).expect("trainer");
    trainer.train(label).expect("train")
}

/// Simple accuracy formatter: `92.4` or `DIVERGED`.
pub fn acc_cell(out: &TrainOutcome) -> String {
    if out.diverged || !out.final_metric.is_finite() {
        "DIVERGED".to_string()
    } else {
        format!("{:.1}", 100.0 * out.final_metric)
    }
}

pub fn header(title: &str, paper_ref: &str) {
    println!("\n==================================================================");
    println!("{title}");
    println!("paper reference: {paper_ref}");
    println!("==================================================================\n");
}

pub fn shape_note() {
    println!(
        "\n(shape reproduction: synthetic workload + scaled-down model — compare\n orderings and gaps against the paper column, not absolute values)"
    );
}

//! Fig 2 — per-layer gradient distributions inside one model (ResNet).
//!
//! The paper's point: even within one model the layers' gradient scales
//! differ wildly, which is what APS's *layer-wise* factors exploit.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::{local_max_exp, SyncMethod, SyncOptions};
use aps_cpd::coordinator::{Trainer, TrainerSetup};
use aps_cpd::metrics::ExpHistogram;
use aps_cpd::util::table::Table;
use support::BenchEnv;

fn main() {
    support::header("Fig 2 — per-layer gradient distributions (ResNet)", "paper §3.1, Fig 2");
    let env = BenchEnv::new();
    let model = env.model("resnet");

    let world = 8;
    let mut setup = TrainerSetup::new(world, SyncOptions::new(SyncMethod::Fp32));
    setup.epochs = 1;
    setup.steps_per_epoch = 5;
    let mut trainer = Trainer::new(&model, setup).expect("trainer");
    let mut out = Default::default();
    for s in 0..5 {
        trainer.step(0, s, &mut out).expect("step");
    }
    let grads = trainer.snapshot_gradients(5).expect("grads");

    let mut t = Table::new(&["layer", "elements", "p50 exp", "max exp", "APS factor 2^f"]);
    let mut medians = Vec::new();
    for (l, g) in grads.iter().enumerate() {
        let mut h = ExpHistogram::gradient_window();
        h.add_all(g);
        let p50 = h.percentile_exp(50.0);
        medians.push(p50);
        let me = local_max_exp(g, world).unwrap_or(0);
        let factor = aps_cpd::cpd::FpFormat::E5M2.max_exponent() - me;
        t.row(&[
            model.spec.params[l].name.clone(),
            g.len().to_string(),
            format!("2^{p50}"),
            format!("2^{me}"),
            format!("2^{factor}"),
        ]);
    }
    t.print();

    let min = *medians.iter().min().unwrap();
    let max = *medians.iter().max().unwrap();
    assert!(
        max - min >= 3,
        "per-layer medians should span ≥ 3 octaves (got 2^{min}..2^{max})"
    );
    println!(
        "\nper-layer median exponents span 2^{min}..2^{max} — the layer-wise APS\nfactors (rightmost column) differ across layers, as in the paper's Fig 2 ✔"
    );
}

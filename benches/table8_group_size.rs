//! Table 8 — hierarchical group size vs accuracy (all layers low
//! precision, including the classification layer).
//!
//! Paper (256 nodes): (4,3) k=32 → 74.95, k=16 → 75.46;
//!                    (5,2) k=32 → 74.91, k=16 → 75.08.
//! Shape claim: smaller groups (k=16) reduce round-off vs k=32 and give
//! equal-or-better accuracy.

#[path = "support/mod.rs"]
mod support;

use aps_cpd::aps::SyncMethod;
use aps_cpd::collectives::Topology;
use aps_cpd::cpd::FpFormat;
use aps_cpd::util::table::Table;
use support::{acc_cell, env_usize, train, BenchEnv, RunShape};

fn main() {
    support::header("Table 8 — group size vs accuracy (256 workers)", "paper §4.2, Table 8");
    let env = BenchEnv::new();
    // ResNet-50 is the paper's model; the default stand-in here is the
    // fast-learning classifier so a full 256-worker sweep stays within a
    // bench budget. Set APS_BENCH_MODEL=resnet for the conv stand-in
    // (same code path, ~10× wall time). See DESIGN.md §3.
    let model_name =
        std::env::var("APS_BENCH_MODEL").unwrap_or_else(|_| "mlp".to_string());
    let model = env.model(&model_name);
    let world = env_usize("APS_BENCH_WORLD", 256);
    let mut shape = RunShape::large_cluster(world);
    shape.seed = 7;

    let rows: &[(&str, FpFormat, usize, &str)] = &[
        ("(4,3): 8bits", FpFormat::E4M3, 32, "74.95"),
        ("(4,3): 8bits", FpFormat::E4M3, 16, "75.46"),
        ("(5,2): 8bits", FpFormat::E5M2, 32, "74.91"),
        ("(5,2): 8bits", FpFormat::E5M2, 16, "75.08"),
    ];

    let mut t = Table::new(&[
        "precision",
        "group size",
        "measured acc %",
        "mean Eq.5 round-off %",
        "paper acc %",
    ]);
    let mut results = Vec::new();
    for (prec, fmt, k, paper_acc) in rows {
        let k = if world % k == 0 { *k } else { 4 };
        let mut sh = shape;
        sh.seed = 7;
        let out = {
            let sync = aps_cpd::aps::SyncOptions::new(SyncMethod::Aps { fmt: *fmt })
                .with_topology(Topology::Hierarchical { group_size: k });
            let mut setup = aps_cpd::coordinator::TrainerSetup::new(sh.world, sync);
            setup.epochs = sh.epochs;
            setup.steps_per_epoch = sh.steps_per_epoch;
            setup.eval_examples = sh.eval_examples;
            setup.schedule = aps_cpd::optim::LrSchedule::Constant { lr: sh.lr };
            setup.seed = sh.seed;
            setup.track_roundoff = true;
            let mut trainer =
                aps_cpd::coordinator::Trainer::new(&model, setup).expect("trainer");
            trainer.train(format!("t8-{prec}-k{k}")).expect("train")
        };
        t.row(&[
            prec.to_string(),
            k.to_string(),
            acc_cell(&out),
            format!("{:.2}", 100.0 * out.mean_roundoff()),
            paper_acc.to_string(),
        ]);
        results.push(out);
    }
    t.print();
    support::shape_note();

    // Round-off ordering: k=16 ≤ k=32 for both formats (the paper's
    // mechanism for the accuracy difference).
    assert!(
        results[1].mean_roundoff() <= results[0].mean_roundoff() * 1.05,
        "(4,3): k=16 round-off should be ≤ k=32"
    );
    assert!(
        results[3].mean_roundoff() <= results[2].mean_roundoff() * 1.05,
        "(5,2): k=16 round-off should be ≤ k=32"
    );
    println!("\nshape ✔  k=16 shows lower Eq.5 round-off than k=32 for both formats");
}
